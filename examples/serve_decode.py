"""Serving example: prefill + batched KV-cache decoding (reduced config).

Exercises the same prefill/serve_step code paths the decode_32k/long_500k
dry-runs lower, including the sliding-window ring buffer.

    PYTHONPATH=src python examples/serve_decode.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.transformer import (decode_step, init_cache, init_params,
                                      prefill)


def main():
    cfg = get_config("tinyllama-1.1b").reduced(num_layers=4, d_model=256)
    cfg = dataclasses.replace(cfg, sliding_window=64)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, prompt_len, gen_len = 4, 32, 24
    window = cfg.sliding_window

    # sliding-window ring-buffer cache (long-context serving mode)
    cache = init_cache(cfg, B, window, dtype=jnp.float32)
    prompt = jax.random.randint(key, (B, prompt_len), 0, cfg.vocab_size)

    t0 = time.time()
    logits, cache = prefill(params, cfg, tokens=prompt, cache=cache)
    print(f"prefill: {prompt.shape} -> logits {logits.shape} "
          f"({time.time()-t0:.2f}s)")

    step = jax.jit(lambda p, tok, c, i: decode_step(
        p, cfg, tokens=tok, cache=c, index=i, window=window))
    tok = jnp.argmax(logits, -1)[:, None]
    out = [tok]
    for i in range(gen_len):
        logits, cache = step(params, tok, cache, jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits, -1)[:, None]
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    print(f"decoded {gen_len} tokens/seq with a {window}-slot ring buffer")
    print("sample token ids:", gen[0].tolist())


if __name__ == "__main__":
    main()
