"""Quickstart: build a decentralized network, route flows, train 10 iterations.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs import get_config
from repro.core.executor import DecentralizedTrainer
from repro.core.flow.graph import geo_distributed_network
from repro.data.pipeline import DataConfig, DataNodeShard


def main():
    # 1. A small LLaMA-like model (the paper's eval family), reduced for CPU.
    cfg = get_config("gwtf-llama-300m").reduced(num_layers=4, d_model=128)
    print(f"model: {cfg.name} ({cfg.num_layers}L, d_model={cfg.d_model})")

    # 2. A geo-distributed volunteer network: 2 data nodes, 8 relays in 4
    #    stages, heterogeneous capacities, WAN-like links.
    net = geo_distributed_network(
        num_stages=4,
        relay_capacities=[2, 3, 3, 2, 3, 3, 2, 3, 3, 2, 3, 3],
        num_data_nodes=2, data_capacity=4,
        rng=np.random.default_rng(0))
    print(f"network: {len(net.nodes)} nodes, {net.num_stages} stages, "
          f"stage capacities = "
          f"{[net.stage_capacity(s) for s in range(net.num_stages)]}")

    # 3. GWTF: decentralized flow construction + real JAX training.
    trainer = DecentralizedTrainer(cfg, net, churn=0.05, lr=3e-3, seed=0)
    flows = trainer.protocol.complete_flows()
    print(f"flows built: {len(flows)}")
    for f in flows[:4]:
        print("  flow:", " -> ".join(map(str, f)))

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, batch_size=8,
                    microbatch_size=2, seed=0)
    shards = {d.id: DataNodeShard(dc, d.id, 2) for d in net.data_nodes()}
    for it in range(10):
        batches = {dn: s.microbatches() for dn, s in shards.items()}
        r = trainer.iteration(batches)
        print(f"iter {it}: loss={r.loss:.4f} "
              f"microbatches={r.completed}/{r.launched}")


if __name__ == "__main__":
    main()
