"""End-to-end driver: decentralized training of the paper's ~300M-family
model (reduced to CPU scale) for a few hundred steps under churn, with the
centralized baseline trained side by side — the Fig. 6 experiment.

    PYTHONPATH=src python examples/decentralized_train.py --iterations 200

The staged runtime writes per-stage snapshots (params + optimizer state)
every ``--checkpoint-every`` iterations when ``--checkpoint-dir`` is
set; ``--resume`` restores from them and continues the run — the same
snapshots that bootstrap rejoining nodes (paper Sec. V-E).  Each report
line includes the reroute/recompute counters of the stage-local
recovery path and the resident activation-store bytes (boundary
activations + VJP residuals kept by the fused dispatch);
``--activation-codec int8`` quantises the store (per-tensor symmetric
int8 + fp32 scale) for ~4x less resident memory at a bounded fidelity
cost, and ``--remat`` switches to the rematerialising oracle backward.
``--wire-codec`` compresses the inter-stage boundary-chunk transfers on
the forward path (bf16 / int8 / top-k, or ``planner`` to follow the
flow layer's per-link codec choices; the centralized baseline gets the
same forced codec so the Fig. 6 gap isolates the scheduling, not the
wire fidelity).
"""
import argparse
import os

import numpy as np

from repro.checkpoint import store as ckpt
from repro.configs import get_config
from repro.core.executor import CentralizedTrainer, DecentralizedTrainer
from repro.core.flow.graph import geo_distributed_network
from repro.data.pipeline import DataConfig, DataNodeShard


def _cen_state(cen):
    return {"stage_params": cen.stage_params, "head_params": cen.head_params,
            "stage_opt": cen.stage_opt, "head_opt": cen.head_opt}


def _cen_path(d):
    return os.path.join(d, "centralized.npz")


def save_centralized(cen, d, step):
    """The baseline snapshots alongside the stage checkpoints so a
    resumed run compares trainers of the same training age."""
    ckpt.save(_cen_path(d), _cen_state(cen), step=step)


def restore_centralized(cen, d):
    tree, step = ckpt.restore(_cen_path(d), _cen_state(cen))
    cen.stage_params = tree["stage_params"]
    cen.head_params = tree["head_params"]
    cen.stage_opt = tree["stage_opt"]
    cen.head_opt = tree["head_opt"]
    return step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iterations", type=int, default=200)
    ap.add_argument("--churn", type=float, default=0.1)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint-dir", type=str, default=None,
                    help="write per-stage snapshots here (and bootstrap "
                         "rejoining nodes from them)")
    ap.add_argument("--checkpoint-every", type=int, default=20,
                    help="snapshot period in iterations")
    ap.add_argument("--resume", action="store_true",
                    help="restore from --checkpoint-dir before training")
    ap.add_argument("--activation-codec", choices=["fp", "int8"],
                    default="fp",
                    help="activation/residual store codec: fp (exact, "
                         "default) or int8 (per-tensor symmetric, ~4x "
                         "smaller resident store)")
    ap.add_argument("--remat", action="store_true",
                    help="rematerialising backward (the in-engine "
                         "equality oracle) instead of the fused "
                         "residual-carrying dispatch")
    ap.add_argument("--wire-codec",
                    choices=["fp32", "bf16", "int8", "top-k", "planner"],
                    default="fp32",
                    help="inter-stage wire codec for boundary-chunk "
                         "transfers: fp32 (exact, default), a forced "
                         "codec, or planner (follow the network's "
                         "per-link codec-choice matrix)")
    args = ap.parse_args()

    cfg = get_config("gwtf-llama-300m").reduced(
        num_layers=args.layers, d_model=args.d_model)
    S = 4
    net = geo_distributed_network(
        num_stages=S, relay_capacities=[3] * 12, num_data_nodes=1,
        data_capacity=8, rng=np.random.default_rng(args.seed))
    dec = DecentralizedTrainer(cfg, net, churn=args.churn, lr=1e-3,
                               seed=args.seed,
                               checkpoint_dir=args.checkpoint_dir,
                               checkpoint_every=args.checkpoint_every,
                               activation_codec=args.activation_codec,
                               remat=args.remat,
                               wire_codec=args.wire_codec)
    cen = CentralizedTrainer(cfg, S, lr=1e-3, seed=args.seed,
                             activation_codec=args.activation_codec,
                             remat=args.remat,
                             wire_codec=("fp32" if args.wire_codec ==
                                         "planner" else args.wire_codec))
    if args.resume:
        if not args.checkpoint_dir:
            ap.error("--resume requires --checkpoint-dir")
        step = dec.restore_checkpoint(args.checkpoint_dir)
        cen_step = restore_centralized(cen, args.checkpoint_dir)
        print(f"resumed from {args.checkpoint_dir} at step {step} "
              f"(centralized baseline at step {cen_step})")
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                    batch_size=16, microbatch_size=2, seed=args.seed)
    shard = DataNodeShard(dc, 0, 1)
    dn = net.data_nodes()[0].id

    print(f"training {cfg.name}: {args.iterations} iterations, "
          f"churn={args.churn:.0%}, {S} stages x 3 replicas"
          + (f", snapshots -> {args.checkpoint_dir}"
             if args.checkpoint_dir else ""))
    for it in range(args.iterations):
        mbs = shard.microbatches()
        r = dec.iteration({dn: mbs})
        cl = cen.iteration(mbs)
        if args.checkpoint_dir and dec.step % args.checkpoint_every == 0:
            save_centralized(cen, args.checkpoint_dir, dec.step)
        if it % 10 == 0:
            print(f"iter {it:4d}  GWTF(churn) loss={r.loss:.4f} "
                  f"[{r.completed}/{r.launched} mb, "
                  f"rerouted={r.rerouted} (requeued={r.requeued}), "
                  f"recomputes fwd={r.fwd_recomputes} "
                  f"bwd={r.bwd_replays}, dropped={r.dropped}, "
                  f"store={r.store_peak_bytes / 1e6:.1f}MB "
                  f"{args.activation_codec}, "
                  f"wire={r.wire_bytes / 1e6:.1f}MB "
                  f"{','.join(r.wire_codecs) or 'fp32'}]   "
                  f"centralized loss={cl:.4f}")
    g = np.mean(dec.losses[-10:])
    c = np.mean(cen.losses[-10:])
    print(f"\nfinal (mean last 10): GWTF={g:.4f} centralized={c:.4f} "
          f"gap={abs(g-c):.4f}")
    if dec.joins_bootstrapped:
        print(f"{dec.joins_bootstrapped} rejoining node(s) bootstrapped "
              f"from stage snapshots (Sec. V-E)")
    print("paper Fig. 6: the two curves coincide — GWTF does not change "
          "the training semantics, only the schedule.")


if __name__ == "__main__":
    main()
