"""End-to-end driver: decentralized training of the paper's ~300M-family
model (reduced to CPU scale) for a few hundred steps under churn, with the
centralized baseline trained side by side — the Fig. 6 experiment.

    PYTHONPATH=src python examples/decentralized_train.py --iterations 200
"""
import argparse

import numpy as np

from repro.configs import get_config
from repro.core.executor import CentralizedTrainer, DecentralizedTrainer
from repro.core.flow.graph import geo_distributed_network
from repro.data.pipeline import DataConfig, DataNodeShard


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iterations", type=int, default=200)
    ap.add_argument("--churn", type=float, default=0.1)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config("gwtf-llama-300m").reduced(
        num_layers=args.layers, d_model=args.d_model)
    S = 4
    net = geo_distributed_network(
        num_stages=S, relay_capacities=[3] * 12, num_data_nodes=1,
        data_capacity=8, rng=np.random.default_rng(args.seed))
    dec = DecentralizedTrainer(cfg, net, churn=args.churn, lr=1e-3,
                               seed=args.seed)
    cen = CentralizedTrainer(cfg, S, lr=1e-3, seed=args.seed)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                    batch_size=16, microbatch_size=2, seed=args.seed)
    shard = DataNodeShard(dc, 0, 1)
    dn = net.data_nodes()[0].id

    print(f"training {cfg.name}: {args.iterations} iterations, "
          f"churn={args.churn:.0%}, {S} stages x 3 replicas")
    for it in range(args.iterations):
        mbs = shard.microbatches()
        r = dec.iteration({dn: mbs})
        cl = cen.iteration(mbs)
        if it % 10 == 0:
            print(f"iter {it:4d}  GWTF(churn) loss={r.loss:.4f} "
                  f"[{r.completed}/{r.launched} mb]   "
                  f"centralized loss={cl:.4f}")
    g = np.mean(dec.losses[-10:])
    c = np.mean(cen.losses[-10:])
    print(f"\nfinal (mean last 10): GWTF={g:.4f} centralized={c:.4f} "
          f"gap={abs(g-c):.4f}")
    print("paper Fig. 6: the two curves coincide — GWTF does not change "
          "the training semantics, only the schedule.")


if __name__ == "__main__":
    main()
