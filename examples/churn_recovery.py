"""Churn-tolerance demo: GWTF vs SWARM under crash-heavy conditions.

Reproduces the paper's core claim interactively: with 20% of relays
crashing/rejoining each iteration, GWTF's flow repair keeps wasted GPU
time near zero while SWARM's full-pipeline recomputes burn compute.

    PYTHONPATH=src python examples/churn_recovery.py
"""
import numpy as np

from repro.configs import get_config
from repro.core.flow.graph import geo_distributed_network
from repro.core.simulator import ModelProfile, TrainingSimulator


def run(scheduler: str, churn: float, seed: int = 0):
    cfg = get_config("gwtf-llama-300m")
    prof = ModelProfile.from_config(cfg, num_stages=6)
    rng = np.random.default_rng(seed)
    caps = [int(rng.uniform(1, 4)) for _ in range(16)]
    net = geo_distributed_network(num_stages=4, relay_capacities=caps,
                                  num_data_nodes=2, data_capacity=4,
                                  compute_cost=prof.fwd_compute,
                                  activation_size=prof.activation_bytes,
                                  rng=np.random.default_rng(seed))
    sim = TrainingSimulator(net, scheduler=scheduler, profile=prof,
                            churn=churn, rng=np.random.default_rng(seed + 7))
    ms = sim.run(15)[3:]
    return {
        "time/mb (min)": np.mean([m.time_per_microbatch for m in ms]) / 60,
        "throughput": np.mean([m.completed for m in ms]),
        "comm (min)": np.mean([m.comm_time for m in ms]) / 60,
        "wasted gpu (min)": np.mean([m.wasted_gpu for m in ms]) / 60,
    }


def main():
    for churn in (0.0, 0.1, 0.2):
        print(f"\n=== churn {int(churn*100)}% (heterogeneous capacities) ===")
        g = run("gwtf", churn)
        s = run("swarm", churn)
        for k in g:
            better = "GWTF" if g[k] <= s[k] else "SWARM"
            if k == "throughput":
                better = "GWTF" if g[k] >= s[k] else "SWARM"
            print(f"  {k:18s} GWTF={g[k]:6.2f}  SWARM={s[k]:6.2f}  [{better}]")
        speedup = (s["time/mb (min)"] - g["time/mb (min)"]) / s["time/mb (min)"]
        print(f"  GWTF training-time reduction: {speedup:+.0%} "
              f"(paper: up to 45%)")


if __name__ == "__main__":
    main()
