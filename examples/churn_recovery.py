"""Churn-tolerance demo: GWTF vs SWARM under crash-heavy conditions.

Reproduces the paper's core claim interactively: with 20% of relays
crashing/rejoining each iteration, GWTF's flow repair keeps wasted GPU
time near zero while SWARM's full-pipeline recomputes burn compute.

Beyond the paper's Bernoulli churn, the layered fault model runs two
harder scenarios (FusionLLM-style geo-distributed failure modes):

* ``regional`` — correlated regional outages: one of the 10 geographic
  locations goes dark and every relay there crashes at the same
  moment, with gradual rejoins;
* ``trace``  — deterministic trace replay: a scripted blackout of one
  location mid-run (plus background Bernoulli churn) so both
  schedulers face the *identical* fault sequence.

Two beyond-fail-stop scenarios demo the adversarial fault models and
the detect–quarantine–reroute layer (these compare the GWTF engine
*defended vs undefended* instead of GWTF vs SWARM):

* ``straggler`` — pathologically slow and hung relays: the deadline
  defense hedges at the healthy-estimate deadline and reroutes, the
  undefended engine waits the slowdown out;
* ``byzantine`` — corrupt-gradient relays: the detection screen feeds
  the reputation layer, which quarantines the corrupt relay and plans
  around it (the simulator carries no real gradients, so this shows
  the detection/quarantine plumbing; the real gradient math lives in
  the runtime trainer and `BENCH_exec.json`'s byzantine record).

    PYTHONPATH=src python examples/churn_recovery.py               # all
    PYTHONPATH=src python examples/churn_recovery.py bernoulli
    PYTHONPATH=src python examples/churn_recovery.py straggler byzantine
"""
import sys

import numpy as np

from repro.configs import get_config
from repro.core.flow.graph import geo_distributed_network
from repro.core.simulator import (ComposedChurn, BernoulliChurn,
                                  CorruptGradientChurn, ModelProfile,
                                  RegionalOutageChurn, StragglerChurn,
                                  TraceChurn, TrainingSimulator, summarize)


def make_setup(seed: int = 0):
    cfg = get_config("gwtf-llama-300m")
    prof = ModelProfile.from_config(cfg, num_stages=6)
    rng = np.random.default_rng(seed)
    caps = [int(rng.uniform(1, 4)) for _ in range(16)]
    net = geo_distributed_network(num_stages=4, relay_capacities=caps,
                                  num_data_nodes=2, data_capacity=4,
                                  compute_cost=prof.fwd_compute,
                                  activation_size=prof.activation_bytes,
                                  rng=np.random.default_rng(seed))
    return net, prof


def run(scheduler: str, *, churn: float = 0.0, churn_model=None,
        seed: int = 0, iterations: int = 15, warmup: int = 3):
    net, prof = make_setup(seed)
    if callable(churn_model):                  # needs the topology
        churn_model = churn_model(net)
    sim = TrainingSimulator(net, scheduler=scheduler, profile=prof,
                            churn=churn, churn_model=churn_model,
                            rng=np.random.default_rng(seed + 7))
    table = summarize(sim.run(iterations), warmup=warmup)
    return {
        "time/mb (min)": table["time_per_mb"][0] / 60,
        "throughput": table["throughput"][0],
        "comm (min)": table["comm_time"][0] / 60,
        "wasted gpu (min)": table["wasted_gpu"][0] / 60,
        "reroutes": table["reroutes"][0],
        "queue depth (peak)": table["queue_depth_peak"][0],
    }


def compare(title: str, **kwargs):
    print(f"\n=== {title} ===")
    g = run("gwtf", **kwargs)
    s = run("swarm", **kwargs)
    for k in g:
        better = "GWTF" if g[k] <= s[k] else "SWARM"
        if k == "throughput":
            better = "GWTF" if g[k] >= s[k] else "SWARM"
        print(f"  {k:18s} GWTF={g[k]:6.2f}  SWARM={s[k]:6.2f}  [{better}]")
    s_t, g_t = s["time/mb (min)"], g["time/mb (min)"]
    if s_t:
        print(f"  GWTF training-time reduction: {(s_t - g_t) / s_t:+.0%} "
              f"(paper: up to 45%)")


def scenario_bernoulli():
    for churn in (0.0, 0.1, 0.2):
        compare(f"churn {int(churn * 100)}% (heterogeneous capacities)",
                churn=churn)


def scenario_regional():
    # every ~3rd iteration one of the 10 locations blacks out entirely;
    # dead relays come back with p=0.5 per iteration
    compare("correlated regional outages (30% per iteration, full region)",
            churn_model=lambda net: RegionalOutageChurn(
                0.3, severity=1.0, rejoin_prob=0.5))


def scenario_trace():
    # scripted blackout of one location at iteration 5 (rejoining at 8),
    # on top of 5% background Bernoulli churn — both schedulers replay
    # the identical scripted fault sequence
    def model(net):
        loc = net.stage_nodes(0)[0].location
        return ComposedChurn([
            TraceChurn.regional_blackout(net, location=loc, at_iteration=5,
                                         duration=3, when=0.25),
            BernoulliChurn(0.05),
        ])
    compare("trace replay: scripted location blackout @ iter 5 "
            "+ 5% background churn", churn_model=model)


def _run_defense(model_factory, *, seed: int = 0, iterations: int = 10,
                 **sim_kw):
    net, prof = make_setup(seed)
    sim = TrainingSimulator(net, scheduler="gwtf", profile=prof,
                            churn_model=model_factory(net),
                            rng=np.random.default_rng(seed + 7), **sim_kw)
    ms = sim.run(iterations)
    detections = sum(c for (_, _f, kind), c
                     in sim.engine.timeline.counts().items()
                     if kind == "detection")
    return {
        "duration (min)": sum(m.duration for m in ms) / 60,
        "throughput": (sum(m.completed for m in ms)
                       / max(1e-9, sum(m.duration for m in ms))),
        "timeouts": sum(m.timeouts for m in ms),
        "reroutes": sum(m.reroutes for m in ms),
        "detections": detections,
    }, net


def _compare_defense(title: str, model_factory, defended_kw, undefended_kw):
    print(f"\n=== {title} ===")
    d, d_net = _run_defense(model_factory, **defended_kw)
    u, _ = _run_defense(model_factory, **undefended_kw)
    for k in d:
        print(f"  {k:18s} defended={d[k]:8.2f}  undefended={u[k]:8.2f}")
    if u["throughput"]:
        print(f"  deadline/quarantine defense throughput gain: "
              f"{d['throughput'] / u['throughput']:.1f}x")
    return d, u, d_net


def scenario_straggler():
    # one hung relay plus one pathological slowdown, sized from the
    # profile so the slowed compute blows the healthy-estimate deadline
    # (timeout 30s) — i.e. both are deadline-catchable
    def model(net):
        relays = [n.id for n in net.nodes.values() if not n.is_data]
        factor = 2.0 * (30.0 / max(1e-6, min(
            net.nodes[r].compute_cost for r in relays)) + 1.0)
        return StragglerChurn({relays[1]: factor}, hangs=[relays[0]],
                              known_ids=net.nodes.keys())
    _compare_defense(
        "stragglers: 1 hung + 1 pathologically slow relay",
        model, dict(deadline_defense=True), dict(deadline_defense=False))


def scenario_byzantine():
    # one corrupt relay; the (simulated) screen detects contributions
    # whose chains cross it, reports drop its reputation below the
    # quarantine threshold, and the next plan routes around it
    def model(net):
        victim = net.stage_nodes(1)[0].id
        return CorruptGradientChurn([victim], mode="perturb", scale=1.0,
                                    seed=7, known_ids=net.nodes.keys())
    d, u, net = _compare_defense(
        "byzantine: 1 corrupt-gradient relay (perturb x1.0)",
        model, dict(corrupt_screen=True), dict(corrupt_screen=False))
    victim = net.stage_nodes(1)[0].id
    print(f"  corrupt relay {victim}: reputation "
          f"{net.reputation(victim):.3f}"
          f"{'  [quarantined]' if net.quarantined(victim) else ''}")


SCENARIOS = {
    "bernoulli": scenario_bernoulli,
    "regional": scenario_regional,
    "trace": scenario_trace,
    "straggler": scenario_straggler,
    "byzantine": scenario_byzantine,
}


def main(argv=None):
    names = (argv if argv else None) or list(SCENARIOS)
    unknown = [n for n in names if n not in SCENARIOS]
    if unknown:
        raise SystemExit(f"unknown scenario(s) {unknown}; "
                         f"pick from {sorted(SCENARIOS)}")
    for name in names:
        SCENARIOS[name]()


if __name__ == "__main__":
    main(sys.argv[1:])
