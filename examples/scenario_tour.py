"""One scenario, three engines: tour of the cross-layer harness.

Picks a named scenario from the committed corpus and drives it through
every execution layer the repo has, printing what each one saw and the
differential checks tying them together:

1. **flow layer** — the batched `GWTFProtocol`, its strict scalar
   mode and the frozen reference engine build the same plan
   bit-for-bit; the `MinCostFlow` oracle prices the optimum;
2. **simulator** — the discrete-event engine times the scenario's
   iterations under the spec's churn program (Table II/III columns);
3. **real compute** (``--runtime``) — the staged JAX runtime trains a
   reduced model through the *same* churn program, and the harness
   checks its plans and fault accounting against the simulator's.

    PYTHONPATH=src python examples/scenario_tour.py
    PYTHONPATH=src python examples/scenario_tour.py geo-regional-blackout
    PYTHONPATH=src python examples/scenario_tour.py trace-crash-rejoin --runtime
    PYTHONPATH=src python examples/scenario_tour.py --list
"""
import sys

from repro.core.scenarios import generate
from repro.core.scenarios.corpus import load_corpus
from repro.core.scenarios.harness import (check_flow_equivalence,
                                          check_optimal_consistency,
                                          check_sim_runtime_consistency)
from repro.core.sim.metrics import summarize


def main(argv):
    names = [a for a in argv if not a.startswith("-")]
    if "--list" in argv:
        for spec in load_corpus():
            kinds = ",".join(c["kind"] for c in spec.churn) or "no churn"
            print(f"{spec.name:28s} {spec.topology:9s} {kinds}")
        return
    name = names[0] if names else "table2-het-churn10"
    spec = next(s for s in load_corpus() if s.name == name)
    print(f"=== scenario {spec.name!r} ===")
    print(f"  {spec.topology} topology, {spec.num_stages} stages x "
          f"{spec.relays_per_stage} relays, {spec.num_data_nodes} data "
          f"node(s), churn program: "
          f"{[c['kind'] for c in spec.churn] or 'none'}")

    print("\n[flow] batched vs strict vs reference (bit-equality gate)")
    rep = check_flow_equivalence(spec)
    print(f"  all three engines agree: {rep['flows']} chains, "
          f"total cost {rep['total_cost']:.2f} "
          f"(+ crash/rejoin episode on {rep['churn_episode']})")
    opt = check_optimal_consistency(spec)
    print(f"  centralized optimum: flow {opt['flow']:.0f}, "
          f"cost {opt['cost']:.2f}")

    print("\n[sim] discrete-event run")
    table = summarize(generate.run_sim(spec), warmup=1)
    for col in ("time_per_mb", "throughput", "wasted_gpu", "reroutes"):
        mean, std = table[col]
        print(f"  {col:14s} {mean:10.3f} +- {std:.3f}")

    if "--runtime" in argv:
        print("\n[runtime] real-compute differential vs the simulator")
        rep = check_sim_runtime_consistency(
            spec.replace(iterations=min(spec.iterations, 3)))
        print(f"  plans identical across layers for "
              f"{rep['iterations']} iterations; "
              f"runtime repaired {rep['runtime_rerouted']} microbatches "
              f"(sim rerouted {rep['sim_reroutes']})")
    else:
        print("\n(pass --runtime for the real-compute differential; "
              "needs JAX)")


if __name__ == "__main__":
    main(sys.argv[1:])
