"""GWTF on the production target: flow-routed pipeline placement over
TPU pod slices, with preemption repair (DESIGN.md Sec. 3).

    PYTHONPATH=src python examples/pod_slicing.py
"""
from repro.configs import get_config
from repro.core.podmap import carve_pod, lose_slice, schedule_pipelines


def main():
    cfg = get_config("gemma-7b")
    slices = carve_pod((16, 16), (4, 4))
    print(f"pod 16x16 carved into {len(slices)} slices of 4x4 chips")

    proto, net = schedule_pipelines(cfg, num_stages=5)
    flows = proto.complete_flows()
    print(f"\n{cfg.name}: {len(flows)} pipeline flows across 5 stages")
    for f in flows[:4]:
        hops = " -> ".join(f"slice{n}" for n in f)
        print("  ", hops)
    print(f"  max edge cost: {proto.max_edge_cost()*1e3:.2f} ms "
          f"(compute+ICI per microbatch hop)")

    victim = flows[0][2]
    print(f"\npreempting slice {victim} (on flow 0)...")
    new_flows = lose_slice(proto, net, victim)
    print(f"repaired: {len(new_flows)} flows, none through slice {victim}: "
          f"{all(victim not in f for f in new_flows)}")
    print(f"  max edge cost after repair: {proto.max_edge_cost()*1e3:.2f} ms")


if __name__ == "__main__":
    main()
