"""Smoke coverage for the serving stubs: the `launch/serve.py` driver
and `examples/serve_decode.py` must import cleanly and survive a tiny
prefill + decode step (they are not exercised by any benchmark job, so
an API drift in models/transformer would otherwise ship silently)."""
import os
import sys

import jax
import jax.numpy as jnp
import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_serve_driver_tiny_decode(monkeypatch, capsys):
    """Run the real `repro.launch.serve` CLI end to end on a reduced
    config: prefill + 2 greedy decode steps."""
    import repro.launch.serve as serve

    monkeypatch.setattr(sys, "argv", [
        "serve", "--arch", "tinyllama-1.1b", "--reduced", "--layers", "2",
        "--d-model", "64", "--batch", "1", "--prompt-len", "8",
        "--gen", "2"])
    serve.main()
    out = capsys.readouterr().out
    assert "prefill: bs=1 len=8" in out
    assert "decoded 2 steps" in out


def test_serve_driver_long_mode(monkeypatch, capsys):
    """The sliding-window ring-buffer path (--long) decodes past the
    window without growing the cache."""
    import repro.launch.serve as serve

    monkeypatch.setattr(sys, "argv", [
        "serve", "--arch", "tinyllama-1.1b", "--reduced", "--layers", "2",
        "--d-model", "64", "--batch", "1", "--prompt-len", "8",
        "--gen", "2", "--long", "--window", "16"])
    serve.main()
    assert "ring-buffer" in capsys.readouterr().out


def test_serve_example_imports_and_decode_path_runs():
    """`examples/serve_decode.py` parses/compiles, and the exact code
    path it demonstrates (sliding-window prefill + jitted decode_step)
    works on a smaller-than-example shape."""
    import dataclasses

    from repro.configs import get_config
    from repro.models.transformer import (decode_step, init_cache,
                                          init_params, prefill)

    path = os.path.join(_REPO, "examples", "serve_decode.py")
    with open(path) as fh:
        compile(fh.read(), path, "exec")     # syntax/shape of the stub

    cfg = dataclasses.replace(
        get_config("tinyllama-1.1b").reduced(num_layers=2, d_model=64),
        sliding_window=16)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    window = cfg.sliding_window
    cache = init_cache(cfg, 1, window, dtype=jnp.float32)
    prompt = jax.random.randint(key, (1, 8), 0, cfg.vocab_size)
    logits, cache = prefill(params, cfg, tokens=prompt, cache=cache)
    assert logits.shape[0] == 1
    step = jax.jit(lambda p, tok, c, i: decode_step(
        p, cfg, tokens=tok, cache=c, index=i, window=window))
    tok = jnp.argmax(logits, -1)[:, None]
    for i in range(2):
        logits, cache = step(params, tok, cache, jnp.int32(8 + i))
        tok = jnp.argmax(logits, -1)[:, None]
    assert tok.shape == (1, 1)
    assert int(tok[0, 0]) < cfg.vocab_size
