"""Discrete-event simulator + churn/recovery behaviour (paper Sec. VI)."""
import numpy as np
import pytest

from repro.core.flow.graph import geo_distributed_network
from repro.core.join import assign_joiners, flood_utilization, StageReport
from repro.core.membership import DHT, Contact, elect_leader
from repro.core.simulator import ModelProfile, TrainingSimulator
from repro.core.swarm import SwarmRouter
from repro.configs import get_config


def make_net(seed=0, het=False, stages=4, relays=16, data_capacity=4):
    rng = np.random.default_rng(seed)
    caps = ([int(rng.uniform(1, 4)) for _ in range(relays)] if het
            else [4] * relays)
    return geo_distributed_network(
        num_stages=stages, relay_capacities=caps, num_data_nodes=2,
        data_capacity=data_capacity, compute_cost=0.05,
        rng=np.random.default_rng(seed))


class TestSimulator:
    def test_no_churn_all_complete(self):
        net = make_net()
        sim = TrainingSimulator(net, scheduler="gwtf", churn=0.0,
                                rng=np.random.default_rng(1))
        ms = sim.run(5)
        for m in ms:
            assert m.completed == m.launched
            assert m.wasted_gpu == 0.0
            assert m.duration > 0

    def test_swarm_no_churn_all_complete(self):
        net = make_net()
        sim = TrainingSimulator(net, scheduler="swarm", churn=0.0,
                                rng=np.random.default_rng(1))
        ms = sim.run(5)
        for m in ms:
            assert m.completed == m.launched == 8

    def test_churn_degrades_but_survives(self):
        net = make_net(seed=2)
        sim = TrainingSimulator(net, scheduler="gwtf", churn=0.1,
                                rng=np.random.default_rng(3))
        ms = sim.run(10)
        assert sum(m.completed for m in ms) > 0

    def test_gwtf_wastes_less_than_swarm_under_churn(self):
        """The paper's headline: GWTF wasted GPU time ~0 vs SWARM > 0."""
        waste = {}
        for sched in ("gwtf", "swarm"):
            totals = []
            for seed in range(3):
                net = make_net(seed=seed, het=True)
                sim = TrainingSimulator(net, scheduler=sched, churn=0.15,
                                        rng=np.random.default_rng(seed + 9))
                ms = sim.run(8)
                totals.append(np.mean([m.wasted_gpu for m in ms]))
            waste[sched] = np.mean(totals)
        assert waste["gwtf"] <= waste["swarm"]

    def test_gwtf_faster_than_swarm_heterogeneous(self):
        """Time per microbatch: GWTF < SWARM on heterogeneous capacities."""
        tpm = {}
        cfg = get_config("gwtf-llama-300m")
        prof = ModelProfile.from_config(cfg, num_stages=4)
        for sched in ("gwtf", "swarm"):
            vals = []
            for seed in range(3):
                rng = np.random.default_rng(seed)
                caps = [int(rng.uniform(1, 4)) for _ in range(16)]
                net = geo_distributed_network(
                    num_stages=4, relay_capacities=caps, num_data_nodes=2,
                    data_capacity=4, compute_cost=prof.fwd_compute,
                    activation_size=prof.activation_bytes,
                    rng=np.random.default_rng(seed))
                sim = TrainingSimulator(net, scheduler=sched, profile=prof,
                                        churn=0.0,
                                        rng=np.random.default_rng(seed + 5))
                ms = sim.run(6)[1:]
                vals.append(np.mean([m.time_per_microbatch for m in ms]))
            tpm[sched] = np.mean(vals)
        assert tpm["gwtf"] < tpm["swarm"]

    def test_metrics_are_finite(self):
        net = make_net(seed=4, het=True)
        sim = TrainingSimulator(net, scheduler="gwtf", churn=0.2,
                                rng=np.random.default_rng(5))
        for m in sim.run(6):
            assert np.isfinite(m.duration)
            assert np.isfinite(m.comm_time)
            assert m.completed <= m.launched


class TestSwarmRouter:
    def test_route_is_stagewise(self):
        net = make_net()
        r = SwarmRouter(net, rng=np.random.default_rng(0))
        path = r.route(0)
        assert path[0] == path[-1] == 0
        for s, nid in enumerate(path[1:-1]):
            assert net.nodes[nid].stage == s

    def test_exclusion(self):
        net = make_net()
        r = SwarmRouter(net, rng=np.random.default_rng(0))
        first = r.next_hop(0, 0, 0)
        second = r.next_hop(0, 0, 0, exclude={first})
        assert second != first


class TestMembershipAndJoin:
    def test_dht_and_leader(self):
        dht = DHT()
        dht.publish(Contact(5, -1, 4, is_data=True))
        dht.publish(Contact(2, -1, 4, is_data=True))
        dht.publish(Contact(7, 0, 2))
        assert elect_leader(dht) == 2
        dht.registry[2].alive = False
        assert elect_leader(dht) == 5
        assert [c.node_id for c in dht.lookup_stage(0)] == [7]
        assert dht.lookup_time_total > 0

    def test_flood_utilization(self):
        net = make_net()
        flows = [[0, 2, 6, 10, 14, 0], [1, 3, 7, 11, 15, 1]]
        reports = flood_utilization(net, flows)
        assert len(reports) == net.num_stages
        for r in reports:
            assert r.flows == 2

    def test_gwtf_join_targets_bottleneck(self):
        reports = [StageReport(0, 2, 4), StageReport(1, 10, 4),
                   StageReport(2, 5, 4)]
        # utilization: s0=2.0 (bottleneck), s2=0.8, s1=0.4
        assign = assign_joiners(reports, [1, 9, 5], policy="gwtf")
        # highest capacity (9) -> most utilized stage (0)
        assert assign[1] == 0
        # second (5) -> stage 2
        assert assign[2] == 2

    def test_random_policy_in_range(self):
        reports = [StageReport(s, 4, 2) for s in range(4)]
        assign = assign_joiners(reports, [3, 2, 1], policy="random",
                                rng=np.random.default_rng(0))
        assert all(0 <= a < 4 for a in assign)


from _hypothesis_compat import given, settings, st


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 5000), churn=st.sampled_from([0.0, 0.1, 0.3]),
       scheduler=st.sampled_from(["gwtf", "swarm"]))
def test_property_simulator_invariants(seed, churn, scheduler):
    """For any topology/churn/scheduler: event times non-negative,
    completed <= launched, metrics finite, capacities never oversubscribed
    at iteration end (all slots released)."""
    net = make_net(seed=seed % 7, het=True)
    sim = TrainingSimulator(net, scheduler=scheduler, churn=churn,
                            rng=np.random.default_rng(seed))
    for m in sim.run(4):
        assert m.duration >= 0
        assert 0 <= m.completed <= m.launched
        assert np.isfinite(m.comm_time) and m.comm_time >= 0
        assert np.isfinite(m.wasted_gpu) and m.wasted_gpu >= 0
