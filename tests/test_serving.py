"""Serving-plane test tier.

Promoted from the old ``test_serve_smoke.py``: the standalone
``launch/serve.py`` driver and ``examples/serve_decode.py`` smoke
coverage rides along unchanged, joined by the churn-tolerant serving
plane proper — seeded RNG-key discipline, request conservation,
continuous-batching bit-equivalence against the standalone decode
path, crash-mid-decode requeue recovering the exact token stream, and
KV-residency pricing monotonicity on the flow graph.

Fast checks run in tier 1; the crash-recovery differential (three full
real-compute serving runs) lives behind ``-m scenarios`` next to the
corpus sweep.
"""
import dataclasses
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.runtime.serving import serving_inputs, serving_keys
from repro.core.scenarios import generate
from repro.core.scenarios.harness import (check_serving_consistency,
                                          check_serving_invariants)
from repro.core.scenarios.spec import ScenarioSpec
from repro.core.sim.metrics import summarize_serving

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _serving_spec(**overrides) -> ScenarioSpec:
    """Tiny 3-stage geo serving scenario shared by the tests below."""
    kw = dict(
        name="t-serve", seed=26, num_stages=3,
        relays_per_stage=3, num_data_nodes=1, iterations=2,
        model_layers=2, model_d=32, model_vocab=128, seq_len=16,
        microbatch_size=1,
        arrivals=[{"kind": "spike", "at_iteration": 0,
                   "requests": 3, "when": 0.2}],
        prompt_len=8, gen_tokens=16, serve_batch=4)
    kw.update(overrides)
    spec = ScenarioSpec(**kw)
    spec.validate()
    return spec


# ---------------------------------------------------------------------------
# Satellite 1: seeded key discipline (the launch/serve.py RNG fix)
# ---------------------------------------------------------------------------

def test_serving_keys_distinct_and_reproducible():
    """``serving_keys`` must fan one seed into four *distinct* streams
    (params / prompt / aux / sampling — the old driver reused one key
    for all of them) and be a pure function of the seed."""
    def raw(keys):
        return [tuple(np.asarray(k).ravel().tolist()) for k in keys]

    keys = serving_keys(7)
    assert len(keys) == 4
    first = raw(keys)
    assert len(set(first)) == 4, "key streams must not collide"
    assert first == raw(serving_keys(7))
    assert first != raw(serving_keys(8))


def test_serving_inputs_seeded_determinism():
    """Params/prompt/sampling material is bit-reproducible per seed and
    the prompt stream is decoupled from the param stream."""
    from repro.configs import get_config
    cfg = get_config("tinyllama-1.1b").reduced(num_layers=2, d_model=64)
    a = serving_inputs(cfg, seed=3, batch=2, prompt_len=8)
    b = serving_inputs(cfg, seed=3, batch=2, prompt_len=8)
    assert all(bool(jnp.array_equal(x, y)) for x, y in
               zip(jax.tree_util.tree_leaves(a[:2]),
                   jax.tree_util.tree_leaves(b[:2])))
    c = serving_inputs(cfg, seed=4, batch=2, prompt_len=8)
    assert not bool(jnp.array_equal(a[1], c[1]))


def test_serve_driver_seeded_determinism(monkeypatch, capsys):
    """Two driver runs with the same ``--seed`` emit identical sampled
    tokens; a different seed diverges (the pre-fix driver fed the same
    key to init and to every sampling step)."""
    import repro.launch.serve as serve

    def run(seed):
        monkeypatch.setattr(sys, "argv", [
            "serve", "--arch", "tinyllama-1.1b", "--reduced", "--layers",
            "2", "--d-model", "64", "--batch", "1", "--prompt-len", "8",
            "--gen", "3", "--temperature", "1.0", "--seed", str(seed)])
        serve.main()
        out = capsys.readouterr().out
        return [ln for ln in out.splitlines() if "sample:" in ln]

    first = run(11)
    assert first, "driver printed no sampled tokens"
    assert first == run(11)
    assert first != run(12)


# ---------------------------------------------------------------------------
# Absorbed smoke coverage (formerly tests/test_serve_smoke.py)
# ---------------------------------------------------------------------------

def test_serve_driver_tiny_decode(monkeypatch, capsys):
    """Run the real `repro.launch.serve` CLI end to end on a reduced
    config: prefill + 2 greedy decode steps."""
    import repro.launch.serve as serve

    monkeypatch.setattr(sys, "argv", [
        "serve", "--arch", "tinyllama-1.1b", "--reduced", "--layers", "2",
        "--d-model", "64", "--batch", "1", "--prompt-len", "8",
        "--gen", "2"])
    serve.main()
    out = capsys.readouterr().out
    assert "prefill: bs=1 len=8" in out
    assert "decoded 2 steps" in out


def test_serve_driver_long_mode(monkeypatch, capsys):
    """The sliding-window ring-buffer path (--long) decodes past the
    window without growing the cache."""
    import repro.launch.serve as serve

    monkeypatch.setattr(sys, "argv", [
        "serve", "--arch", "tinyllama-1.1b", "--reduced", "--layers", "2",
        "--d-model", "64", "--batch", "1", "--prompt-len", "8",
        "--gen", "2", "--long", "--window", "16"])
    serve.main()
    assert "ring-buffer" in capsys.readouterr().out


def test_serve_example_imports_and_decode_path_runs():
    """`examples/serve_decode.py` parses/compiles, and the exact code
    path it demonstrates (sliding-window prefill + jitted decode_step)
    works on a smaller-than-example shape."""
    from repro.configs import get_config
    from repro.models.transformer import (decode_step, init_cache,
                                          init_params, prefill)

    path = os.path.join(_REPO, "examples", "serve_decode.py")
    with open(path) as fh:
        compile(fh.read(), path, "exec")     # syntax/shape of the stub
    cfg = dataclasses.replace(
        get_config("tinyllama-1.1b").reduced(num_layers=2, d_model=64),
        sliding_window=16)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    window = cfg.sliding_window
    cache = init_cache(cfg, 1, window, dtype=jnp.float32)
    prompt = jax.random.randint(key, (1, 8), 0, cfg.vocab_size)
    logits, cache = prefill(params, cfg, tokens=prompt, cache=cache)
    assert logits.shape[0] == 1
    step = jax.jit(lambda p, tok, c, i: decode_step(
        p, cfg, tokens=tok, cache=c, index=i, window=window))
    tok = jnp.argmax(logits, -1)[:, None]
    for i in range(2):
        logits, cache = step(params, tok, cache, jnp.int32(8 + i))
        tok = jnp.argmax(logits, -1)[:, None]
    assert tok.shape == (1, 1)
    assert int(tok[0, 0]) < cfg.vocab_size


# ---------------------------------------------------------------------------
# Satellite 2: serving invariants and differentials
# ---------------------------------------------------------------------------

def test_request_conservation_invariant():
    """admitted == completed + dropped + in_flight at every iteration
    boundary, plus the rest of the pure-sim invariant battery (seeded
    rerun identity, arrival accounting, TTFT ordering)."""
    spec = _serving_spec(
        gen_tokens=8,
        arrivals=[{"kind": "poisson", "rate": 2.0},
                  {"kind": "spike", "at_iteration": 1,
                   "requests": 4, "when": 0.3}],
        churn=[{"kind": "trace", "events": [(1, "crash", 5, 0.45)]}],
        iterations=3)
    out = check_serving_invariants(spec)
    assert out["admitted"] >= 4
    assert out["admitted"] == (out["completed"] + out["dropped"]
                               + out["summary"]["in_flight"])


def test_kv_residency_pricing_monotonicity():
    """Eq. 1 destination surcharge: resident sequences raise every
    in-edge of their host, monotonically in the count; the trivial
    state is bit-identical to the serving-free matrix; migration is
    priced exactly at the link's communication model."""
    spec = _serving_spec(kv_weight=0.0)
    net, _ = generate.build_network(spec)
    base = net.cost_matrix().copy()

    net.kv_weight = 0.5
    net.invalidate_costs()
    assert not net.kv_active()
    # trivial state (no residents) must reproduce the base bytes
    assert np.array_equal(net.cost_matrix(), base)

    nid = sorted(net.nodes)[2]
    prev = base
    for count in (1, 3, 9):
        net.set_kv_residency(nid, count)
        assert net.kv_active()
        m = net.cost_matrix().copy()
        col = [i for i in sorted(net.nodes) if i != nid]
        # host column strictly more expensive, monotone in residency
        assert all(m[i, nid] > prev[i, nid] for i in col)
        assert np.isclose(m[3, nid] - base[3, nid],
                          net.kv_weight * count)
        # every other column untouched
        other = [j for j in sorted(net.nodes) if j != nid]
        assert np.array_equal(m[np.ix_(other, other)],
                              base[np.ix_(other, other)])
        prev = m

    # migration pays the same wire-codec physics as activations
    kv_bytes = 4096.0
    assert (net.kv_migration_cost(3, nid, kv_bytes)
            == net.comm_cost(3, nid, kv_bytes))

    # bulk clear snaps back to the trivial serving-free matrix
    net.update_kv_residency({})
    assert not net.kv_active()
    assert np.array_equal(net.cost_matrix(), base)


def test_continuous_batching_bit_match():
    """Same-stage stacked decode must be bit-identical to the
    standalone one-request-at-a-time serve path, while actually
    batching (more stacked rows than dispatches)."""
    spec = _serving_spec(gen_tokens=4, serve_batch=3, iterations=2)
    out = check_serving_consistency(spec)
    assert out["streams_checked"] >= 1
    assert out["summary"]["completed"] >= 1.0
    assert out["stacked_rows"] > out["decode_dispatches"], \
        "cohorts never stacked — continuous batching is not exercised"


@pytest.mark.scenarios
def test_crash_mid_decode_recovers_exact_stream():
    """A relay crash while requests are mid-decode: the defended
    executor requeues onto a surviving chain, teacher-force replays the
    generated prefix to rebuild the KV cache, and finishes the *exact*
    token streams of an undisturbed run — at far better tail latency
    than the undefended drop-and-retry baseline."""
    calm = _serving_spec()
    crash = dataclasses.replace(
        calm, churn=[{"kind": "trace", "events": [(0, "crash", 5, 0.45)]}])
    crash.validate()

    # sim: every victim is mid-decode (k > 0) when the relay dies
    eng = generate.build_serving_sim(crash)
    sim_ms = eng.run(crash.iterations)
    ks = [op[5] for tl in eng.traces for op in tl if op[0] == "requeue"]
    assert ks and all(k > 0 for k in ks), \
        f"crash must land mid-decode, requeue prefixes were {ks}"

    ref = generate.build_serving_runtime(calm)
    ref.run(calm.iterations)
    tr = generate.build_serving_runtime(crash)
    rt_ms = tr.run(crash.iterations)
    assert tr.replay_steps > 0, "requeue never replayed a KV prefix"
    assert [summarize_serving([m]) for m in rt_ms] \
        == [summarize_serving([m]) for m in sim_ms]
    for rid in range(3):
        assert tr.token_stream(rid) == ref.token_stream(rid), \
            f"request {rid} stream diverged after crash-requeue"

    und = generate.build_serving_runtime(crash, reroute=False)
    und_ms = und.run(crash.iterations)
    su = summarize_serving(und_ms)
    sd = summarize_serving(rt_ms)
    assert su["restarts"] >= 1.0 and sd["requeues"] >= 1.0
    assert su["p99_ttft"] > sd["p99_ttft"], \
        "defended requeue should beat drop-and-retry tail latency"
    for rid in range(3):      # undefended restarts are slow, not wrong
        assert und.token_stream(rid) == ref.token_stream(rid)
