"""Scenario corpus + cross-layer differential harness.

Fast representative checks run in tier-1; the full corpus sweep, the
runtime-involving differentials and the seeded fuzz session carry the
``scenarios`` marker and run in the dedicated CI job
(``pytest -m scenarios``; tier-1 deselects them via pytest.ini).
"""
import os

import numpy as np
import pytest

from repro.core.flow.mincost import MinCostFlow, solve_training_flow
from repro.core.scenarios import generate
from repro.core.scenarios.corpus import (GOLDEN_PINNED, get_scenario,
                                         load_corpus, load_golden)
from repro.core.scenarios.harness import (ADVERSARIAL_FUZZ_CHECKS,
                                          FUZZ_CHECKS, SCALE_FUZZ_CHECKS,
                                          SERVE_FUZZ_CHECKS,
                                          ScenarioDiscrepancy,
                                          check_capacity_monotonicity,
                                          check_codec_agreement,
                                          check_detection_precision_recall,
                                          check_fault_timeline,
                                          check_flow_equivalence,
                                          check_optimal_consistency,
                                          check_permutation_invariance,
                                          check_serving_invariants,
                                          check_sim_runtime_consistency,
                                          check_zero_churn, fuzz, minimize,
                                          random_adversarial_spec,
                                          random_scale_spec,
                                          random_serving_spec, run_checks,
                                          scale_checks)
from repro.core.scenarios.spec import ScenarioSpec
from repro.core.sim.metrics import summarize, summarize_serving
from tests._hypothesis_compat import given, settings, st

CORPUS = load_corpus()
CORPUS_IDS = [s.name for s in CORPUS]
SCALE_CORPUS = load_corpus(tier="scale")
SCALE_IDS = [s.name for s in SCALE_CORPUS]


def small_spec(**kw):
    base = dict(name="t", seed=1, topology="synthetic", num_stages=3,
                relays_per_stage=3, num_data_nodes=1, source_capacity=3,
                capacity_range=(1, 3), cost_range=(1, 9), iterations=2)
    base.update(kw)
    return ScenarioSpec(**base).validate()


# ---------------------------------------------------------------------------
# Spec schema (satellite: strict validation)
# ---------------------------------------------------------------------------

class TestSpecSchema:
    def test_round_trip(self):
        spec = get_scenario("geo-flash-crowd")
        again = ScenarioSpec.from_json(spec.to_json())
        assert again == spec

    def test_unknown_field_rejected(self):
        d = small_spec().to_dict()
        d["chrun"] = []                      # typo'd field must not pass
        with pytest.raises(ValueError, match="unknown field"):
            ScenarioSpec.from_dict(d)

    def test_unknown_churn_kind_rejected(self):
        with pytest.raises(ValueError, match="unknown kind"):
            small_spec(churn=[{"kind": "meteor_strike"}])

    def test_churn_clause_field_typo_rejected(self):
        with pytest.raises(ValueError, match="unknown field"):
            small_spec(churn=[{"kind": "bernoulli", "p": 0.1,
                               "prob": 0.1}])
        with pytest.raises(ValueError, match="missing field"):
            small_spec(churn=[{"kind": "bernoulli"}])

    def test_geo_only_clause_on_synthetic_rejected(self):
        with pytest.raises(ValueError, match="geo topology"):
            small_spec(churn=[{"kind": "regional_blackout", "location": 0,
                               "at_iteration": 0}])

    def test_flash_crowd_needs_spares(self):
        with pytest.raises(ValueError, match="spare_nodes"):
            ScenarioSpec(name="t", topology="geo",
                         churn=[{"kind": "flash_crowd", "at_iteration": 1,
                                 "nodes": 3}]).validate()

    def test_corpus_specs_validate_and_are_unique(self):
        assert len(CORPUS) >= 12
        assert len({s.name for s in CORPUS}) == len(CORPUS)

    def test_unknown_tier_rejected(self):
        with pytest.raises(ValueError, match="tier"):
            small_spec(tier="mega")
        with pytest.raises(ValueError, match="tier"):
            load_corpus(tier="mega")

    def test_scale_tier_is_separate(self):
        """Scale specs never leak into the standard corpus (which the
        golden file covers) and vice versa."""
        assert len(SCALE_CORPUS) >= 3
        assert all(s.tier == "scale" for s in SCALE_CORPUS)
        assert all(s.tier == "standard" for s in CORPUS)
        assert not set(SCALE_IDS) & set(CORPUS_IDS)
        both = load_corpus(tier="all")
        assert {s.name for s in both} == set(SCALE_IDS) | set(CORPUS_IDS)

    def test_location_clause_allowed_on_geo_abstract(self):
        small_spec(topology="geo-abstract",
                   churn=[{"kind": "regional_blackout", "location": 0,
                           "at_iteration": 0}])
        # but bandwidth-touching clauses still need the real geo links
        with pytest.raises(ValueError, match="geo topology"):
            small_spec(topology="geo-abstract",
                       churn=[{"kind": "link_degradation",
                               "at_iteration": 0, "factor": 2.0}])


# ---------------------------------------------------------------------------
# Deterministic materialization
# ---------------------------------------------------------------------------

class TestGenerator:
    def test_network_deterministic(self):
        spec = get_scenario("table2-het-churn10")
        a, _ = generate.build_network(spec)
        b, _ = generate.build_network(spec)
        np.testing.assert_array_equal(a.latency, b.latency)
        np.testing.assert_array_equal(a.bandwidth, b.bandwidth)
        assert [(n.id, n.stage, n.capacity, n.compute_cost, n.location)
                for n in a.nodes.values()] == \
               [(n.id, n.stage, n.capacity, n.compute_cost, n.location)
                for n in b.nodes.values()]

    def test_spare_nodes_created_dead(self):
        spec = get_scenario("geo-flash-crowd")
        net, _ = generate.build_network(spec)
        spares = generate.spare_node_ids(spec)
        assert len(spares) == spec.spare_nodes
        assert all(not net.nodes[nid].alive for nid in spares)
        assert all(net.nodes[nid].alive for nid in range(spec.base_nodes))

    def test_region_heterogeneity_applied(self):
        spec = get_scenario("geo-hetero-compute")
        flat = spec.replace(region_compute_scale=None,
                            region_bandwidth_scale=None)
        het, _ = generate.build_network(spec)
        base, _ = generate.build_network(flat)
        scaled = [nid for nid, n in het.nodes.items() if not n.is_data
                  and n.compute_cost != base.nodes[nid].compute_cost]
        assert scaled                        # some region got slower
        assert (het.bandwidth <= base.bandwidth + 1e-9).all()
        assert (het.bandwidth < base.bandwidth).any()

    def test_sim_runs_are_reproducible(self):
        spec = get_scenario("geo-churn5")
        a = summarize(generate.run_sim(spec), warmup=1)
        b = summarize(generate.run_sim(spec), warmup=1)
        assert a == b


# ---------------------------------------------------------------------------
# Differential harness — fast representatives (tier-1)
# ---------------------------------------------------------------------------

class TestHarnessFast:
    def test_flow_equivalence_synthetic(self):
        check_flow_equivalence(small_spec(), max_rounds=80)

    def test_flow_equivalence_geo_with_spares(self):
        spec = ScenarioSpec(
            name="t", seed=2, topology="geo", num_stages=3,
            relays_per_stage=3, num_data_nodes=2, data_capacity=3,
            spare_nodes=2, iterations=2,
            churn=[{"kind": "flash_crowd", "at_iteration": 1, "nodes": 2}])
        check_flow_equivalence(spec, max_rounds=80)

    def test_metamorphic_synthetic(self):
        spec = small_spec(seed=5, num_data_nodes=2)
        check_optimal_consistency(spec)
        check_capacity_monotonicity(spec)
        check_permutation_invariance(spec)

    def test_discrepancy_detected_on_tampered_engine(self, monkeypatch):
        """The harness is not vacuous: perturbing the cost matrix that
        one engine sees must make check_flow_equivalence itself raise
        ScenarioDiscrepancy (guards the comparison polarity, not just
        the engines)."""
        spec = small_spec(seed=3)
        real_build = generate.build_flow

        def tampered(s, engine="batched", net=None, cost_matrix=None):
            if engine == "batched" and cost_matrix is not None:
                cost_matrix = np.asarray(cost_matrix) + 1.0
            return real_build(s, engine, net=net, cost_matrix=cost_matrix)

        monkeypatch.setattr(generate, "build_flow", tampered)
        with pytest.raises(ScenarioDiscrepancy, match="batched"):
            check_flow_equivalence(spec)

    def test_scale_check_selection(self):
        """The scale tier swaps the O(N^2) reference differential out
        above ~600 nodes and swaps the hierarchy gap check in on
        located topologies; real-compute checks never appear."""
        assert scale_checks(get_scenario("scale-flow-500")) == \
            ("flow-equivalence", "sim-invariants")
        assert scale_checks(get_scenario("scale-geo-1000-churn10")) == \
            ("sim-invariants", "hierarchy-gap")
        assert scale_checks(get_scenario("scale-geo-2000-blackout")) == \
            ("sim-invariants", "hierarchy-gap")
        for spec in SCALE_CORPUS:
            assert "sim-runtime" not in scale_checks(spec)

    def test_capacity_monotonicity_is_falsifiable(self):
        """Sanity: the invariant check actually compares costs (a fake
        regression — raising all link costs — is caught by re-solving
        at higher cost and asserting the bound manually)."""
        spec = small_spec(seed=4)
        base = generate.solve_optimal(spec, "dense")
        net, cm = generate.build_network(spec)
        worse = solve_training_flow(net, cost_matrix=np.asarray(cm) + 5.0,
                                    max_flow=base.flow, method="dense")
        assert worse.cost > base.cost


# ---------------------------------------------------------------------------
# Property tests: MinCostFlow dial vs dense on scenario-generated
# layered graphs (satellite)
# ---------------------------------------------------------------------------

class TestMinCostFlowProperties:
    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 10 ** 6), stages=st.integers(2, 5),
           relays=st.integers(2, 5), chi=st.integers(3, 25),
           sources=st.integers(1, 3))
    def test_dial_matches_dense_on_layered_graphs(self, seed, stages,
                                                  relays, chi, sources):
        spec = ScenarioSpec(name="prop", seed=seed, topology="synthetic",
                            num_stages=stages, relays_per_stage=relays,
                            num_data_nodes=sources, source_capacity=3,
                            capacity_range=(1, 3), cost_range=(1, chi),
                            iterations=1).validate()
        net, cm = generate.build_network(spec)
        dense = solve_training_flow(net, cost_matrix=cm, method="dense")
        dial = solve_training_flow(net, cost_matrix=cm, method="dial")
        auto = solve_training_flow(net, cost_matrix=cm, method="auto")
        assert dial.flow == dense.flow == auto.flow
        assert abs(dial.cost - dense.cost) <= 1e-6 * max(1.0, dense.cost)
        assert auto.cost == dial.cost        # auto selects dial here

    def test_non_integer_costs_fall_back_to_dense(self):
        spec = ScenarioSpec(name="t", seed=1, topology="geo", num_stages=2,
                            relays_per_stage=2, num_data_nodes=1,
                            iterations=1).validate()
        net, _ = generate.build_network(spec)
        auto = solve_training_flow(net, method="auto")
        dense = solve_training_flow(net, method="dense")
        assert auto.flow == dense.flow
        assert auto.cost == pytest.approx(dense.cost, rel=1e-12)
        with pytest.raises(ValueError, match="integer"):
            solve_training_flow(net, method="dial")

    def test_empty_and_degenerate_graphs(self):
        # empty arc set: nothing flows, both cores agree
        for method in ("dial", "dense"):
            mc = MinCostFlow(4)
            assert mc.solve(0, 3, method=method) == (0.0, 0.0)
        # disconnected sink
        for method in ("dial", "dense"):
            mc = MinCostFlow(4)
            mc.add_edge(0, 1, 5, 1)
            assert mc.solve(0, 3, method=method) == (0.0, 0.0)
        # zero-capacity path
        for method in ("dial", "dense"):
            mc = MinCostFlow(3)
            mc.add_edge(0, 1, 0, 1)
            mc.add_edge(1, 2, 4, 1)
            assert mc.solve(0, 2, method=method) == (0.0, 0.0)
        # a stage emptied by churn: the layered graph has no through-path
        spec = small_spec(seed=6)
        net, cm = generate.build_network(spec)
        for n in net.stage_nodes(1):
            net.kill_node(n.id)
        plan = solve_training_flow(net, cost_matrix=cm)
        assert plan.flow == 0.0 and plan.cost == 0.0


# ---------------------------------------------------------------------------
# Golden-metrics regression (satellite): tolerance-free pins
# ---------------------------------------------------------------------------

class TestGoldenMetrics:
    @pytest.mark.parametrize("name", GOLDEN_PINNED)
    def test_pinned_summaries_bit_exact(self, name):
        """Table II/III summarize() columns for the pinned corpus
        scenarios — exact equality, no tolerances: seeded GWTF runs
        are bit-deterministic end to end."""
        spec = get_scenario(name)
        golden = load_golden()[name]
        flow = generate.run_flow(spec, "batched")
        assert len(flow.flows) == golden["flow"]["chains"]
        assert flow.total_cost == golden["flow"]["total_cost"]
        assert flow.rounds == golden["flow"]["rounds"]
        table = summarize(generate.run_sim(spec), warmup=1)
        assert {k: list(v) for k, v in table.items()} == golden["sim"]
        if "serving" in golden:
            row = summarize_serving(generate.run_serving_sim(spec))
            assert row == golden["serving"]

    def test_golden_covers_whole_corpus(self):
        golden = load_golden()
        for spec in load_corpus(include_shrunk=False):
            assert spec.name in golden, f"{spec.name} missing a golden"


# ---------------------------------------------------------------------------
# Facade kwarg validation (satellite)
# ---------------------------------------------------------------------------

class TestFacadeValidation:
    def _net(self):
        net, _ = generate.build_network(small_spec(topology="geo",
                                                   num_data_nodes=1))
        return net

    def test_unknown_kwarg_raises(self):
        from repro.core.simulator import TrainingSimulator
        with pytest.raises(TypeError):
            TrainingSimulator(self._net(), scheduler="gwtf",
                              chrun_model=None)

    def test_churn_rate_with_churn_model_raises(self):
        from repro.core.simulator import TrainingSimulator, TraceChurn
        with pytest.raises(ValueError, match="churn_model"):
            TrainingSimulator(self._net(), churn=0.1,
                              churn_model=TraceChurn([]))

    def test_scheduler_with_policy_raises(self):
        from repro.core.simulator import TrainingSimulator
        from repro.core.sim.policies import FixedPolicy
        net = self._net()
        with pytest.raises(ValueError, match="scheduler"):
            TrainingSimulator(net, scheduler="gwtf",
                              policy=FixedPolicy(net, []))

    def test_fixed_paths_without_fixed_scheduler_raises(self):
        from repro.core.simulator import TrainingSimulator
        with pytest.raises(ValueError, match="fixed"):
            TrainingSimulator(self._net(), scheduler="gwtf",
                              fixed_paths=[[0, 1, 2, 0]])

    def test_valid_combinations_still_work(self):
        from repro.core.simulator import TrainingSimulator, TraceChurn
        net = self._net()
        sim = TrainingSimulator(net, scheduler="gwtf",
                                churn_model=TraceChurn([]),
                                rng=np.random.default_rng(0))
        m = sim.run_iteration()
        assert m.completed == m.launched > 0


# ---------------------------------------------------------------------------
# Fuzz plumbing (shrinker correctness; budget session is marker-gated)
# ---------------------------------------------------------------------------

class TestFuzzPlumbing:
    def test_minimize_shrinks_and_preserves_failure(self):
        """Shrinking against an artificial predicate ('relays_per_stage
        >= 3 fails') must return a still-failing, strictly smaller,
        valid spec."""
        from repro.core.scenarios import harness

        spec = small_spec(seed=8, num_stages=4, relays_per_stage=4,
                          num_data_nodes=2,
                          churn=[{"kind": "bernoulli", "p": 0.2}])

        def fake_check(s):
            if s.relays_per_stage >= 3:
                raise ScenarioDiscrepancy(s, "fake", "too many relays")
            return {}

        orig = harness.CHECKS
        harness.CHECKS = dict(orig, fake=(fake_check, lambda s: True))
        try:
            small = minimize(spec, ["fake"])
        finally:
            harness.CHECKS = orig
        assert small.relays_per_stage == 3      # shrunk to the boundary
        assert small.num_stages < spec.num_stages
        assert not small.churn
        small.validate()

    def test_fuzz_wraps_crash_class_bugs(self, tmp_path):
        """A check that dies with an arbitrary exception (not a
        ScenarioDiscrepancy) must still go through the shrink+commit
        pipeline instead of aborting the session."""
        from repro.core.scenarios import harness

        def crashing_check(s):
            raise IndexError("boom deep inside an engine")

        orig = harness.CHECKS
        harness.CHECKS = dict(orig, crashy=(crashing_check,
                                            lambda s: True))
        try:
            rep = fuzz(seed=2, budget_seconds=30.0, max_cases=1,
                       corpus_dir=str(tmp_path), checks=["crashy"])
        finally:
            harness.CHECKS = orig
        assert len(rep.failures) == 1
        f = rep.failures[0]
        assert f.check == "crash:IndexError"
        assert "boom" in f.detail
        assert f.written_to and os.path.exists(f.written_to)

    def test_fuzz_writes_shrunk_spec_into_corpus_dir(self, tmp_path):
        from repro.core.scenarios import harness

        calls = {"n": 0}

        def fake_check(s):
            calls["n"] += 1
            raise ScenarioDiscrepancy(s, "fake", "always fails")

        orig = harness.CHECKS
        harness.CHECKS = dict(orig, fake=(fake_check, lambda s: True))
        try:
            rep = fuzz(seed=1, budget_seconds=30.0, max_cases=1,
                       corpus_dir=str(tmp_path), checks=["fake"])
        finally:
            harness.CHECKS = orig
        assert not rep.ok and len(rep.failures) == 1
        f = rep.failures[0]
        assert f.written_to and os.path.exists(f.written_to)
        reloaded = ScenarioSpec.from_json(open(f.written_to).read())
        assert reloaded.name.startswith("shrunk-fake-")


# ===========================================================================
# Marker-gated: full corpus sweep, runtime differentials, fuzz budget
# ===========================================================================

@pytest.mark.scenarios
class TestCorpusSweep:
    @pytest.mark.parametrize("spec", CORPUS, ids=CORPUS_IDS)
    def test_flow_bit_equality(self, spec):
        """Every corpus scenario: batched/strict/reference flow engines
        bit-identical, including through a crash/rejoin episode."""
        check_flow_equivalence(spec)

    @pytest.mark.parametrize("spec", CORPUS, ids=CORPUS_IDS)
    def test_oracle_and_metamorphic(self, spec):
        check_optimal_consistency(spec)
        check_capacity_monotonicity(spec)
        check_permutation_invariance(spec)

    @pytest.mark.parametrize("spec", CORPUS, ids=CORPUS_IDS)
    def test_sim_runs_clean(self, spec):
        ms = generate.run_sim(spec)
        assert len(ms) == spec.iterations
        for m in ms:
            assert m.completed <= m.launched
            assert not m.truncated


@pytest.mark.scenarios
class TestRuntimeDifferentials:
    def test_zero_churn_corpus_scenario(self):
        check_zero_churn(get_scenario("geo-zero-churn"))

    @pytest.mark.parametrize("name", ["trace-crash-rejoin",
                                      "table2-het-churn10",
                                      "geo-flash-crowd"])
    def test_sim_runtime_consistency(self, name):
        spec = get_scenario(name)
        # reduced shape: real compute per iteration is the expensive part
        spec = spec.replace(iterations=min(spec.iterations, 4))
        check_sim_runtime_consistency(spec)

    def test_codec_agreement_corpus_scenario(self):
        """Flow/sim/runtime agree on per-link codec choices and the
        fp32-only menu is a bit-exact no-op (full cross-layer check,
        including one real-compute iteration)."""
        out = check_codec_agreement(get_scenario("geo-wan-compress"))
        assert out["flow_codec_hist"]           # someone chose a codec
        assert out["runtime_wire_bytes"] > 0


@pytest.mark.scenarios
class TestAdversarialTier:
    """Beyond-fail-stop corpus scenarios: the simulator and the
    real-compute runtime must produce the *same* fault timeline, and
    on certainly-detectable corruption the runtime screen must hit
    exact precision and recall."""

    @pytest.mark.parametrize("name", ["adversarial-corrupt",
                                      "adversarial-straggler",
                                      "adversarial-flaky"])
    def test_fault_timeline_cross_layer(self, name):
        out = check_fault_timeline(get_scenario(name))
        # non-vacuous: the committed scenarios were chosen so their
        # fault programs actually fire on both layers
        assert min(out["records"]) > 0
        if name in ("adversarial-corrupt", "adversarial-straggler"):
            assert out["cross_layer_detections"] > 0

    def test_detection_precision_recall(self):
        out = check_detection_precision_recall(
            get_scenario("adversarial-corrupt"))
        assert sum(out["detected"]) > 0

    def test_seeded_adversarial_fuzz(self, tmp_path):
        """Randomized adversarial fault programs (stragglers/hangs,
        corrupt gradients, flaky links, optional Bernoulli crashes on
        top) against the simulator invariants (default 5 s locally;
        CI sets SCENARIO_ADVERSARIAL_FUZZ_SECONDS=30)."""
        budget = float(os.environ.get(
            "SCENARIO_ADVERSARIAL_FUZZ_SECONDS", "5"))
        rep = fuzz(seed=20260809, budget_seconds=budget,
                   corpus_dir=str(tmp_path),
                   checks=ADVERSARIAL_FUZZ_CHECKS,
                   spec_factory=random_adversarial_spec)
        assert rep.cases > 0
        assert rep.ok, "\n\n".join(
            f"[{f.check}] {f.detail}" for f in rep.failures)


@pytest.mark.scenarios
class TestScaleTier:
    """The ``--scale`` corpus tier: internet-scale specs swept with the
    restricted `scale_checks` regime.  scale-flow-500 is the committed
    ≥500-relay engine-vs-reference bit-equality scenario (including the
    harness' crash→repair→rejoin episode); the geo-abstract specs run
    the event engine under churn plus the hierarchical planner's
    feasibility + optimality-gap check."""

    @pytest.mark.parametrize("spec", SCALE_CORPUS, ids=SCALE_IDS)
    def test_scale_sweep(self, spec):
        out = run_checks(spec, scale_checks(spec))
        assert "sim-invariants" in out
        gap = out.get("hierarchy-gap")
        if gap is not None:
            assert gap["flow"] > 0 and not gap.get("skipped")

    def test_seeded_scale_fuzz(self, tmp_path):
        """Randomized 1000+-relay specs under the scale check set
        (default 15 s locally; the scenario-corpus CI job raises the
        budget via SCENARIO_SCALE_FUZZ_SECONDS).  No shrinking — the
        unshrunk reproducer is still committed to tmp_path on failure."""
        budget = float(os.environ.get("SCENARIO_SCALE_FUZZ_SECONDS", "15"))
        rep = fuzz(seed=20260809, budget_seconds=budget,
                   corpus_dir=str(tmp_path), checks=SCALE_FUZZ_CHECKS,
                   spec_factory=random_scale_spec, shrink=False)
        assert rep.cases > 0
        assert rep.ok, "\n\n".join(
            f"[{f.check}] {f.detail}" for f in rep.failures)


@pytest.mark.scenarios
class TestServingTier:
    """Serving-plane corpus scenarios: numpy-only invariants for every
    spec with an arrival program, plus the seeded serve-fuzz session.
    The real-compute serving differential lives in
    tests/test_serving.py (it decodes actual tokens)."""

    @pytest.mark.parametrize("name", ["serve-steady-poisson",
                                      "serve-flash-spike",
                                      "serve-churn-under-load"])
    def test_serving_invariants_corpus(self, name):
        out = check_serving_invariants(get_scenario(name))
        assert out["admitted"] > 0 and out["completed"] > 0

    def test_seeded_serving_fuzz(self, tmp_path):
        """Randomized arrival programs + decode shapes + churn against
        the ServingEngine invariants (default 5 s locally; CI sets
        SCENARIO_SERVE_FUZZ_SECONDS=30)."""
        budget = float(os.environ.get("SCENARIO_SERVE_FUZZ_SECONDS", "5"))
        rep = fuzz(seed=20260809, budget_seconds=budget,
                   corpus_dir=str(tmp_path), checks=SERVE_FUZZ_CHECKS,
                   spec_factory=random_serving_spec)
        assert rep.cases > 0
        assert rep.ok, "\n\n".join(
            f"[{f.check}] {f.detail}\nminimized: {f.minimized.to_json()}"
            for f in rep.failures)


@pytest.mark.scenarios
class TestFuzzBudget:
    def test_seeded_fuzz_finds_no_discrepancies(self, tmp_path):
        """A seeded randomized session (default 5 s locally; CI sets
        SCENARIO_FUZZ_SECONDS=30) over the fast checks must find zero
        discrepancies; any failure lands as a shrunk spec in tmp_path
        and in the assertion message."""
        budget = float(os.environ.get("SCENARIO_FUZZ_SECONDS", "5"))
        rep = fuzz(seed=20260728, budget_seconds=budget,
                   corpus_dir=str(tmp_path), checks=FUZZ_CHECKS)
        assert rep.cases > 0
        assert rep.ok, "\n\n".join(
            f"[{f.check}] {f.detail}\nminimized: {f.minimized.to_json()}"
            for f in rep.failures)
