"""Integration: real-JAX decentralized training (Fig. 6 semantics)."""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.executor import CentralizedTrainer, DecentralizedTrainer
from repro.core.flow.graph import geo_distributed_network
from repro.data.pipeline import DataConfig, DataNodeShard


def tiny_cfg():
    cfg = get_config("gwtf-llama-300m").reduced(num_layers=4, d_model=128)
    return dataclasses.replace(cfg, vocab_size=256)


def make_net(seed=0, stages=2, data_nodes=1):
    return geo_distributed_network(
        num_stages=stages, relay_capacities=[3] * (3 * stages),
        num_data_nodes=data_nodes, data_capacity=4,
        rng=np.random.default_rng(seed))


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_cfg()
    net = make_net()
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, batch_size=8,
                    microbatch_size=2, seed=0)
    return cfg, net, DataNodeShard(dc, 0, 1)


def test_loss_decreases(setup):
    cfg, net, shard = setup
    tr = DecentralizedTrainer(cfg, net, churn=0.0, lr=3e-3, seed=0)
    dn = net.data_nodes()[0].id
    for _ in range(8):
        tr.iteration({dn: shard.microbatches()})
    assert tr.losses[-1] < tr.losses[0]


def test_matches_centralized_without_churn():
    """No churn -> bit-for-bit the same SGD trajectory as centralized."""
    cfg = tiny_cfg()
    net = make_net(seed=1)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, batch_size=8,
                    microbatch_size=2, seed=1)
    shard = DataNodeShard(dc, 0, 1)
    dec = DecentralizedTrainer(cfg, net, churn=0.0, lr=3e-3, seed=0)
    cen = CentralizedTrainer(cfg, net.num_stages, lr=3e-3, seed=0)
    dn = net.data_nodes()[0].id
    for _ in range(4):
        mbs = shard.microbatches()
        r = dec.iteration({dn: mbs})
        cl = cen.iteration(mbs)
        assert r.completed == len(mbs)
        assert abs(r.loss - cl) < 1e-4     # identical microbatch set


def test_converges_under_churn():
    """Paper Fig. 6: churn does not break convergence."""
    cfg = tiny_cfg()
    net = make_net(seed=2)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, batch_size=8,
                    microbatch_size=2, seed=2)
    shard = DataNodeShard(dc, 0, 1)
    tr = DecentralizedTrainer(cfg, net, churn=0.1, lr=3e-3, seed=3)
    dn = net.data_nodes()[0].id
    for _ in range(10):
        tr.iteration({dn: shard.microbatches()})
    done = [l for l in tr.losses if l > 0]
    assert done[-1] < done[0]


def test_hlo_analysis_scan_awareness():
    """analyze_hlo multiplies scan bodies by trip count (the raw XLA
    cost_analysis does not)."""
    import jax
    import jax.numpy as jnp
    from repro.launch.hlo_analysis import analyze_hlo

    L, D = 7, 32

    def f(w, x):
        def body(c, wl):
            return jnp.tanh(c @ wl), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    c = jax.jit(f).lower(jnp.zeros((L, D, D)), jnp.zeros((4, D))).compile()
    costs = analyze_hlo(c.as_text())
    expect = L * 2 * 4 * D * D
    assert abs(costs.dot_flops - expect) / expect < 0.01
    assert costs.while_loops == 1
