"""Scale-rebuild invariants: indexed protocol equivalence, amortized
matrix growth, cost-cache invalidation, refinement regression.

The equivalence tests are the contract of the PR that introduced the
indexed `GWTFProtocol`: for any seed, the optimized engine must produce
the *identical* flows — and consume the identical RNG stream — as the
straightforward per-round-scan `ReferenceGWTFProtocol`.
"""
import numpy as np
import pytest

from repro.core.flow.decentralized import GWTFProtocol
from repro.core.flow.graph import FlowNetwork, Node, geo_distributed_network, \
    synthetic_network
from repro.core.flow.reference import ReferenceGWTFProtocol

# Paper Table V settings (bench_flow.SETTINGS)
TABLE_V = [
    dict(name="1", sources=1, relays=40, stages=8, cap=(1, 3), cost=(1, 20)),
    dict(name="2", sources=1, relays=40, stages=10, cap=(1, 3), cost=(1, 20)),
    dict(name="3", sources=1, relays=40, stages=8, cap=(5, 15), cost=(1, 20)),
    dict(name="4", sources=1, relays=40, stages=8, cap=(1, 3), cost=(5, 100)),
    dict(name="5", sources=2, relays=40, stages=8, cap=(1, 3), cost=(1, 20)),
    dict(name="6", sources=4, relays=80, stages=8, cap=(1, 3), cost=(1, 20)),
]


def build_setting(s, seed, source_capacity=4):
    rng = np.random.default_rng(seed)
    return synthetic_network(
        num_stages=s["stages"], relays_per_stage=s["relays"] // s["stages"],
        capacities=lambda r: int(r.uniform(*s["cap"])),
        link_costs=lambda r: float(int(r.uniform(*s["cost"]))),
        num_sources=s["sources"], source_capacity=source_capacity, rng=rng)


def assert_equivalent(opt, ref, tag=""):
    assert opt.complete_flows() == ref.complete_flows(), f"{tag}: flows differ"
    assert opt.total_cost() == ref.total_cost(), f"{tag}: cost differs"
    assert opt.T == ref.T, f"{tag}: annealing temperature differs"
    assert opt.rng.bit_generator.state == ref.rng.bit_generator.state, \
        f"{tag}: RNG stream diverged"


class TestProtocolEquivalence:
    @pytest.mark.parametrize("setting", TABLE_V, ids=lambda s: s["name"])
    def test_table_v_identical_flows(self, setting):
        """Same RNG seed -> identical complete_flows() on every paper
        Table V configuration (the PR's behavior-preservation contract)."""
        for seed in range(3):
            net_o, cost_o = build_setting(setting, seed)
            net_r, cost_r = build_setting(setting, seed)
            opt = GWTFProtocol(net_o, cost_matrix=cost_o,
                               rng=np.random.default_rng(seed + 3))
            ref = ReferenceGWTFProtocol(net_r, cost_matrix=cost_r,
                                        rng=np.random.default_rng(seed + 3))
            opt.run(max_rounds=120)
            ref.run(max_rounds=120)
            assert_equivalent(opt, ref, f"setting {setting['name']} seed {seed}")
            assert len(opt.complete_flows()) > 0

    def test_equivalent_under_churn(self):
        """Crash + reclaim + repair + rejoin keeps the engines in
        lock-step (the index maintenance covers every mutation path)."""
        s = TABLE_V[4]
        for seed in range(2):
            net_o, cost_o = build_setting(s, seed)
            net_r, cost_r = build_setting(s, seed)
            opt = GWTFProtocol(net_o, cost_matrix=cost_o,
                               rng=np.random.default_rng(seed))
            ref = ReferenceGWTFProtocol(net_r, cost_matrix=cost_r,
                                        rng=np.random.default_rng(seed))
            opt.run(80)
            ref.run(80)
            flows = opt.complete_flows()
            victims = {c[2] for c in flows[:3]} | {c[4] for c in flows[:3]}
            for v in victims:
                net_o.kill_node(v)
                net_r.kill_node(v)
                opt.remove_node(v)
                ref.remove_node(v)
            opt.reclaim_sink_slots()
            ref.reclaim_sink_slots()
            opt.run(40, quiet_rounds=5)
            ref.run(40, quiet_rounds=5)
            assert_equivalent(opt, ref, f"seed {seed} post-crash")
            for v in sorted(victims):
                net_o.nodes[v].alive = True
                net_r.nodes[v].alive = True
                opt.add_node(net_o.nodes[v])
                ref.add_node(net_r.nodes[v])
            opt.reclaim_sink_slots()
            ref.reclaim_sink_slots()
            opt.run(40, quiet_rounds=5)
            ref.run(40, quiet_rounds=5)
            assert_equivalent(opt, ref, f"seed {seed} post-rejoin")

    def test_equivalent_on_eq1_costs_and_partial_views(self):
        """cost_matrix=None (cached Eq. 1 oracle) + peer_view subsets."""
        for seed in range(2):
            rng = np.random.default_rng(seed)
            caps = [int(rng.uniform(1, 4)) for _ in range(16)]
            net_o = geo_distributed_network(
                num_stages=4, relay_capacities=caps,
                rng=np.random.default_rng(seed))
            net_r = geo_distributed_network(
                num_stages=4, relay_capacities=caps,
                rng=np.random.default_rng(seed))
            opt = GWTFProtocol(net_o, peer_view=3,
                               rng=np.random.default_rng(seed + 9))
            ref = ReferenceGWTFProtocol(net_r, peer_view=3,
                                        rng=np.random.default_rng(seed + 9))
            opt.run(60)
            ref.run(60)
            assert_equivalent(opt, ref, f"geo seed {seed}")

    @pytest.mark.parametrize("relays,stages", [(120, 6), (300, 10)])
    def test_batched_mode_flow_equality_at_scale(self, relays, stages):
        """The default batched annealing engine is gated on
        flow-equality: identical final flows and total cost vs the
        scalar reference, at bench-style relay counts."""
        for seed in range(2):
            s = dict(sources=2, relays=relays, stages=stages,
                     cap=(1, 4), cost=(1, 20))
            net_o, cost_o = build_setting(s, seed, source_capacity=relays // 20)
            net_r, cost_r = build_setting(s, seed, source_capacity=relays // 20)
            opt = GWTFProtocol(net_o, cost_matrix=cost_o, objective="sum",
                               rng=np.random.default_rng(seed + 3))
            ref = ReferenceGWTFProtocol(net_r, cost_matrix=cost_r,
                                        objective="sum",
                                        rng=np.random.default_rng(seed + 3))
            opt.run(max_rounds=80)
            ref.run(max_rounds=80)
            assert opt.complete_flows() == ref.complete_flows(), \
                f"relays={relays} seed={seed}: batched flows diverged"
            assert opt.total_cost() == ref.total_cost(), \
                f"relays={relays} seed={seed}: batched total cost diverged"
            assert len(opt.complete_flows()) > 0

    @pytest.mark.parametrize("relays,stages", [(120, 6), (300, 10)])
    def test_strict_rng_mode_stream_bit_equality(self, relays, stages):
        """strict_rng=True reproduces the reference RNG stream exactly
        (bit-identical generator state after a full run), at >= 2 relay
        counts."""
        for seed in range(2):
            s = dict(sources=2, relays=relays, stages=stages,
                     cap=(1, 4), cost=(1, 20))
            net_o, cost_o = build_setting(s, seed, source_capacity=relays // 20)
            net_r, cost_r = build_setting(s, seed, source_capacity=relays // 20)
            opt = GWTFProtocol(net_o, cost_matrix=cost_o, objective="sum",
                               strict_rng=True,
                               rng=np.random.default_rng(seed + 3))
            ref = ReferenceGWTFProtocol(net_r, cost_matrix=cost_r,
                                        objective="sum",
                                        rng=np.random.default_rng(seed + 3))
            opt.run(max_rounds=80)
            ref.run(max_rounds=80)
            assert opt.rng.bit_generator.state == ref.rng.bit_generator.state, \
                f"relays={relays} seed={seed}: strict_rng stream diverged"
            assert opt.complete_flows() == ref.complete_flows()
            assert opt.T == ref.T

    def test_batched_mode_without_advance_capable_generator(self):
        """Bit generators lacking advance() (MT19937) can't rewind the
        uniform block; the batched engine must fall back to scalar
        prefix draws and stay in lockstep with the reference."""
        s = TABLE_V[0]
        for seed in range(2):
            net_o, cost_o = build_setting(s, seed)
            net_r, cost_r = build_setting(s, seed)
            opt = GWTFProtocol(
                net_o, cost_matrix=cost_o,
                rng=np.random.Generator(np.random.MT19937(seed)))
            ref = ReferenceGWTFProtocol(
                net_r, cost_matrix=cost_r,
                rng=np.random.Generator(np.random.MT19937(seed)))
            opt.run(max_rounds=100)
            ref.run(max_rounds=100)
            assert opt.complete_flows() == ref.complete_flows()
            assert opt.total_cost() == ref.total_cost()
            so = opt.rng.bit_generator.state["state"]
            sr = ref.rng.bit_generator.state["state"]
            assert so["pos"] == sr["pos"]
            assert np.array_equal(so["key"], sr["key"])

    def test_batched_and_strict_modes_agree(self):
        """The two optimized scan implementations make identical
        decisions (same flows, same stream) on the same seeds."""
        s = TABLE_V[5]
        for seed in range(2):
            net_b, cost_b = build_setting(s, seed)
            net_s, cost_s = build_setting(s, seed)
            batched = GWTFProtocol(net_b, cost_matrix=cost_b,
                                   rng=np.random.default_rng(seed))
            strict = GWTFProtocol(net_s, cost_matrix=cost_s, strict_rng=True,
                                  rng=np.random.default_rng(seed))
            batched.run(max_rounds=100)
            strict.run(max_rounds=100)
            assert batched.complete_flows() == strict.complete_flows()
            assert batched.rng.bit_generator.state == \
                strict.rng.bit_generator.state

    def test_advertisement_index_matches_scan(self):
        """_advertised() via the index == the reference's segment scan,
        for every (peer, data node) pair after convergence."""
        s = TABLE_V[5]
        net_o, cost_o = build_setting(s, 1)
        net_r, cost_r = build_setting(s, 1)
        opt = GWTFProtocol(net_o, cost_matrix=cost_o,
                           rng=np.random.default_rng(2))
        ref = ReferenceGWTFProtocol(net_r, cost_matrix=cost_r,
                                    rng=np.random.default_rng(2))
        opt.run(40, quiet_rounds=3)
        ref.run(40, quiet_rounds=3)
        for j in opt.protos:
            for dn in opt._data_ids:
                assert opt._advertised(j, dn) == ref._advertised(j, dn)


class TestRefinementRegression:
    def test_refinement_improves_post_convergence_cost(self):
        """Regression for the step_round indentation bug: with Request
        Change / Redirect running per relay (not per data node with a
        stale loop index), the converged cost must improve vs. a run
        with refinement disabled."""
        wins, total = 0, 0
        for seed in range(5):
            s = dict(sources=1, relays=48, stages=6, cap=(1, 3), cost=(1, 50))
            net_a, cost_a = build_setting(s, seed)
            net_b, cost_b = build_setting(s, seed)
            refined = GWTFProtocol(net_a, cost_matrix=cost_a, objective="sum",
                                   rng=np.random.default_rng(seed + 1))
            plain = GWTFProtocol(net_b, cost_matrix=cost_b, objective="sum",
                                 refine=False,
                                 rng=np.random.default_rng(seed + 1))
            refined.run(150)
            plain.run(150)
            if not (refined.complete_flows() and plain.complete_flows()):
                continue
            a = refined.total_cost() / len(refined.complete_flows())
            b = plain.total_cost() / len(plain.complete_flows())
            total += 1
            if a < b:
                wins += 1
        assert total >= 3, "not enough comparable runs"
        assert wins > total / 2, \
            f"refinement won only {wins}/{total} runs — annealed " \
            f"refinement is not engaging"


class TestAmortizedGrowth:
    def test_add_node_grows_geometrically(self):
        """Joins must not reallocate the matrices every time: growth
        count is O(log joins), and between growths the exposed matrices
        are views into one buffer."""
        net = geo_distributed_network(
            num_stages=2, relay_capacities=[1, 1, 1, 1],
            rng=np.random.default_rng(0))
        n0 = len(net.nodes)
        joins = 100
        for k in range(joins):
            nid = n0 + k
            net.add_node(Node(nid, k % 2, 1, 1.0))
        assert net.matrix_grow_count <= int(np.ceil(np.log2(joins))) + 1
        assert net.latency.shape == (n0 + joins, n0 + joins)
        assert net.latency.base is net._lat_buf
        # another join inside remaining capacity must not reallocate
        before = net.matrix_grow_count
        buf = net._lat_buf
        net.add_node(Node(n0 + joins, 0, 1, 1.0))
        assert net.matrix_grow_count == before
        assert net._lat_buf is buf

    def test_add_node_preserves_rows_and_defaults(self):
        net = geo_distributed_network(
            num_stages=2, relay_capacities=[1, 1],
            rng=np.random.default_rng(0))
        old_lat = net.latency.copy()
        n = len(net.nodes)
        row = np.full(n, 0.123)
        col = np.full(n, 0.456)
        net.add_node(Node(n, 0, 1, 1.0), latency_row=row, latency_col=col)
        np.testing.assert_array_equal(net.latency[:n, :n], old_lat)
        np.testing.assert_array_equal(net.latency[n, :n], row)
        np.testing.assert_array_equal(net.latency[:n, n], col)
        # unspecified bandwidth row/col fall back to the join default
        from repro.core.flow.graph import DEFAULT_JOIN_BANDWIDTH
        assert float(net.bandwidth[n, 0]) == DEFAULT_JOIN_BANDWIDTH


class TestCostMatrixCache:
    def test_cache_hit_and_exactness(self):
        net = geo_distributed_network(
            num_stages=2, relay_capacities=[1, 1, 1, 1],
            rng=np.random.default_rng(1))
        cm = net.cost_matrix()
        assert net.cost_matrix() is cm          # cached object
        # cached entries are bit-identical to the scalar Eq. 1 evaluation
        for i in net.nodes:
            for j in net.nodes:
                ni, nj = net.nodes[i], net.nodes[j]
                comp = 0.5 * (ni.compute_cost + nj.compute_cost)
                lat = 0.5 * (net.latency[i, j] + net.latency[j, i])
                bw = net.bandwidth[i, j] + net.bandwidth[j, i]
                direct = comp + lat + 2.0 * net.activation_size / bw
                assert float(cm[i, j]) == float(direct)
                assert net.edge_cost(i, j) == float(direct)

    def test_cache_invalidated_on_join(self):
        net = geo_distributed_network(
            num_stages=2, relay_capacities=[1, 1, 1, 1],
            rng=np.random.default_rng(1))
        cm = net.cost_matrix()
        n = len(net.nodes)
        net.add_node(Node(n, 0, 1, 2.5))
        cm2 = net.cost_matrix()
        assert cm2 is not cm
        assert cm2.shape == (n + 1, n + 1)
        assert net.edge_cost(n, 0) == float(cm2[n, 0])

    def test_cache_invalidated_on_matrix_rebind(self):
        """bench_node_addition rebinds net.latency wholesale; the cache
        must notice."""
        net = geo_distributed_network(
            num_stages=2, relay_capacities=[1, 1],
            rng=np.random.default_rng(1))
        before = net.edge_cost(0, 1)
        net.latency = net.latency + 1.0
        after = net.edge_cost(0, 1)
        assert after == pytest.approx(before + 1.0)

    def test_rebound_matrix_survives_subsequent_join(self):
        """Regression: a wholesale latency rebind must not be shadowed by
        the stale growth buffer on the next add_node."""
        net = geo_distributed_network(
            num_stages=2, relay_capacities=[1, 1],
            rng=np.random.default_rng(1))
        n = len(net.nodes)
        net.add_node(Node(n, 0, 1, 1.0))      # buffers adopted, spare cap
        net.latency = net.latency + 1.0       # external rebind
        bumped = net.edge_cost(0, 1)
        net.add_node(Node(n + 1, 1, 1, 1.0))  # join after the rebind
        assert net.edge_cost(0, 1) == bumped
        # and yet another join still sees the rebound values
        net.add_node(Node(n + 2, 0, 1, 1.0))
        assert net.edge_cost(0, 1) == bumped

    def test_death_keeps_costs_valid(self):
        """kill_node changes membership, not link costs: the cache stays
        valid and queries against the dead node still resolve."""
        net = geo_distributed_network(
            num_stages=2, relay_capacities=[1, 1, 1, 1],
            rng=np.random.default_rng(1))
        cm = net.cost_matrix()
        c01 = net.edge_cost(0, 1)
        net.kill_node(3)
        assert not net.nodes[3].alive
        assert net.cost_matrix() is cm
        assert net.edge_cost(0, 1) == c01
        assert all(n.id != 3 for n in net.stage_nodes(net.nodes[3].stage))
