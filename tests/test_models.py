"""Model-layer unit + property tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.moe import apply_moe, init_moe


def mini_cfg(**kw):
    base = dict(name="t", arch_type="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                vocab_size=97, param_dtype="float32", remat=False)
    base.update(kw)
    return ModelConfig(**base)


class TestRoPE:
    def test_norm_preserved(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 16))
        y = L.apply_rope(x, jnp.arange(8), 10000.0)
        np.testing.assert_allclose(np.linalg.norm(np.asarray(x)),
                                   np.linalg.norm(np.asarray(y)), rtol=1e-5)

    def test_relative_property(self):
        """<rope(q,m), rope(k,n)> depends only on m-n."""
        key = jax.random.PRNGKey(1)
        q = jax.random.normal(key, (1, 1, 1, 16))
        k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 16))

        def dot_at(m, n):
            qm = L.apply_rope(q, jnp.array([m]), 10000.0)
            kn = L.apply_rope(k, jnp.array([n]), 10000.0)
            return float(jnp.sum(qm * kn))

        assert abs(dot_at(5, 3) - dot_at(10, 8)) < 1e-4
        assert abs(dot_at(0, 0) - dot_at(7, 7)) < 1e-4


class TestChunkedLoss:
    def test_matches_naive(self):
        cfg = mini_cfg(vocab_size=64)
        key = jax.random.PRNGKey(0)
        p = L.init_embed(key, cfg, jnp.float32)
        x = jax.random.normal(key, (2, 16, cfg.d_model))
        labels = jax.random.randint(key, (2, 16), 0, 64)
        loss = L.chunked_xent_loss(p, x, labels, cfg, chunk=4)
        logits = L.lm_logits(p, x, cfg)
        logp = jax.nn.log_softmax(logits, -1)
        naive = -jnp.mean(jnp.take_along_axis(logp, labels[..., None],
                                              -1))
        np.testing.assert_allclose(float(loss), float(naive), rtol=1e-5)

    def test_chunk_sizes_agree(self):
        cfg = mini_cfg(vocab_size=50)
        key = jax.random.PRNGKey(3)
        p = L.init_embed(key, cfg, jnp.float32)
        x = jax.random.normal(key, (1, 24, cfg.d_model))
        labels = jax.random.randint(key, (1, 24), 0, 50)
        ref = L.chunked_xent_loss(p, x, labels, cfg, chunk=24)
        for c in (4, 6, 12):
            got = L.chunked_xent_loss(p, x, labels, cfg, chunk=c)
            np.testing.assert_allclose(float(got), float(ref), rtol=1e-5)


class TestOnlineAttention:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 1000), window=st.sampled_from([None, 4, 8]))
    def test_property_matches_naive(self, seed, window):
        key = jax.random.PRNGKey(seed)
        B, S, H, hd = 1, 16, 2, 8
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, S, H, hd))
        k = jax.random.normal(ks[1], (B, S, H, hd))
        v = jax.random.normal(ks[2], (B, S, H, hd))
        out = L._online_attention(q, k, v, q_offset=0, causal=True,
                                  window=window, q_block=4)
        # naive
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k) * hd ** -0.5
        qpos = jnp.arange(S)[:, None]
        kpos = jnp.arange(S)[None, :]
        mask = kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask[None, None], s, -1e30)
        ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


class TestMoEImpls:
    @pytest.mark.parametrize("arch", ["granite-moe-3b-a800m",
                                      "qwen2-moe-a2.7b"])
    def test_capacity_matches_dense(self, arch):
        cfg = get_config(arch).reduced()
        key = jax.random.PRNGKey(0)
        p = init_moe(key, cfg, jnp.float32)
        x = jax.random.normal(key, (2, 32, cfg.d_model))
        out_d, aux_d = apply_moe(p, x, cfg, impl="dense")
        out_c, aux_c = apply_moe(p, x, cfg, impl="capacity")
        np.testing.assert_allclose(np.asarray(out_d), np.asarray(out_c),
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(float(aux_d), float(aux_c), rtol=1e-5)

    def test_ragged_matches_dense(self):
        cfg = get_config("granite-moe-3b-a800m").reduced()
        key = jax.random.PRNGKey(1)
        p = init_moe(key, cfg, jnp.float32)
        x = jax.random.normal(key, (1, 16, cfg.d_model))
        out_d, _ = apply_moe(p, x, cfg, impl="dense")
        out_r, _ = apply_moe(p, x, cfg, impl="ragged")
        np.testing.assert_allclose(np.asarray(out_d), np.asarray(out_r),
                                   rtol=1e-3, atol=1e-3)

    def test_router_aux_loss_balanced_is_low(self):
        """A perfectly uniform router gives aux ~ E * E*(1/E)*(1/E) = 1
        (x k for top-k overcounting of frac)."""
        cfg = get_config("granite-moe-3b-a800m").reduced()
        key = jax.random.PRNGKey(2)
        p = init_moe(key, cfg, jnp.float32)
        x = jax.random.normal(key, (4, 64, cfg.d_model)) * 1e-4  # ~uniform
        _, aux = apply_moe(p, x, cfg)
        assert float(aux) < cfg.num_experts_per_tok * 1.5


class TestConfigReduction:
    @pytest.mark.parametrize("arch", ["gemma-7b", "hymba-1.5b",
                                      "qwen2-moe-a2.7b",
                                      "llama-3.2-vision-90b"])
    def test_reduced_invariants(self, arch):
        cfg = get_config(arch)
        r = cfg.reduced()
        assert r.arch_type == cfg.arch_type
        assert r.num_layers <= 4 and r.d_model <= 512
        assert r.num_experts <= 4
        if r.num_heads:
            assert r.num_heads % max(r.num_kv_heads, 1) == 0
            assert r.num_heads * r.head_dim <= 8 * r.d_model


class TestKernelIntegration:
    """The use_kernel=True path routes model attention through the Pallas
    flash kernel (interpret mode on CPU) — must match the jnp path."""

    def test_forward_with_kernel_matches(self):
        import numpy as np
        from repro.models.transformer import forward_hidden, init_params
        cfg = get_config("tinyllama-1.1b").reduced(num_layers=2,
                                                   d_model=128)
        key = jax.random.PRNGKey(0)
        params = init_params(cfg, key)
        toks = jax.random.randint(key, (1, 128), 0, cfg.vocab_size)
        h_ref, _, _ = forward_hidden(params, cfg, tokens=toks,
                                     use_kernel=False)
        h_ker, _, _ = forward_hidden(params, cfg, tokens=toks,
                                     use_kernel=True)
        np.testing.assert_allclose(np.asarray(h_ker), np.asarray(h_ref),
                                   rtol=2e-3, atol=2e-3)
