"""Per-architecture smoke tests: a REDUCED variant of each assigned family
(<= 2-4 layers, d_model <= 512, <= 4 experts) runs one forward/train step
and one decode step on CPU; output shapes asserted, losses/grads finite.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.transformer import (decode_step, init_cache, init_params,
                                      prefill, train_loss)

ASSIGNED = [a for a in ARCH_IDS if not a.startswith("gwtf_")]
B, S = 2, 32


def make_batch(cfg, key):
    batch = {"labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.audio_frontend:
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model))
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.arch_type == "vlm":
        batch["vision"] = jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.vision_dim))
    return batch


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_smoke(arch, key):
    cfg = get_config(arch).reduced()
    assert cfg.d_model <= 512 and cfg.num_layers <= 4
    assert cfg.num_experts <= 4
    params = init_params(cfg, key)
    batch = make_batch(cfg, key)
    loss, grads = jax.value_and_grad(train_loss)(params, batch, cfg)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_step_smoke(arch, key):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, key)
    cache = init_cache(cfg, B, 16, dtype=jnp.float32)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    vision = (jax.random.normal(key, (B, cfg.num_image_tokens, cfg.vision_dim))
              if cfg.arch_type == "vlm" else None)
    logits, new_cache = decode_step(params, cfg, tokens=tok, vision=vision,
                                    cache=cache, index=jnp.int32(0))
    assert logits.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-130m",
                                  "hymba-1.5b", "granite-moe-3b-a800m"])
def test_prefill_matches_decode(arch, key):
    """Prefill then forward() must agree: decoding token-by-token gives the
    same last-position logits as a single full forward."""
    cfg = get_config(arch).reduced()
    params = init_params(cfg, key)
    T = 8
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    # full forward logits at last position
    from repro.models import layers as L
    from repro.models.transformer import forward_hidden
    hidden, _, _ = forward_hidden(params, cfg, tokens=toks)
    full_logits = L.lm_logits(params["embed"], hidden[:, -1:], cfg)[:, 0]
    # prefill path
    cache = init_cache(cfg, B, T, dtype=jnp.float32)
    pre_logits, _ = prefill(params, cfg, tokens=toks, cache=cache)
    np.testing.assert_allclose(np.asarray(pre_logits),
                               np.asarray(full_logits), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-130m",
                                  "hymba-1.5b"])
def test_incremental_decode_matches_full(arch, key):
    """Token-by-token decoding reproduces the full-sequence forward."""
    cfg = get_config(arch).reduced()
    params = init_params(cfg, key)
    T = 8
    toks = jax.random.randint(key, (1, T), 0, cfg.vocab_size)
    from repro.models import layers as L
    from repro.models.transformer import forward_hidden
    hidden, _, _ = forward_hidden(params, cfg, tokens=toks)
    full_logits = L.lm_logits(params["embed"], hidden, cfg)  # (1, T, V)
    cache = init_cache(cfg, 1, T, dtype=jnp.float32)
    for t in range(T):
        logits, cache = decode_step(params, cfg, tokens=toks[:, t:t + 1],
                                    cache=cache, index=jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits[0]),
                                   np.asarray(full_logits[0, t]),
                                   rtol=5e-3, atol=5e-3)


def test_sliding_window_ring_buffer(key):
    """Ring-buffer decode (cache = W slots) matches a full forward pass
    with sliding-window masked attention at every position."""
    cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                              sliding_window=8)
    params = init_params(cfg, key)
    W, T = 8, 14
    toks = jax.random.randint(key, (1, T), 0, cfg.vocab_size)
    # reference: full sequence, window-masked attention
    from repro.models import layers as L
    from repro.models.transformer import forward_hidden
    hidden, _, _ = forward_hidden(params, cfg, tokens=toks, window=W)
    ref_logits = L.lm_logits(params["embed"], hidden, cfg)   # (1, T, V)
    # ring decode
    cache = init_cache(cfg, 1, W, dtype=jnp.float32)
    for t in range(T):
        logits, cache = decode_step(params, cfg, tokens=toks[:, t:t + 1],
                                    cache=cache, index=jnp.int32(t),
                                    window=W)
        np.testing.assert_allclose(np.asarray(logits[0]),
                                   np.asarray(ref_logits[0, t]),
                                   rtol=5e-3, atol=5e-3)


def test_head_padded_cache_matches_unpadded(key):
    """Hillclimb D: a kv-head-padded decode cache (even model-axis
    sharding) must be numerically identical to the unpadded layout."""
    cfg = get_config("qwen1.5-4b").reduced()
    params = init_params(cfg, key)
    T = 6
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    c1 = init_cache(cfg, B, T, dtype=jnp.float32)
    c2 = init_cache(cfg, B, T, dtype=jnp.float32,
                    kv_heads_override=cfg.num_kv_heads + 3)
    for t in range(T):
        l1, c1 = decode_step(params, cfg, tokens=toks[:, t:t + 1],
                             cache=c1, index=jnp.int32(t))
        l2, c2 = decode_step(params, cfg, tokens=toks[:, t:t + 1],
                             cache=c2, index=jnp.int32(t))
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-5, atol=1e-5)
    p1, _ = prefill(params, cfg, tokens=toks,
                    cache=init_cache(cfg, B, T, dtype=jnp.float32))
    p2, _ = prefill(params, cfg, tokens=toks,
                    cache=init_cache(cfg, B, T, dtype=jnp.float32,
                                     kv_heads_override=cfg.num_kv_heads + 3))
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2),
                               rtol=1e-5, atol=1e-5)
