"""Dirty-slot candidate-table maintenance (`flow/decentralized.py`).

The incremental planner patches its per-stage Request Redirect /
Request Change candidate tables in place at the slot positions touched
by each mutation; ``strict_rebuild=True`` keeps the pre-dirty-slot
behavior (a full epoch-keyed regather per mutated stage) as the
in-engine equality oracle.  These tests drive randomized mutation
sequences — refinement rounds, crashes, sink reclaims, rejoins —
through both modes in lock-step and assert:

* the candidate tables are identical after every mutation (same slot
  registry, same validity masks, same column values at every valid
  position);
* the protocol-level observables (flows, cost, temperature, RNG
  stream) never diverge;
* the whole engine stays bit-identical to the frozen
  `ReferenceGWTFProtocol` through a crash→repair→rejoin episode at
  500 relays (the scale regime the dirty-slot tables exist for).
"""
import numpy as np
import pytest

from repro.core.flow.decentralized import GWTFProtocol
from repro.core.flow.graph import synthetic_network
from repro.core.flow.reference import ReferenceGWTFProtocol


def build_net(seed, stages=4, relays_per_stage=5, sources=2,
              source_capacity=4):
    rng = np.random.default_rng(seed)
    return synthetic_network(
        num_stages=stages, relays_per_stage=relays_per_stage,
        capacities=lambda r: int(r.uniform(1, 4)),
        link_costs=lambda r: float(int(r.uniform(1, 20))),
        num_sources=sources, source_capacity=source_capacity, rng=rng)


def make_pair(seed, **kw):
    """The same scenario twice: dirty-slot mode vs strict_rebuild."""
    net_a, cm_a = build_net(seed, **kw)
    net_b, cm_b = build_net(seed, **kw)
    dirty = GWTFProtocol(net_a, cost_matrix=cm_a,
                         rng=np.random.default_rng(seed + 7))
    strict = GWTFProtocol(net_b, cost_matrix=cm_b, strict_rebuild=True,
                          rng=np.random.default_rng(seed + 7))
    return dirty, strict


def assert_tables_equal(dirty, strict, tag=""):
    """Both table queries agree per stage: identical slot registries
    and validity masks, identical column values wherever valid (rows
    with ``valid == False`` carry unspecified values by contract)."""
    for stage in range(dirty.net.num_stages):
        for query in ("_redirect_cands", "_change_cands"):
            ta = getattr(dirty, query)(stage)
            tb = getattr(strict, query)(stage)
            where = f"{tag} stage {stage} {query}"
            np.testing.assert_array_equal(ta[0], tb[0],
                                          err_msg=f"{where}: slots")
            np.testing.assert_array_equal(ta[6], tb[6],
                                          err_msg=f"{where}: valid mask")
            v = np.asarray(tb[6], bool)
            for col in range(1, 6):
                np.testing.assert_array_equal(
                    np.asarray(ta[col])[v], np.asarray(tb[col])[v],
                    err_msg=f"{where}: column {col}")


def assert_protocols_equal(dirty, strict, tag=""):
    assert dirty.complete_flows() == strict.complete_flows(), \
        f"{tag}: flows diverged"
    assert dirty.total_cost() == strict.total_cost(), f"{tag}: cost"
    assert dirty.T == strict.T, f"{tag}: temperature"
    assert dirty.rng.bit_generator.state == \
        strict.rng.bit_generator.state, f"{tag}: RNG stream"


class TestDirtySlotTables:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_randomized_mutation_sequences(self, seed):
        """~12 random operations (refinement bursts, relay crashes,
        rejoins, sink reclaims) applied to both modes in lock-step:
        tables and observables must stay identical throughout."""
        dirty, strict = make_pair(seed)
        ops = np.random.default_rng([seed, 99])   # op stream only —
        # never the protocols' RNG, so both consume identical draws
        dirty.run(max_rounds=40, quiet_rounds=5)
        strict.run(max_rounds=40, quiet_rounds=5)
        assert_tables_equal(dirty, strict, f"seed {seed} warmup")
        dead = []
        for step in range(12):
            relays = [n.id for n in dirty.net.nodes.values()
                      if not n.is_data]
            alive = [nid for nid in relays if dirty.net.nodes[nid].alive]
            op = ops.integers(0, 4)
            if op == 0 and len(alive) > dirty.net.num_stages:
                victim = int(ops.choice(alive))
                for p in (dirty, strict):
                    p.net.kill_node(victim)
                    p.remove_node(victim)
                dead.append(victim)
            elif op == 1 and dead:
                back = dead.pop(int(ops.integers(0, len(dead))))
                for p in (dirty, strict):
                    p.net.nodes[back].alive = True
                    p.add_node(p.net.nodes[back])
            elif op == 2:
                for p in (dirty, strict):
                    p.reclaim_sink_slots()
            else:
                rounds = int(ops.integers(3, 15))
                for p in (dirty, strict):
                    p.run(max_rounds=rounds, quiet_rounds=2)
            tag = f"seed {seed} step {step} op {op}"
            assert_tables_equal(dirty, strict, tag)
            assert_protocols_equal(dirty, strict, tag)
        # close out: repair to quiescence and re-check end state
        for p in (dirty, strict):
            p.reclaim_sink_slots()
            p.run(max_rounds=60, quiet_rounds=5)
        assert_tables_equal(dirty, strict, f"seed {seed} final")
        assert_protocols_equal(dirty, strict, f"seed {seed} final")
        assert len(dirty.complete_flows()) > 0

    def test_cost_matrix_refresh_invalidates_tables(self):
        """A cost-epoch move (wholesale ``net.latency`` rebind, as
        bench_node_addition does) is one of the three full-rebuild
        triggers: the dirty mode's cached edge costs must not go
        stale."""
        from repro.core.flow.graph import geo_distributed_network

        def build(seed=11):
            return geo_distributed_network(
                num_stages=3, relay_capacities=[2] * 9,
                num_data_nodes=1, data_capacity=3,
                rng=np.random.default_rng(seed))

        dirty = GWTFProtocol(build(), rng=np.random.default_rng(4))
        strict = GWTFProtocol(build(), strict_rebuild=True,
                              rng=np.random.default_rng(4))
        for p in (dirty, strict):
            p.run(max_rounds=40, quiet_rounds=5)
        assert_tables_equal(dirty, strict, "pre-rebind")
        for p in (dirty, strict):
            p.net.latency = p.net.latency * 3.0 + 1.0   # cost epoch moves
            p.reclaim_sink_slots()
            p.run(max_rounds=30, quiet_rounds=3)
        assert_tables_equal(dirty, strict, "post-rebind")
        assert_protocols_equal(dirty, strict, "post-rebind")


class TestScaleBitEquality:
    def test_500_relay_crash_repair_rejoin_vs_reference(self):
        """The full engine (dirty-slot tables on) stays bit-identical
        to the frozen reference through crash → repair → rejoin at 500
        relays — the regime the incremental tables were built for."""
        seed = 5
        net_o, cm_o = build_net(seed, stages=10, relays_per_stage=50,
                                sources=2, source_capacity=25)
        net_r, cm_r = build_net(seed, stages=10, relays_per_stage=50,
                                sources=2, source_capacity=25)
        opt = GWTFProtocol(net_o, cost_matrix=cm_o,
                           rng=np.random.default_rng(seed + 3))
        ref = ReferenceGWTFProtocol(net_r, cost_matrix=cm_r,
                                    rng=np.random.default_rng(seed + 3))
        opt.run(max_rounds=60, quiet_rounds=10)
        ref.run(max_rounds=60, quiet_rounds=10)
        flows = ref.complete_flows()
        assert opt.complete_flows() == flows and len(flows) > 0
        victims = sorted({flows[0][1], flows[-1][2], flows[-1][1]})
        for p, n in ((opt, net_o), (ref, net_r)):
            for v in victims:
                n.kill_node(v)
                p.remove_node(v)
            p.reclaim_sink_slots()
            p.run(max_rounds=30, quiet_rounds=5)
        assert opt.complete_flows() == ref.complete_flows(), "post-crash"
        assert opt.total_cost() == ref.total_cost()
        assert opt.rng.bit_generator.state == ref.rng.bit_generator.state
        for p, n in ((opt, net_o), (ref, net_r)):
            for v in victims:
                n.nodes[v].alive = True
                p.add_node(n.nodes[v])
            p.reclaim_sink_slots()
            p.run(max_rounds=30, quiet_rounds=5)
        assert opt.complete_flows() == ref.complete_flows(), "post-rejoin"
        assert opt.total_cost() == ref.total_cost()
        assert opt.rng.bit_generator.state == ref.rng.bit_generator.state
