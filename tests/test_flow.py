"""Unit + property tests for the GWTF flow layer (paper Sec. V-A/V-C)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.flow.decentralized import GWTFProtocol
from repro.core.flow.graph import FlowNetwork, Node, synthetic_network
from repro.core.flow.mincost import MinCostFlow, solve_training_flow


def build(seed=0, stages=4, relays=4, cap_lo=1, cap_hi=3, sources=1,
          source_cap=4, cost_hi=20.0):
    rng = np.random.default_rng(seed)
    return synthetic_network(
        num_stages=stages, relays_per_stage=relays,
        capacities=lambda r: int(r.uniform(cap_lo, cap_hi + 1)),
        link_costs=lambda r: float(int(r.uniform(1, cost_hi))),
        num_sources=sources, source_capacity=source_cap, rng=rng)


# ---------------------------------------------------------------------------
# Min-cost-flow oracle
# ---------------------------------------------------------------------------

class TestMinCostFlow:
    def test_simple_path(self):
        mc = MinCostFlow(3)
        mc.add_edge(0, 1, 2, 1.0)
        mc.add_edge(1, 2, 2, 1.0)
        flow, cost = mc.solve(0, 2)
        assert flow == 2 and cost == 4.0

    def test_chooses_cheap_path(self):
        mc = MinCostFlow(4)
        mc.add_edge(0, 1, 1, 10.0)
        mc.add_edge(0, 2, 1, 1.0)
        mc.add_edge(1, 3, 1, 1.0)
        mc.add_edge(2, 3, 1, 1.0)
        flow, cost = mc.solve(0, 3)
        assert flow == 2 and cost == 13.0

    def test_capacity_bound(self):
        mc = MinCostFlow(2)
        mc.add_edge(0, 1, 3, 2.0)
        flow, cost = mc.solve(0, 1, max_flow=10)
        assert flow == 3

    def test_training_graph_flow_bounded_by_stage_capacity(self):
        net, cost = build(seed=3, cap_lo=1, cap_hi=2, source_cap=50)
        plan = solve_training_flow(net, cost_matrix=cost)
        min_stage = min(net.stage_capacity(s) for s in range(net.num_stages))
        assert plan.flow <= min_stage

    def test_add_edges_matches_scalar_add_edge(self):
        """Batched arc appends produce the identical arc table (ids,
        reverse pairing, caps, costs) as the scalar loop."""
        us = [0, 0, 1, 2]
        vs = [1, 2, 3, 3]
        caps = [1.0, 2.0, 3.0, 4.0]
        costs = [5.0, 6.0, -7.0, 8.0]
        a = MinCostFlow(4)
        for u, v, c, w in zip(us, vs, caps, costs):
            a.add_edge(u, v, c, w)
        b = MinCostFlow(4)
        fwd = b.add_edges(us, vs, caps, costs)
        assert fwd.tolist() == [0, 2, 4, 6]
        assert a.to.tolist() == b.to.tolist()
        assert a.cap.tolist() == b.cap.tolist()
        assert a.cost.tolist() == b.cost.tolist()
        assert a.graph == b.graph


class TestDialQueueMCMF:
    """The integer-cost bucket-queue core must compute the exact same
    optimum as the dense masked-argmin core."""

    @staticmethod
    def _random_graph(seed):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(4, 14))
        edges = []
        for _ in range(int(rng.integers(6, 40))):
            u, v = (int(x) for x in rng.integers(0, n, 2))
            if u == v:
                continue
            edges.append((u, v, float(rng.integers(1, 6)),
                          float(rng.integers(0, 12))))
        return n, edges

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 100_000))
    def test_cost_optimality_equals_dense_on_random_graphs(self, seed):
        n, edges = self._random_graph(seed)
        dial = MinCostFlow(n)
        dense = MinCostFlow(n)
        for u, v, c, w in edges:
            dial.add_edge(u, v, c, w)
            dense.add_edge(u, v, c, w)
        f1, c1 = dial.solve(0, n - 1, method="dial")
        f2, c2 = dense.solve(0, n - 1, method="dense")
        assert f1 == f2
        assert c1 == pytest.approx(c2, abs=1e-9)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 100_000), cap=st.integers(1, 8))
    def test_max_flow_cap_respected(self, seed, cap):
        n, edges = self._random_graph(seed)
        dial = MinCostFlow(n)
        dense = MinCostFlow(n)
        for u, v, c, w in edges:
            dial.add_edge(u, v, c, w)
            dense.add_edge(u, v, c, w)
        f1, c1 = dial.solve(0, n - 1, max_flow=cap, method="dial")
        f2, c2 = dense.solve(0, n - 1, max_flow=cap, method="dense")
        assert f1 == f2 <= cap
        assert c1 == pytest.approx(c2, abs=1e-9)

    def test_auto_selects_dense_for_fractional_costs(self):
        """Non-integer costs: auto must fall back to the dense core
        (and produce its exact result); forcing dial raises."""
        def build_mc():
            mc = MinCostFlow(4)
            mc.add_edge(0, 1, 1, 0.5)
            mc.add_edge(0, 2, 1, 1.25)
            mc.add_edge(1, 3, 1, 0.75)
            mc.add_edge(2, 3, 1, 0.25)
            return mc
        auto = build_mc()
        dense = build_mc()
        fa, ca = auto.solve(0, 3)            # method="auto"
        fd, cd = dense.solve(0, 3, method="dense")
        assert (fa, ca) == (fd, cd)
        with pytest.raises(ValueError):
            build_mc().solve(0, 3, method="dial")

    def test_training_flow_dial_matches_dense(self):
        """End-to-end: the layered training graph (integer d_ij) solved
        by both cores yields the identical (flow, cost) optimum."""
        net, cost = build(seed=7, stages=5, relays=5, source_cap=8)
        p_auto = solve_training_flow(net, cost_matrix=cost)
        net2, cost2 = build(seed=7, stages=5, relays=5, source_cap=8)
        p_dense = solve_training_flow(net2, cost_matrix=cost2,
                                      method="dense")
        assert p_auto.flow == p_dense.flow
        assert p_auto.cost == pytest.approx(p_dense.cost, abs=1e-9)


# ---------------------------------------------------------------------------
# Decentralized protocol
# ---------------------------------------------------------------------------

class TestGWTFProtocol:
    def test_builds_max_flows(self):
        net, cost = build(seed=42, source_cap=4)
        proto = GWTFProtocol(net, cost_matrix=cost,
                             rng=np.random.default_rng(1))
        proto.run(max_rounds=150)
        flows = proto.complete_flows()
        min_stage = min(net.stage_capacity(s) for s in range(net.num_stages))
        assert len(flows) == min(4, min_stage)

    def test_flows_are_valid_chains(self):
        net, cost = build(seed=7, source_cap=4)
        proto = GWTFProtocol(net, cost_matrix=cost,
                             rng=np.random.default_rng(2))
        proto.run(max_rounds=150)
        for chain in proto.complete_flows():
            assert chain[0] == chain[-1]               # returns to origin
            assert net.nodes[chain[0]].is_data
            relays = chain[1:-1]
            assert len(relays) == net.num_stages
            for s, nid in enumerate(relays):
                assert net.nodes[nid].stage == s       # stage order

    def test_capacity_never_exceeded(self):
        net, cost = build(seed=11, source_cap=8, cap_lo=1, cap_hi=2)
        proto = GWTFProtocol(net, cost_matrix=cost,
                             rng=np.random.default_rng(3))
        proto.run(max_rounds=150)
        for p in proto.protos.values():
            assert p.used <= p.capacity

    def test_near_optimal_cost(self):
        """Paper: GWTF is never more than 25% worse than the optimum."""
        ratios = []
        for seed in range(5):
            net, cost = build(seed=seed, stages=6, relays=5, source_cap=4)
            proto = GWTFProtocol(net, cost_matrix=cost, objective="sum",
                                 rng=np.random.default_rng(seed + 100))
            proto.run(max_rounds=200)
            opt = solve_training_flow(net, cost_matrix=cost,
                                      max_flow=len(proto.complete_flows()))
            if opt.flow and proto.complete_flows():
                ratios.append(proto.total_cost() / max(opt.cost, 1e-9))
        assert ratios, "no comparable runs"
        assert np.mean(ratios) < 1.5, ratios

    def test_crash_recovery_rebuilds_flows(self):
        net, cost = build(seed=5, relays=5, cap_lo=2, cap_hi=3, source_cap=4)
        proto = GWTFProtocol(net, cost_matrix=cost,
                             rng=np.random.default_rng(4))
        proto.run(max_rounds=150)
        before = len(proto.complete_flows())
        assert before > 0
        # crash one relay on a flow
        victim = proto.complete_flows()[0][2]
        net.nodes[victim].alive = False
        proto.remove_node(victim)
        proto.reclaim_sink_slots()
        proto.run(max_rounds=60)
        after = len(proto.complete_flows())
        min_stage = min(net.stage_capacity(s) for s in range(net.num_stages))
        assert after >= min(before, min_stage, 4) - 1
        # no flow touches the dead node
        for chain in proto.complete_flows():
            assert victim not in chain

    def test_annealing_temperature_decays(self):
        net, cost = build(seed=9)
        proto = GWTFProtocol(net, cost_matrix=cost, temperature=1.7,
                             alpha=0.95, rng=np.random.default_rng(5))
        proto.run(max_rounds=100)
        assert proto.T <= 1.7


# ---------------------------------------------------------------------------
# Property-based invariants
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), stages=st.integers(2, 6),
       relays=st.integers(2, 5), source_cap=st.integers(1, 6))
def test_property_protocol_invariants(seed, stages, relays, source_cap):
    """For any topology: capacities respected, chains well-formed, cost
    of every complete flow equals the sum of its edge costs."""
    net, cost = build(seed=seed, stages=stages, relays=relays,
                      source_cap=source_cap)
    proto = GWTFProtocol(net, cost_matrix=cost,
                         rng=np.random.default_rng(seed + 1))
    proto.run(max_rounds=120)
    for p in proto.protos.values():
        assert p.used <= p.capacity
    flows = proto.complete_flows()
    min_stage = min(net.stage_capacity(s) for s in range(net.num_stages))
    assert len(flows) <= min(source_cap, min_stage)
    for chain, c in zip(flows, proto.flow_costs()):
        manual = sum(cost[chain[i], chain[i + 1]]
                     for i in range(len(chain) - 1))
        assert abs(manual - c) < 1e-6


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_protocol_never_beats_optimal(seed):
    """Decentralized cost >= centralized optimum at the same flow value."""
    net, cost = build(seed=seed, stages=3, relays=3, source_cap=3)
    proto = GWTFProtocol(net, cost_matrix=cost, objective="sum",
                         rng=np.random.default_rng(seed + 7))
    proto.run(max_rounds=120)
    k = len(proto.complete_flows())
    if k == 0:
        return
    opt = solve_training_flow(net, cost_matrix=cost, max_flow=k)
    assert proto.total_cost() >= opt.cost - 1e-6
