"""Unit + property tests for the GWTF flow layer (paper Sec. V-A/V-C)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.flow.decentralized import GWTFProtocol
from repro.core.flow.graph import FlowNetwork, Node, synthetic_network
from repro.core.flow.mincost import MinCostFlow, solve_training_flow


def build(seed=0, stages=4, relays=4, cap_lo=1, cap_hi=3, sources=1,
          source_cap=4, cost_hi=20.0):
    rng = np.random.default_rng(seed)
    return synthetic_network(
        num_stages=stages, relays_per_stage=relays,
        capacities=lambda r: int(r.uniform(cap_lo, cap_hi + 1)),
        link_costs=lambda r: float(int(r.uniform(1, cost_hi))),
        num_sources=sources, source_capacity=source_cap, rng=rng)


# ---------------------------------------------------------------------------
# Min-cost-flow oracle
# ---------------------------------------------------------------------------

class TestMinCostFlow:
    def test_simple_path(self):
        mc = MinCostFlow(3)
        mc.add_edge(0, 1, 2, 1.0)
        mc.add_edge(1, 2, 2, 1.0)
        flow, cost = mc.solve(0, 2)
        assert flow == 2 and cost == 4.0

    def test_chooses_cheap_path(self):
        mc = MinCostFlow(4)
        mc.add_edge(0, 1, 1, 10.0)
        mc.add_edge(0, 2, 1, 1.0)
        mc.add_edge(1, 3, 1, 1.0)
        mc.add_edge(2, 3, 1, 1.0)
        flow, cost = mc.solve(0, 3)
        assert flow == 2 and cost == 13.0

    def test_capacity_bound(self):
        mc = MinCostFlow(2)
        mc.add_edge(0, 1, 3, 2.0)
        flow, cost = mc.solve(0, 1, max_flow=10)
        assert flow == 3

    def test_training_graph_flow_bounded_by_stage_capacity(self):
        net, cost = build(seed=3, cap_lo=1, cap_hi=2, source_cap=50)
        plan = solve_training_flow(net, cost_matrix=cost)
        min_stage = min(net.stage_capacity(s) for s in range(net.num_stages))
        assert plan.flow <= min_stage


# ---------------------------------------------------------------------------
# Decentralized protocol
# ---------------------------------------------------------------------------

class TestGWTFProtocol:
    def test_builds_max_flows(self):
        net, cost = build(seed=42, source_cap=4)
        proto = GWTFProtocol(net, cost_matrix=cost,
                             rng=np.random.default_rng(1))
        proto.run(max_rounds=150)
        flows = proto.complete_flows()
        min_stage = min(net.stage_capacity(s) for s in range(net.num_stages))
        assert len(flows) == min(4, min_stage)

    def test_flows_are_valid_chains(self):
        net, cost = build(seed=7, source_cap=4)
        proto = GWTFProtocol(net, cost_matrix=cost,
                             rng=np.random.default_rng(2))
        proto.run(max_rounds=150)
        for chain in proto.complete_flows():
            assert chain[0] == chain[-1]               # returns to origin
            assert net.nodes[chain[0]].is_data
            relays = chain[1:-1]
            assert len(relays) == net.num_stages
            for s, nid in enumerate(relays):
                assert net.nodes[nid].stage == s       # stage order

    def test_capacity_never_exceeded(self):
        net, cost = build(seed=11, source_cap=8, cap_lo=1, cap_hi=2)
        proto = GWTFProtocol(net, cost_matrix=cost,
                             rng=np.random.default_rng(3))
        proto.run(max_rounds=150)
        for p in proto.protos.values():
            assert p.used <= p.capacity

    def test_near_optimal_cost(self):
        """Paper: GWTF is never more than 25% worse than the optimum."""
        ratios = []
        for seed in range(5):
            net, cost = build(seed=seed, stages=6, relays=5, source_cap=4)
            proto = GWTFProtocol(net, cost_matrix=cost, objective="sum",
                                 rng=np.random.default_rng(seed + 100))
            proto.run(max_rounds=200)
            opt = solve_training_flow(net, cost_matrix=cost,
                                      max_flow=len(proto.complete_flows()))
            if opt.flow and proto.complete_flows():
                ratios.append(proto.total_cost() / max(opt.cost, 1e-9))
        assert ratios, "no comparable runs"
        assert np.mean(ratios) < 1.5, ratios

    def test_crash_recovery_rebuilds_flows(self):
        net, cost = build(seed=5, relays=5, cap_lo=2, cap_hi=3, source_cap=4)
        proto = GWTFProtocol(net, cost_matrix=cost,
                             rng=np.random.default_rng(4))
        proto.run(max_rounds=150)
        before = len(proto.complete_flows())
        assert before > 0
        # crash one relay on a flow
        victim = proto.complete_flows()[0][2]
        net.nodes[victim].alive = False
        proto.remove_node(victim)
        proto.reclaim_sink_slots()
        proto.run(max_rounds=60)
        after = len(proto.complete_flows())
        min_stage = min(net.stage_capacity(s) for s in range(net.num_stages))
        assert after >= min(before, min_stage, 4) - 1
        # no flow touches the dead node
        for chain in proto.complete_flows():
            assert victim not in chain

    def test_annealing_temperature_decays(self):
        net, cost = build(seed=9)
        proto = GWTFProtocol(net, cost_matrix=cost, temperature=1.7,
                             alpha=0.95, rng=np.random.default_rng(5))
        proto.run(max_rounds=100)
        assert proto.T <= 1.7


# ---------------------------------------------------------------------------
# Property-based invariants
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), stages=st.integers(2, 6),
       relays=st.integers(2, 5), source_cap=st.integers(1, 6))
def test_property_protocol_invariants(seed, stages, relays, source_cap):
    """For any topology: capacities respected, chains well-formed, cost
    of every complete flow equals the sum of its edge costs."""
    net, cost = build(seed=seed, stages=stages, relays=relays,
                      source_cap=source_cap)
    proto = GWTFProtocol(net, cost_matrix=cost,
                         rng=np.random.default_rng(seed + 1))
    proto.run(max_rounds=120)
    for p in proto.protos.values():
        assert p.used <= p.capacity
    flows = proto.complete_flows()
    min_stage = min(net.stage_capacity(s) for s in range(net.num_stages))
    assert len(flows) <= min(source_cap, min_stage)
    for chain, c in zip(flows, proto.flow_costs()):
        manual = sum(cost[chain[i], chain[i + 1]]
                     for i in range(len(chain) - 1))
        assert abs(manual - c) < 1e-6


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_protocol_never_beats_optimal(seed):
    """Decentralized cost >= centralized optimum at the same flow value."""
    net, cost = build(seed=seed, stages=3, relays=3, source_cap=3)
    proto = GWTFProtocol(net, cost_matrix=cost, objective="sum",
                         rng=np.random.default_rng(seed + 7))
    proto.run(max_rounds=120)
    k = len(proto.complete_flows())
    if k == 0:
        return
    opt = solve_training_flow(net, cost_matrix=cost, max_flow=k)
    assert proto.total_cost() >= opt.cost - 1e-6
