"""Layered simulation engine: policies, churn models, event accounting.

Complements tests/test_simulator.py (which pins the drop-in facade on
the pre-refactor surface): deterministic wasted-GPU accounting on the
`fixed` scheduler and the SWARM full-pipeline-recompute branch, the
trace/regional churn models, max_events truncation surfacing, and
engine-vs-reference equivalence.
"""
import warnings

import numpy as np
import pytest

from repro.core.flow.graph import geo_distributed_network
from repro.core.sim import (ComposedChurn, RegionalOutageChurn, TraceChurn,
                            TrainingSimulator, summarize)
from repro.core.sim.policies import make_policy
from repro.core.sim.reference import ReferenceTrainingSimulator

COMPUTE = 2.0   # deterministic per-relay forward seconds (jitter 0)


def tiny_net(seed=0, *, stages=2, relays_per_stage=1, data_capacity=1):
    """Fully deterministic compute costs; 1 data node."""
    return geo_distributed_network(
        num_stages=stages,
        relay_capacities=[3] * (stages * relays_per_stage),
        num_data_nodes=1, data_capacity=data_capacity,
        compute_cost=COMPUTE, compute_jitter=0.0,
        rng=np.random.default_rng(seed))


def crash_window(net, path):
    """(fwd done at path[1], bwd arrival at path[1]) for a 2-stage path
    [data, a, b, data] with no contention — from Eq. 1 comm costs."""
    dn, a, b = path[0], path[1], path[2]
    c1, c2, c3 = (net.comm_cost(dn, a), net.comm_cost(a, b),
                  net.comm_cost(b, dn))
    fwd_done_a = c1 + COMPUTE
    # a->b, b fwd, b->data (loss), data->b, b bwd, b->a
    bwd_arrive_a = fwd_done_a + c2 + COMPUTE + 2 * c3 + 2 * COMPUTE + c2
    return fwd_done_a, bwd_arrive_a


class TestFixedScheduler:
    def test_no_churn_completes_cleanly(self):
        net = tiny_net(stages=2, relays_per_stage=2)
        a = net.stage_nodes(0)[0].id
        b = net.stage_nodes(1)[0].id
        sim = TrainingSimulator(net, scheduler="fixed",
                                fixed_paths=[[0, a, b, 0]],
                                rng=np.random.default_rng(1))
        for m in sim.run(3):
            assert m.completed == m.launched == 1
            assert m.wasted_gpu == 0.0
            assert m.reroutes == 0

    def test_crash_fails_microbatch_with_exact_waste(self):
        """Preset schedules cannot reroute: a dead on-path node fails the
        microbatch and wastes exactly the forward work completed so far
        (here: one stage-0 forward pass = COMPUTE seconds)."""
        net = tiny_net(stages=2, relays_per_stage=2)
        a = net.stage_nodes(0)[0].id
        b = net.stage_nodes(1)[0].id
        # b dies at t=0.6s, long before the first ~seconds-long transfer
        # arrives anywhere; a stays alive and completes its forward.
        churn = TraceChurn([(1, "crash", b, 0.01)])
        sim = TrainingSimulator(net, scheduler="fixed",
                                fixed_paths=[[0, a, b, 0]],
                                churn_model=churn,
                                rng=np.random.default_rng(1))
        m0, m1 = sim.run(2)
        assert m0.completed == 1 and m0.wasted_gpu == 0.0
        assert m1.completed == 0
        assert m1.wasted_gpu == COMPUTE       # a's forward, exactly
        assert m1.reroutes == 0               # fixed never reroutes
        assert not net.nodes[b].alive         # crash committed


class TestSwarmFullRecompute:
    def test_backward_crash_wastes_whole_pipeline(self):
        """SWARM's backward-crash recovery recomputes the full pipeline:
        the wasted GPU time is exactly the microbatch's entire compute
        history (fwd a + fwd b + bwd b), pinned analytically."""
        net = tiny_net(stages=2, relays_per_stage=1)
        a = net.stage_nodes(0)[0].id
        b = net.stage_nodes(1)[0].id
        lo, hi = crash_window(net, [0, a, b, 0])
        sim = TrainingSimulator(net, scheduler="swarm",
                                rng=np.random.default_rng(1))
        horizon = sim.engine._estimate_iteration()
        # kill a after its forward completes but before the backward
        # returns to it -> the backward-recovery (restart) branch
        churn = TraceChurn([(0, "crash", a, ((lo + hi) / 2) / horizon)])
        sim.engine.churn_model = churn
        (m,) = sim.run(1)
        assert m.launched == 1 and m.completed == 0
        # fwd@a + fwd@b + bwd@b; the restarted pipeline re-routes through
        # the only stage-0 relay (already dead) and adds no compute
        assert m.wasted_gpu == COMPUTE + COMPUTE + 2 * COMPUTE
        assert m.reroutes == 1                # one successful restart

    def test_seeded_regression_slot_leak_fix(self):
        """Golden pin of SWARM waste/throughput under Bernoulli churn
        with the slot-leak fix: restarting microbatches release their
        slots through release_slot, so queued microbatches wake instead
        of stalling out.  On this seed the pre-refactor loop (which
        leaked the slots) completes fewer microbatches and wastes more
        GPU time — the inflation the paper does NOT attribute to
        recomputation."""
        def net():
            rng = np.random.default_rng(2)
            caps = [int(rng.uniform(1, 3)) for _ in range(16)]
            return geo_distributed_network(
                num_stages=4, relay_capacities=caps, num_data_nodes=2,
                data_capacity=4, compute_cost=0.05,
                rng=np.random.default_rng(2))
        sim = TrainingSimulator(net(), scheduler="swarm", churn=0.2,
                                rng=np.random.default_rng(102))
        ms = sim.run(6)
        assert sum(m.completed for m in ms) == 20        # golden
        assert sum(m.wasted_gpu for m in ms) == 29.0     # golden
        ref = ReferenceTrainingSimulator(net(), scheduler="swarm",
                                         churn=0.2,
                                         rng=np.random.default_rng(102))
        mr = ref.run(6)
        assert sum(m.completed for m in mr) == 18        # leaked slots
        assert sum(m.wasted_gpu for m in mr) == 32.0


class TestGWTFPipelineRepair:
    def test_backward_crash_repairs_without_waste(self):
        """Contrast to SWARM: GWTF's pipeline repair splices a spare
        stage node and recomputes only that stage — zero wasted GPU
        time (the paper's headline property)."""
        net = tiny_net(stages=2, relays_per_stage=2)
        sim = TrainingSimulator(net, scheduler="gwtf",
                                rng=np.random.default_rng(1))
        flows = sim.protocol.complete_flows()
        assert flows, "protocol should plan at least one flow"
        path = flows[0]
        lo, hi = crash_window(net, path)
        horizon = sim.engine._estimate_iteration()
        churn = TraceChurn([(0, "crash", path[1], ((lo + hi) / 2) / horizon)])
        sim.engine.churn_model = churn
        (m,) = sim.run(1)
        assert m.completed == m.launched >= 1
        assert m.wasted_gpu == 0.0
        assert m.reroutes >= 1


class TestChurnModels:
    def test_trace_rejoin_roundtrip(self):
        net = tiny_net(stages=2, relays_per_stage=2)
        a = net.stage_nodes(0)[0].id
        churn = TraceChurn([(0, "crash", a, 0.01), (2, "rejoin", a)])
        sim = TrainingSimulator(net, scheduler="gwtf", churn_model=churn,
                                rng=np.random.default_rng(3))
        sim.run(1)
        assert not net.nodes[a].alive
        sim.run(1)                      # iteration 1: still dead
        assert not net.nodes[a].alive
        sim.run(1)                      # iteration 2: rejoins
        assert net.nodes[a].alive

    def test_trace_rejects_unknown_kind(self):
        with pytest.raises(ValueError):
            TraceChurn([(0, "explode", 1)])

    def test_regional_outage_is_correlated(self):
        net = geo_distributed_network(
            num_stages=2, relay_capacities=[2] * 12, num_data_nodes=1,
            data_capacity=2, compute_cost=1.0, num_locations=3,
            rng=np.random.default_rng(4))
        model = RegionalOutageChurn(1.0, rejoin_prob=0.0)
        sim = TrainingSimulator(net, scheduler="swarm", churn_model=model,
                                rng=np.random.default_rng(6))
        (m,) = sim.run(1)
        dead = [n for n in net.nodes.values() if not n.alive]
        assert dead, "outage_prob=1.0 must take down one region"
        locs = {n.location for n in dead}
        assert len(locs) == 1            # all in one location
        loc = locs.pop()
        survivors = [n for n in net.nodes.values()
                     if not n.is_data and n.location == loc and n.alive]
        assert not survivors             # severity 1.0: whole region down

    def test_regional_blackout_trace_helper(self):
        net = geo_distributed_network(
            num_stages=2, relay_capacities=[2] * 12, num_data_nodes=1,
            data_capacity=2, compute_cost=1.0, num_locations=3,
            rng=np.random.default_rng(4))
        loc = net.stage_nodes(0)[0].location
        trace = TraceChurn.regional_blackout(net, location=loc,
                                             at_iteration=0, duration=1)
        sim = TrainingSimulator(net, scheduler="swarm", churn_model=trace,
                                rng=np.random.default_rng(6))
        sim.run(1)
        assert all(not n.alive for n in net.nodes.values()
                   if not n.is_data and n.location == loc)
        sim.run(1)
        assert all(n.alive for n in net.nodes.values()
                   if not n.is_data and n.location == loc)

    def test_composed_union_earliest_crash_wins(self):
        net = tiny_net(stages=2, relays_per_stage=2)
        a = net.stage_nodes(0)[0].id
        b = net.stage_nodes(0)[1].id
        model = ComposedChurn([
            TraceChurn([(0, "crash", a, 0.9), (0, "crash", b, 0.2)]),
            TraceChurn([(0, "crash", a, 0.3)]),
        ])
        from repro.core.sim.faults import ChurnContext
        ctx = ChurnContext(net=net, rng=np.random.default_rng(0),
                           horizon=100.0, iteration=0,
                           on_rejoin=lambda n: None)
        crash = model.sample(ctx)
        assert crash[a] == pytest.approx(30.0)   # earliest of 90 / 30
        assert crash[b] == pytest.approx(20.0)

    @staticmethod
    def _ctx(net, iteration, rejoined=None):
        from repro.core.sim.faults import ChurnContext
        log = rejoined if rejoined is not None else []
        return ChurnContext(net=net, rng=np.random.default_rng(0),
                            horizon=100.0, iteration=iteration,
                            on_rejoin=lambda n: log.append(n.id))

    def test_composed_trace_and_blackout_overlap_same_node(self):
        """Trace replay + regional blackout hitting the same relay:
        the union keeps the earliest crash time, and the node's
        *second* crash record does not double-kill or corrupt the
        rejoin bookkeeping of either model."""
        net = geo_distributed_network(
            num_stages=2, relay_capacities=[2] * 12, num_data_nodes=1,
            data_capacity=2, compute_cost=1.0, num_locations=3,
            rng=np.random.default_rng(4))
        victim = net.stage_nodes(0)[0]
        loc = victim.location
        model = ComposedChurn([
            TraceChurn([(0, "crash", victim.id, 0.8)]),
            TraceChurn.regional_blackout(net, location=loc,
                                         at_iteration=0, duration=2,
                                         when=0.25),
        ])
        crash = model.sample(self._ctx(net, 0))
        # the blackout's earlier moment wins for the shared victim
        assert crash[victim.id] == pytest.approx(25.0)
        region = [n.id for n in net.nodes.values()
                  if not n.is_data and n.location == loc]
        assert all(crash[nid] == pytest.approx(25.0) for nid in region)
        for nid in crash:
            net.kill_node(nid)
        # iteration 1: nothing due in either model
        assert model.sample(self._ctx(net, 1)) == {}
        assert not net.nodes[victim.id].alive
        # iteration 2: the blackout's rejoin revives the whole region,
        # including the doubly-crashed victim, exactly once
        rejoined = []
        assert model.sample(self._ctx(net, 2, rejoined)) == {}
        assert sorted(rejoined) == sorted(region)
        assert net.nodes[victim.id].alive

    def test_trace_rejoin_during_active_blackout(self):
        """A later clause may revive a node mid-blackout (operator
        intervention); the blackout's own scheduled rejoin then finds
        the node alive and must skip it — and an earlier-in-composition
        model can still re-crash the revived node in a later
        iteration."""
        net = geo_distributed_network(
            num_stages=2, relay_capacities=[2] * 12, num_data_nodes=1,
            data_capacity=2, compute_cost=1.0, num_locations=3,
            rng=np.random.default_rng(4))
        victim = net.stage_nodes(0)[0]
        loc = victim.location
        model = ComposedChurn([
            TraceChurn([(2, "crash", victim.id, 0.5)]),
            TraceChurn.regional_blackout(net, location=loc,
                                         at_iteration=0, duration=3,
                                         when=0.25),
            TraceChurn([(1, "rejoin", victim.id)]),     # mid-blackout
        ])
        for nid in model.sample(self._ctx(net, 0)):
            net.kill_node(nid)
        assert not net.nodes[victim.id].alive
        rejoined = []
        assert model.sample(self._ctx(net, 1, rejoined)) == {}
        assert rejoined == [victim.id]                  # revived early
        assert net.nodes[victim.id].alive
        # iteration 2: the first model re-crashes the revived node
        crash = model.sample(self._ctx(net, 2))
        assert crash == {victim.id: pytest.approx(50.0)}
        net.kill_node(victim.id)
        # iteration 3: blackout's scheduled rejoin — the victim is dead
        # again so it *is* revived (trace rejoins skip only alive
        # nodes), together with the rest of its region, each exactly
        # once
        rejoined = []
        region = [n.id for n in net.nodes.values()
                  if not n.is_data and n.location == loc]
        assert model.sample(self._ctx(net, 3, rejoined)) == {}
        assert sorted(rejoined) == sorted(region)
        assert all(net.nodes[nid].alive for nid in region)

    def test_composed_interaction_through_full_engine(self):
        """The overlap semantics hold end-to-end: a composed
        trace+blackout program runs through the engine with the victim
        region recovering on schedule."""
        net = geo_distributed_network(
            num_stages=2, relay_capacities=[2] * 12, num_data_nodes=1,
            data_capacity=2, compute_cost=1.0, num_locations=3,
            rng=np.random.default_rng(4))
        victim = net.stage_nodes(0)[0]
        loc = victim.location
        model = ComposedChurn([
            TraceChurn([(0, "crash", victim.id, 0.9)]),
            TraceChurn.regional_blackout(net, location=loc,
                                         at_iteration=0, duration=2),
        ])
        sim = TrainingSimulator(net, scheduler="gwtf", churn_model=model,
                                rng=np.random.default_rng(6))
        sim.run(2)
        region = [n.id for n in net.nodes.values()
                  if not n.is_data and n.location == loc]
        assert all(not net.nodes[nid].alive for nid in region)
        sim.run(1)
        assert all(net.nodes[nid].alive for nid in region)

    def test_link_degradation_applies_and_restores(self):
        from repro.core.sim.faults import LinkDegradationChurn
        net = geo_distributed_network(
            num_stages=2, relay_capacities=[2] * 8, num_data_nodes=1,
            data_capacity=2, compute_cost=1.0, num_locations=3,
            rng=np.random.default_rng(4))
        before = net.bandwidth.copy()
        ver0 = net.cost_version
        model = LinkDegradationChurn(1, 4.0, duration=2)
        assert model.sample(self._ctx(net, 0)) == {}
        np.testing.assert_array_equal(net.bandwidth, before)
        assert model.sample(self._ctx(net, 1)) == {}     # degrade
        assert net.cost_version > ver0
        locs = np.array([net.nodes[i].location
                         for i in range(before.shape[0])])
        inter = locs[:, None] != locs[None, :]
        np.testing.assert_allclose(net.bandwidth[inter],
                                   before[inter] / 4.0)
        np.testing.assert_array_equal(net.bandwidth[~inter],
                                      before[~inter])
        ver1 = net.cost_version
        assert model.sample(self._ctx(net, 2)) == {}     # held
        assert model.sample(self._ctx(net, 3)) == {}     # restore
        np.testing.assert_array_equal(net.bandwidth, before)
        assert net.cost_version > ver1

    def test_overlapping_link_degradations_compose_and_undo(self):
        """Two degradation windows overlapping in a ComposedChurn:
        the cuts stack while both are active and each undo removes
        only its own factor — after both expire the matrix is back to
        the original (power-of-two factors: bit-exact)."""
        from repro.core.sim.faults import LinkDegradationChurn
        net = geo_distributed_network(
            num_stages=2, relay_capacities=[2] * 8, num_data_nodes=1,
            data_capacity=2, compute_cost=1.0, num_locations=3,
            rng=np.random.default_rng(4))
        before = net.bandwidth.copy()
        model = ComposedChurn([
            LinkDegradationChurn(0, 2.0, duration=2,
                                 inter_region_only=False),
            LinkDegradationChurn(1, 4.0, duration=2,
                                 inter_region_only=False),
        ])
        model.sample(self._ctx(net, 0))                  # A on
        np.testing.assert_array_equal(net.bandwidth, before / 2.0)
        model.sample(self._ctx(net, 1))                  # B on: stacked
        np.testing.assert_array_equal(net.bandwidth, before / 8.0)
        model.sample(self._ctx(net, 2))                  # A off, B holds
        np.testing.assert_array_equal(net.bandwidth, before / 4.0)
        model.sample(self._ctx(net, 3))                  # B off
        np.testing.assert_array_equal(net.bandwidth, before)


class TestEventAccounting:
    def test_max_events_truncation_warns(self):
        net = tiny_net(stages=2, relays_per_stage=2, data_capacity=2)
        sim = TrainingSimulator(net, scheduler="gwtf",
                                rng=np.random.default_rng(1), max_events=5)
        with pytest.warns(RuntimeWarning, match="truncated"):
            m = sim.run_iteration()
        assert m.truncated
        assert m.events == 5
        assert np.isfinite(m.duration)

    def test_clean_iteration_not_truncated(self):
        net = tiny_net(stages=2, relays_per_stage=2)
        sim = TrainingSimulator(net, scheduler="gwtf",
                                rng=np.random.default_rng(1))
        with warnings.catch_warnings():
            warnings.simplefilter("error", RuntimeWarning)
            m = sim.run_iteration()
        assert not m.truncated
        assert m.events > 0 and m.loop_seconds >= 0.0
        assert m.events_per_sec >= 0.0

    def test_queue_metrics_under_contention(self):
        """Capacity-1 relays + capacity-blind SWARM routing must queue."""
        net = geo_distributed_network(
            num_stages=2, relay_capacities=[1, 1, 1, 1], num_data_nodes=1,
            data_capacity=6, compute_cost=5.0, compute_jitter=0.0,
            rng=np.random.default_rng(7))
        sim = TrainingSimulator(net, scheduler="swarm",
                                rng=np.random.default_rng(8))
        (m,) = sim.run(1)
        assert m.queue_enqueues > 0
        assert m.queue_depth_peak > 0

    def test_summarize_columns(self):
        net = tiny_net(stages=2, relays_per_stage=2, data_capacity=2)
        sim = TrainingSimulator(net, scheduler="gwtf", churn=0.1,
                                rng=np.random.default_rng(9))
        table = summarize(sim.run(4), warmup=1)
        for key in ("time_per_mb", "throughput", "wasted_gpu", "reroutes",
                    "queue_depth_peak", "truncated_iterations"):
            assert key in table
            mean, std = table[key]
            assert np.isfinite(mean) and np.isfinite(std)


class _SlowPolicy:
    """Delegating policy whose plan() sleeps — a deterministic planning
    overrun against a millisecond event loop."""

    def __init__(self, inner, delay=0.08, oracle_seconds=0.0):
        self.inner = inner
        self.name = inner.name
        self.delay = delay
        self.last_oracle_seconds = oracle_seconds
        self.throttle_calls = 0

    def plan(self):
        import time
        time.sleep(self.delay)
        return self.inner.plan()

    def recover(self, view, mb, frm, dead, t):
        return self.inner.recover(view, mb, frm, dead, t)

    def on_rejoin(self, node):
        self.inner.on_rejoin(node)

    def on_crash(self, nid):
        self.inner.on_crash(nid)

    def throttle_planning(self):
        self.throttle_calls += 1
        return self.inner.throttle_planning()


class TestPlanOverrunGuard:
    def _sim(self, policy_delay=0.08, oracle_seconds=0.0):
        net = tiny_net(stages=2, relays_per_stage=2, data_capacity=2)
        rng = np.random.default_rng(5)
        slow = _SlowPolicy(make_policy("gwtf", net, rng=rng),
                           delay=policy_delay,
                           oracle_seconds=oracle_seconds)
        return slow, TrainingSimulator(net, policy=slow, rng=rng,
                                       plan_overrun_factor=2.0,
                                       plan_overrun_min_seconds=0.02)

    def test_overrun_warns_flags_and_throttles(self):
        slow, sim = self._sim()
        inner_rounds = slow.inner.repair_rounds
        with pytest.warns(RuntimeWarning, match="planning overran"):
            m = sim.run_iteration()
        assert m.plan_overrun
        assert slow.throttle_calls == 1
        assert slow.inner.repair_rounds == max(2, inner_rounds // 2)
        assert m.completed == m.launched > 0     # warn-and-cap, not fail

    def test_oracle_time_excluded_from_guard(self):
        """The optimality oracle rides inside plan() as a diagnostic;
        its wall time must not trip the throttle."""
        slow, sim = self._sim(oracle_seconds=10.0)
        with warnings.catch_warnings():
            warnings.simplefilter("error", RuntimeWarning)
            m = sim.run_iteration()
        assert not m.plan_overrun
        assert slow.throttle_calls == 0

    def test_track_optimality_surfaces_ratio_stream_neutrally(self):
        """GWTFPolicy(track_optimality=True) publishes the dial-oracle
        cost ratio into IterationMetrics without touching the RNG
        stream or any behavioral metric."""
        from repro.core.sim.policies import GWTFPolicy

        def run(track):
            net = tiny_net(seed=2, stages=2, relays_per_stage=2,
                           data_capacity=2)
            rng = np.random.default_rng(3)
            sim = TrainingSimulator(
                net, policy=GWTFPolicy(net, rng=rng,
                                       track_optimality=track),
                churn=0.1, rng=rng)
            return sim.run(3)
        tracked, plain = run(True), run(False)
        for a, b in zip(tracked, plain):
            assert (a.completed, a.comm_time, a.wasted_gpu, a.duration) \
                == (b.completed, b.comm_time, b.wasted_gpu, b.duration)
            assert b.cost_ratio_vs_optimal is None
            if a.launched:
                assert a.cost_ratio_vs_optimal is not None
                assert a.cost_ratio_vs_optimal >= 1.0 - 1e-9


class TestEngineEquivalence:
    @pytest.mark.parametrize("churn", [0.0, 0.15])
    def test_gwtf_metric_and_rng_identical(self, churn):
        """The layered engine is a perf refactor of the reference loop:
        seeded GWTF runs must be bit-identical (metrics + RNG stream)."""
        def net():
            rng = np.random.default_rng(3)
            caps = [int(rng.uniform(1, 4)) for _ in range(16)]
            return geo_distributed_network(
                num_stages=4, relay_capacities=caps, num_data_nodes=2,
                data_capacity=4, compute_cost=0.05,
                rng=np.random.default_rng(3))
        s1 = TrainingSimulator(net(), scheduler="gwtf", churn=churn,
                               rng=np.random.default_rng(12))
        s2 = ReferenceTrainingSimulator(net(), scheduler="gwtf", churn=churn,
                                        rng=np.random.default_rng(12))
        for a, b in zip(s1.run(5), s2.run(5)):
            assert a.duration == b.duration
            assert a.completed == b.completed
            assert a.comm_time == b.comm_time
            assert a.wasted_gpu == b.wasted_gpu
            assert a.aggregation_time == b.aggregation_time
        assert s1.rng.bit_generator.state == s2.rng.bit_generator.state

    def test_unknown_scheduler_rejected(self):
        net = tiny_net()
        with pytest.raises(ValueError, match="unknown scheduler"):
            make_policy("mystery", net)


class TestCostMatrices:
    def test_comm_and_edge_matrices_match_scalar_paths(self):
        net = tiny_net(stages=2, relays_per_stage=3)
        size = 12345.0
        C = net.comm_matrix(size)
        E = net.edge_matrix(size)
        ids = list(net.nodes)
        for i in ids[:4]:
            for j in ids[:4]:
                if i == j:
                    continue
                assert C[i, j] == net.comm_cost(i, j, size)
                assert E[i, j] == net.edge_cost(i, j, size)
