"""Hierarchical geo-planner (`flow/hierarchy.py`).

Feasibility, determinism, the optimality gap against the flat dial
MCMF oracle, refinement monotonicity, parallel-refinement equivalence,
and the MinCostFlow transport fallback used when scipy is absent.
"""
import numpy as np
import pytest

from repro.core.flow.graph import FlowNetwork, Node
from repro.core.flow.hierarchy import (aggregate_regions,
                                       build_region_network,
                                       solve_hierarchical)
from repro.core.flow.mincost import solve_training_flow

STAGES = 5
LOCATIONS = 6


def geo_net(relays=150, seed=0, sources=2, locations=LOCATIONS,
            stages=STAGES):
    """bench_scale-style topology: integer per-location-pair base cost
    + bounded symmetric node jitter, Node.location stamped."""
    rng = np.random.default_rng(seed)
    N = sources + relays
    nodes = {}
    loc = np.empty(N, np.int64)
    for d in range(sources):
        nodes[d] = Node(d, -1, max(4, relays // 20), 0.0, is_data=True)
        loc[d] = int(rng.integers(0, locations))
    for i in range(relays):
        nid = sources + i
        nodes[nid] = Node(nid, i % stages, int(rng.integers(1, 4)), 0.0,
                          location=int(rng.integers(0, locations)))
        loc[nid] = nodes[nid].location
    base = rng.integers(4, 21, (locations, locations)).astype(float)
    base = np.maximum(base, base.T)
    np.fill_diagonal(base, 0.0)
    base += np.diag(rng.integers(1, 5, locations).astype(float))
    jitter = rng.integers(0, 3, (N, N)).astype(float)
    cm = base[np.ix_(loc, loc)] + np.maximum(jitter, jitter.T)
    np.fill_diagonal(cm, 0.0)
    net = FlowNetwork(nodes=nodes, num_stages=stages, latency=cm,
                      bandwidth=np.full((N, N), np.inf),
                      activation_size=0.0)
    return net, cm


def assert_feasible(net, plan):
    """Closed stage-ordered chains within every node's capacity."""
    assert plan.flow == len(plan.paths) > 0
    used = {}
    for path in plan.paths:
        assert len(path) == net.num_stages + 2
        assert path[0] == path[-1] and net.nodes[path[0]].is_data
        for s, nid in enumerate(path[1:-1]):
            node = net.nodes[nid]
            assert node.stage == s and node.alive and not node.is_data
        for hop in path[:-1]:
            used[hop] = used.get(hop, 0) + 1
    for nid, cnt in used.items():
        assert cnt <= net.nodes[nid].capacity, f"node {nid} over capacity"
    # the reported cost is the true cost of the emitted chains
    cm = net.cost_matrix() if plan.paths else None
    total = sum(cm[a, b] for p in plan.paths for a, b in zip(p, p[1:]))
    assert plan.cost == pytest.approx(total)


class TestHierarchicalPlanner:
    def test_feasible_deterministic_and_within_gap(self):
        net, cm = geo_net()
        h1 = solve_hierarchical(net, cost_matrix=cm)
        assert_feasible(net, h1)
        net2, cm2 = geo_net()
        h2 = solve_hierarchical(net2, cost_matrix=cm2)
        assert h1.paths == h2.paths and h1.cost == h2.cost
        flat = solve_training_flow(net, cost_matrix=cm, max_flow=h1.flow,
                                   method="dial")
        assert flat.flow == h1.flow
        assert h1.cost <= 1.15 * flat.cost   # committed gap bound

    def test_region_aggregation_covers_alive_relays(self):
        net, cm = geo_net(relays=60)
        dead = 2 + 7
        net.kill_node(dead)
        groups = aggregate_regions(net)
        members = [m for g in groups.values() for m in g]
        alive_relays = [n.id for n in net.nodes.values()
                        if not n.is_data and n.alive]
        assert sorted(members) == sorted(alive_relays)
        for (s, _), g in groups.items():
            assert all(net.nodes[m].stage == s for m in g)
        region_net, rcm, super_of, _ = build_region_network(
            net, cost_matrix=cm)
        for srid, (s, loc) in super_of.items():
            assert region_net.nodes[srid].capacity == \
                sum(net.nodes[m].capacity for m in groups[(s, loc)])

    def test_refine_passes_monotone(self):
        """Coordinate-descent sweeps only ever lower the plan cost."""
        net, cm = geo_net(seed=3)
        costs = [solve_hierarchical(net, cost_matrix=cm,
                                    refine_passes=k).cost
                 for k in (0, 1, 2, 4)]
        for a, b in zip(costs, costs[1:]):
            assert b <= a + 1e-9

    def test_parallel_refinement_matches_serial(self):
        net, cm = geo_net(seed=4)
        serial = solve_hierarchical(net, cost_matrix=cm, parallel=0)
        threaded = solve_hierarchical(net, cost_matrix=cm, parallel=3)
        assert serial.paths == threaded.paths
        assert serial.cost == threaded.cost

    def test_max_flow_cap_respected(self):
        net, cm = geo_net(seed=5)
        full = solve_hierarchical(net, cost_matrix=cm)
        capped = solve_hierarchical(net, cost_matrix=cm,
                                    max_flow=full.flow // 2)
        assert capped.flow == full.flow // 2
        assert_feasible(net, capped)

    def test_transport_fallback_without_scipy(self, monkeypatch):
        """With scipy's linear_sum_assignment unavailable, the exact
        MinCostFlow transport fallback produces an equally-cheap plan."""
        from repro.core.flow import hierarchy

        net, cm = geo_net(relays=60, seed=6)
        with_lsa = solve_hierarchical(net, cost_matrix=cm)
        monkeypatch.setattr(hierarchy, "_lsa", None)
        without = solve_hierarchical(net, cost_matrix=cm)
        assert_feasible(net, without)
        assert without.flow == with_lsa.flow
        # both transports are exact solvers of the same per-group
        # problems; sweeps may break cost ties differently, so compare
        # the objective, not the chains
        assert without.cost == pytest.approx(with_lsa.cost)
