"""Compression-aware WAN planning: per-link codec pricing in the flow
layer, bytes-on-wire accounting in the simulator, and bf16/top-k wire
codecs on the runtime's inter-stage boundary transfers (the PR-8
compression rework).  The fp32-only menu must be a bit-exact no-op on
every layer."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.flow.graph import (WIRE_CODECS, FlowNetwork,
                                   geo_distributed_network)
from repro.core.runtime.activations import (Bf16Codec, TopKCodec,
                                            make_codec)
from repro.core.runtime.trainer import CentralizedTrainer, RuntimeTrainer
from repro.core.scenarios import generate
from repro.core.scenarios.spec import ScenarioSpec
from repro.core.sim.faults import TraceChurn
from repro.data.pipeline import DataConfig, DataNodeShard
from tests._hypothesis_compat import given, settings, st

FULL_MENU = ("fp32", "bf16", "int8", "top-k")


def make_net(seed=0, stages=2, **kw):
    return geo_distributed_network(
        num_stages=stages, relay_capacities=[3] * (3 * stages),
        num_data_nodes=1, data_capacity=4,
        rng=np.random.default_rng(seed), **kw)


def geo_spec(**kw):
    base = dict(name="t", seed=7, topology="geo", num_stages=3,
                relays_per_stage=3, num_data_nodes=1, data_capacity=3,
                num_locations=4, iterations=2)
    base.update(kw)
    return ScenarioSpec(**base).validate()


# ---------------------------------------------------------------------------
# Flow layer: codec-aware link pricing
# ---------------------------------------------------------------------------

class TestFlowCodecPricing:
    def test_fp32_menu_is_bit_identical_to_default(self):
        """The default menu and an explicit fp32-only menu produce the
        exact same cached matrices and scalar costs (the codec
        machinery's off switch is bit-exact, not approximately so)."""
        a = make_net(seed=3)
        b = make_net(seed=3)
        b.codec_menu = ("fp32",)
        b.fidelity_budget = 0.5          # budget is irrelevant to fp32
        np.testing.assert_array_equal(a.cost_matrix(), b.cost_matrix())
        for size in (None, 1.0, 4096.0, a.activation_size):
            np.testing.assert_array_equal(a.edge_matrix(size),
                                          b.edge_matrix(size))
            if size is not None:
                np.testing.assert_array_equal(a.comm_matrix(size),
                                              b.comm_matrix(size))
            assert a.edge_cost(0, 3, size) == b.edge_cost(0, 3, size)
            assert a.comm_cost(2, 4, size) == b.comm_cost(2, 4, size)
        assert (b.wire_codec_matrix() == 0).all()

    def test_budget_gates_admissibility(self):
        net = make_net()
        net.codec_menu = FULL_MENU
        net.fidelity_budget = 0.0
        assert net.wire_codec_names() == ("fp32",)   # all lossy codecs out
        net.fidelity_budget = 0.02
        assert net.wire_codec_names() == ("fp32", "bf16", "int8")
        net.fidelity_budget = 1.0
        assert net.wire_codec_names() == FULL_MENU

    def test_unknown_codec_name_rejected(self):
        net = make_net()
        net.codec_menu = ("fp32", "fp64")
        with pytest.raises(ValueError, match="unknown wire codec"):
            net.cost_matrix()

    def test_choice_is_per_edge_price_argmin(self):
        """Every entry of the codec-choice matrix is the true scalar
        argmin of the per-codec edge price (first-min tie-break), and
        the priced matrix equals the chosen codec's price."""
        net = make_net(seed=5)
        net.codec_menu = FULL_MENU
        net.fidelity_budget = 0.1
        size = net.activation_size / 64.0   # small enough to diversify
        comm = net.comm_matrix(size)
        choice = net.wire_codec_matrix(size)
        adm = net.admissible_codecs()
        lat = 0.5 * (net.latency + net.latency.T)
        bw = net.bandwidth + net.bandwidth.T
        n = lat.shape[0]
        rng = np.random.default_rng(0)
        for i, j in zip(rng.integers(0, n, 40), rng.integers(0, n, 40)):
            prices = [lat[i, j] + 2.0 * (c.ratio * size) / bw[i, j]
                      + c.coder_rate * size
                      + net.fidelity_weight * c.fidelity_penalty
                      for c in adm]
            k = int(np.argmin(prices))
            assert choice[i, j] == k
            assert comm[i, j] == pytest.approx(prices[k], rel=1e-12)

    def test_wan_links_compress_fast_links_do_not(self):
        """Co-optimization story: at a payload size where transfer time
        matters, slow inter-location links pick an aggressive codec
        while at a tiny payload every link stays fp32 (the fidelity
        penalty dominates)."""
        net = make_net(seed=1)
        net.codec_menu = FULL_MENU
        net.fidelity_budget = 0.1
        big = net.wire_codec_matrix(net.activation_size)
        assert (big > 0).any()               # someone compressed
        tiny = net.wire_codec_matrix(1.0)
        assert (tiny == 0).all()             # nobody compresses 1 byte

    def test_flow_records_chosen_codecs(self):
        spec = geo_spec(compression={"menu": list(FULL_MENU),
                                     "fidelity_budget": 0.1})
        flow = generate.run_flow(spec)
        codecs = flow.protocol.flow_codecs()
        assert len(codecs) == len(flow.flows)
        names = set(flow.net.wire_codec_names())
        for chain, chain_codecs in zip(flow.flows, codecs):
            assert len(chain_codecs) == len(chain) - 1
            assert set(chain_codecs) <= names

    def test_matrix_cache_survives_alternating_sizes(self):
        """Regression for the single-entry per-size cache: alternating
        comm/edge matrix sizes must hit the per-epoch dict, not rebuild
        every call."""
        net = make_net(seed=2)
        for _ in range(100):
            net.comm_matrix(1024.0)
            net.comm_matrix(net.activation_size)
            net.edge_matrix(1024.0)
            net.edge_matrix(net.activation_size)
        assert net.matrix_rebuild_count <= 4
        # same behaviour with a non-trivial menu
        net.codec_menu = FULL_MENU
        net.fidelity_budget = 0.1
        base = net.matrix_rebuild_count
        for _ in range(100):
            net.comm_matrix(1024.0)
            net.comm_matrix(net.activation_size)
        assert net.matrix_rebuild_count - base <= 2
        # a cost-epoch bump invalidates and rebuilds once per size
        net.invalidate_costs()
        base = net.matrix_rebuild_count
        for _ in range(10):
            net.comm_matrix(1024.0)
        assert net.matrix_rebuild_count - base == 1


# ---------------------------------------------------------------------------
# Runtime codecs: bf16 and top-k round-trip bounds (property tests)
# ---------------------------------------------------------------------------

class TestBf16Codec:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10 ** 6), rows=st.integers(1, 8),
           cols=st.integers(1, 64),
           mag=st.floats(1e-4, 1e4))
    def test_roundtrip_relative_error_bound(self, seed, rows, cols, mag):
        """Elementwise |x - dq(q(x))| <= 2**-8 * |x| (half an ulp of
        bf16's eps = 2**-7) for normal values."""
        rng = np.random.default_rng(seed)
        x = jnp.asarray((rng.normal(size=(rows, cols)) * mag
                         ).astype(np.float32))
        codec = Bf16Codec()
        enc = codec.encode(x)
        dq = np.asarray(codec.decode(enc))
        assert dq.dtype == np.float32
        err = np.abs(np.asarray(x) - dq)
        assert (err <= 2.0 ** -8 * np.abs(np.asarray(x)) + 1e-30).all()
        assert codec.nbytes(enc) * 2 == x.nbytes

    def test_non_float_passthrough(self):
        codec = Bf16Codec()
        ids = jnp.arange(12, dtype=jnp.int32)
        assert codec.encode(ids) is ids
        assert codec.decode(ids) is ids


class TestTopKCodec:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10 ** 6), n=st.integers(8, 512),
           k_frac=st.floats(0.05, 1.0))
    def test_roundtrip_error_bounded_by_min_kept(self, seed, n, k_frac):
        """Kept entries round-trip exactly; every dropped magnitude is
        <= the smallest kept magnitude."""
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32))
        codec = TopKCodec(k_frac=k_frac)
        enc = codec.encode(x)
        dq = np.asarray(codec.decode(enc))
        kept = np.asarray(enc.idx)
        np.testing.assert_array_equal(dq[kept], np.asarray(x)[kept])
        dropped = np.setdiff1d(np.arange(n), kept)
        assert (dq[dropped] == 0).all()
        if dropped.size:
            min_kept = np.abs(np.asarray(enc.vals)).min()
            assert np.abs(np.asarray(x)[dropped]).max() <= min_kept
        err = np.abs(np.asarray(x) - dq)
        bound = np.abs(np.asarray(enc.vals)).min()
        assert err.max() <= bound + 1e-30

    def test_nbytes_monotone_in_k(self, rng):
        x = jnp.asarray(rng.normal(size=(32, 32)).astype(np.float32))
        sizes = [TopKCodec(k_frac=k).nbytes(TopKCodec(k_frac=k).encode(x))
                 for k in (0.05, 0.1, 0.25, 0.5, 1.0)]
        assert sizes == sorted(sizes)
        assert sizes[0] < sizes[-1]

    def test_k_frac_validated(self):
        with pytest.raises(ValueError, match="k_frac"):
            TopKCodec(k_frac=0.0)
        with pytest.raises(ValueError, match="k_frac"):
            TopKCodec(k_frac=1.5)

    def test_shape_and_dtype_restored(self, rng):
        x = jnp.asarray(rng.normal(size=(3, 5, 7)).astype(np.float32))
        codec = TopKCodec(k_frac=0.25)
        dq = codec.decode(codec.encode(x))
        assert dq.shape == x.shape and dq.dtype == x.dtype


class TestCodecRegistry:
    def test_planner_names_resolve(self):
        """Every flow-layer WIRE_CODECS name maps onto a runtime codec
        (the alias table keeps the two registries in sync)."""
        from repro.core.runtime.activations import (CODEC_ALIASES, CODECS,
                                                    Int8Codec, NullCodec)
        for name in WIRE_CODECS:
            codec = make_codec(name)
            assert codec is not None
        assert isinstance(make_codec("fp32"), NullCodec)
        assert isinstance(make_codec("top-k"), TopKCodec)
        assert isinstance(make_codec("int8"), Int8Codec)
        assert set(CODEC_ALIASES.values()) <= set(CODECS)


# ---------------------------------------------------------------------------
# Sim layer: bytes-on-wire accounting
# ---------------------------------------------------------------------------

class TestSimBytesOnWire:
    def test_trivial_menu_counts_raw_bytes(self):
        spec = geo_spec()
        sim = generate.build_sim(spec)
        for m in sim.run(2):
            assert m.codec_legs is None
            assert m.bytes_on_wire > 0
            assert m.bytes_on_wire % sim.profile.activation_bytes == 0

    def test_codec_menu_shrinks_bytes_on_wire(self):
        base = geo_spec()
        comp = base.replace(compression={"menu": list(FULL_MENU),
                                         "fidelity_budget": 0.1})
        mb = generate.run_sim(base)
        mc = generate.run_sim(comp)
        raw = sum(m.bytes_on_wire for m in mb)
        enc = sum(m.bytes_on_wire for m in mc)
        assert enc < raw                    # compression actually helps
        assert raw / enc >= 2.0             # at least bf16 everywhere
        # a bandwidth-starved WAN pushes links to top-k (>= 3x is the
        # committed bench_sim gate on the WAN row)
        slow = base.replace(min_bandwidth=2e6, max_bandwidth=1e7,
                            compression=comp.compression)
        sraw = sum(m.bytes_on_wire
                   for m in generate.run_sim(slow.replace(
                       compression=None)))
        senc = sum(m.bytes_on_wire for m in generate.run_sim(slow))
        assert sraw / senc >= 3.0
        sim = generate.build_sim(comp)
        ratios = {c.name: c.ratio
                  for c in sim.net.admissible_codecs()}
        act = sim.profile.activation_bytes
        for m in sim.run(2):
            assert m.codec_legs and set(m.codec_legs) <= set(ratios)
            expect = sum(cnt * ratios[n] * act
                         for n, cnt in m.codec_legs.items())
            assert m.bytes_on_wire == pytest.approx(expect, rel=1e-9)

    def test_fp32_menu_summary_bit_identical(self):
        from repro.core.sim.metrics import summarize
        base = geo_spec(seed=9)
        fp32 = base.replace(compression={"menu": ["fp32"]})
        assert summarize(generate.run_sim(fp32)) == \
            summarize(generate.run_sim(base))


# ---------------------------------------------------------------------------
# Runtime layer: wire codecs on inter-stage boundary transfers
# ---------------------------------------------------------------------------

def tiny_cfg():
    cfg = get_config("gwtf-llama-300m").reduced(num_layers=4, d_model=128)
    return dataclasses.replace(cfg, vocab_size=256)


def make_mbs(cfg, seed=0):
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, batch_size=8,
                    microbatch_size=2, seed=seed)
    return DataNodeShard(dc, 0, 1).microbatches()


class TestRuntimeWire:
    def test_forced_bf16_wire_bytes_and_bounded_loss_delta(self):
        cfg = tiny_cfg()
        mbs = make_mbs(cfg)
        dn = make_net().data_nodes()[0].id
        fp = RuntimeTrainer(cfg, make_net(), lr=3e-3, seed=0,
                            churn_model=TraceChurn([]))
        bf = RuntimeTrainer(cfg, make_net(), lr=3e-3, seed=0,
                            churn_model=TraceChurn([]), wire_codec="bf16")
        for _ in range(2):
            rf = fp.iteration({dn: mbs})
            rb = bf.iteration({dn: mbs})
        assert rf.wire_bytes == 0 and rf.wire_codecs == ()
        assert rb.wire_codecs == ("bf16",)
        # one boundary (S=2), forward only, bf16 = 2 bytes/element
        expect = rb.completed * 2 * 64 * cfg.d_model * 2
        assert rb.wire_bytes == expect
        assert np.isfinite(rb.loss)
        assert abs(rb.loss - rf.loss) < 0.1
        assert bf.losses[-1] < bf.losses[0]          # still trains

    def test_wire_codec_byte_ordering(self):
        """bf16 > int8 > top-k encoded bytes on the same transfers."""
        cfg = tiny_cfg()
        mbs = make_mbs(cfg)
        dn = make_net().data_nodes()[0].id
        got = {}
        for codec in ("bf16", "int8", "top-k"):
            t = RuntimeTrainer(cfg, make_net(), lr=3e-3, seed=0,
                               churn_model=TraceChurn([]),
                               wire_codec=codec)
            r = t.iteration({dn: mbs})
            got[codec] = r.wire_bytes
            assert np.isfinite(r.loss)
        assert got["bf16"] > got["int8"] > got["top-k"] > 0

    def test_bf16_wire_zero_churn_matches_centralized(self):
        """The wire is applied identically by both trainers: a forced
        elementwise codec keeps the zero-churn decentralized run
        bit-identical to `CentralizedTrainer` with the same codec."""
        cfg = tiny_cfg()
        mbs = make_mbs(cfg)
        dn = make_net().data_nodes()[0].id
        rt = RuntimeTrainer(cfg, make_net(), lr=3e-3, seed=0,
                            churn_model=TraceChurn([]), wire_codec="bf16")
        cen = CentralizedTrainer(cfg, 2, lr=3e-3, seed=0,
                                 wire_codec="bf16")
        for _ in range(2):
            r = rt.iteration({dn: mbs})
            assert r.loss == cen.iteration(mbs)
        assert cen.last_wire_bytes > 0
        assert cen.last_wire_bytes == rt.last_wire_bytes

    def test_planner_mode_follows_choice_matrix(self):
        cfg = tiny_cfg()
        mbs = make_mbs(cfg)
        net = make_net()
        net.codec_menu = FULL_MENU
        net.fidelity_budget = 0.1
        dn = net.data_nodes()[0].id
        t = RuntimeTrainer(cfg, net, lr=3e-3, seed=0,
                           churn_model=TraceChurn([]),
                           wire_codec="planner")
        r = t.iteration({dn: mbs})
        # geo default activation size: every WAN link prefers top-k
        assert r.wire_codecs == ("top-k",)
        assert r.wire_bytes > 0
        assert np.isfinite(r.loss)

    def test_planner_mode_with_fp32_menu_is_exact(self):
        """fp32-only menu + planner mode constructs no wire at all —
        bit-identical to a trainer with no wire codec."""
        cfg = tiny_cfg()
        mbs = make_mbs(cfg)
        dn = make_net().data_nodes()[0].id
        plain = RuntimeTrainer(cfg, make_net(), lr=3e-3, seed=0,
                               churn_model=TraceChurn([]))
        planner = RuntimeTrainer(cfg, make_net(), lr=3e-3, seed=0,
                                 churn_model=TraceChurn([]),
                                 wire_codec="planner")
        for _ in range(2):
            rp = plain.iteration({dn: mbs})
            rq = planner.iteration({dn: mbs})
            assert rq.loss == rp.loss
            assert rq.wire_bytes == 0 and rq.wire_codecs == ()
