"""Staged runtime: per-stage VJP execution, stage-local recovery,
requeue semantics, checkpoint plumbing (paper Sec. V-D/V-E, Fig. 6)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.executor import CentralizedTrainer, DecentralizedTrainer
from repro.core.flow.graph import geo_distributed_network
from repro.core.runtime.stages import embed_fn, loss_fn, stage_forward
from repro.core.runtime.trainer import RuntimeTrainer
from repro.core.sim.faults import TraceChurn
from repro.core.sim.policies import FixedPolicy
from repro.data.pipeline import DataConfig, DataNodeShard


def tiny_cfg():
    cfg = get_config("gwtf-llama-300m").reduced(num_layers=4, d_model=128)
    return dataclasses.replace(cfg, vocab_size=256)


def make_net(seed=0, stages=2, data_nodes=1):
    return geo_distributed_network(
        num_stages=stages, relay_capacities=[3] * (3 * stages),
        num_data_nodes=data_nodes, data_capacity=4,
        rng=np.random.default_rng(seed))


def make_shard(cfg, seed=0):
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, batch_size=8,
                    microbatch_size=2, seed=seed)
    return DataNodeShard(dc, 0, 1)


def run_with_trace(cfg, events, seed=1, **kw):
    """Two bit-identical trainers (same seeds, same plans): one healthy,
    one with a deterministic churn trace; returns both plus results."""
    base_net, trace_net = make_net(seed), make_net(seed)
    mbs = make_shard(cfg, seed).microbatches()
    base = RuntimeTrainer(cfg, base_net, lr=3e-3, seed=0,
                          churn_model=TraceChurn([]), **kw)
    dn = base_net.data_nodes()[0].id
    rb = base.iteration({dn: mbs})
    tr = RuntimeTrainer(cfg, trace_net, lr=3e-3, seed=0,
                        churn_model=TraceChurn(events(base)), **kw)
    rt = tr.iteration({dn: mbs})
    return base, rb, tr, rt


# ---------------------------------------------------------------------------
# Stage-local recovery: the paper's central claim, counted in dispatches
# ---------------------------------------------------------------------------

def test_backward_crash_replays_exactly_one_stage():
    """Sec. V-D: a backward crash is repaired by replaying the crashed
    stage's VJP from the stored upstream activation — the dispatch
    counters must show exactly one extra stage-level dispatch per
    replay, never a full-pipeline recompute."""
    cfg = tiny_cfg()

    def events(base):
        # stage-1 relay of the first completed chain; with S=2 its
        # backward visit happens at t=0.75 on the normalized clock, so
        # a crash at 0.6 hits after its forward work is done
        relay = base.last_resolution.completed[0].chain[2]
        events.relay = relay
        return [(0, "crash", relay, 0.6)]

    base, rb, tr, rt = run_with_trace(cfg, events)
    relay = events.relay
    hit = sum(1 for j in base.last_resolution.completed
              if j.chain[2] == relay)
    assert hit >= 1
    assert rt.completed == rt.launched      # every microbatch repaired
    assert rt.bwd_replays == hit
    assert rt.fwd_recomputes == 0
    b, t = base.stages, tr.stages
    # exactly one extra stage dispatch per replay, at the crashed stage
    assert t.bwd_calls[1] - b.bwd_calls[1] == hit
    assert t.bwd_calls[0] == b.bwd_calls[0]
    assert t.fwd_calls == b.fwd_calls
    S = tr.net.num_stages
    extra = t.stage_dispatches - b.stage_dispatches
    assert extra == hit                     # not hit * (2 * S): stage-local
    assert extra < 2 * S * hit
    # recovery must be numerically invisible: same loss trajectory
    assert rt.loss == rb.loss


def test_backward_crash_replay_counted_on_unbatched_path():
    """The per-microbatch (unbatched) path pays the same real lost-work
    dispatches as the batched one."""
    cfg = tiny_cfg()

    def events(base):
        relay = base.last_resolution.completed[0].chain[2]
        events.relay = relay
        return [(0, "crash", relay, 0.6)]

    base, rb, tr, rt = run_with_trace(cfg, events,
                                      batch_microbatches=False)
    hit = sum(1 for j in base.last_resolution.completed
              if j.chain[2] == events.relay)
    assert rt.bwd_replays == hit >= 1
    b, t = base.stages, tr.stages
    assert t.bwd_calls[1] - b.bwd_calls[1] == hit
    assert t.fwd_calls == b.fwd_calls
    assert rt.loss == rb.loss


def test_forward_crash_recomputes_exactly_one_stage():
    """A forward crash reroutes and recomputes only the crashed stage
    from the stored input activation."""
    cfg = tiny_cfg()

    def events(base):
        relay = base.last_resolution.completed[0].chain[1]   # stage 0
        events.relay = relay
        return [(0, "crash", relay, 0.1)]    # dead before fwd visit (0.25)

    base, rb, tr, rt = run_with_trace(cfg, events)
    relay = events.relay
    hit = sum(1 for j in base.last_resolution.completed
              if j.chain[1] == relay)
    assert hit >= 1
    assert rt.completed == rt.launched
    assert rt.fwd_recomputes == hit
    assert rt.bwd_replays == 0
    b, t = base.stages, tr.stages
    assert t.fwd_calls[0] - b.fwd_calls[0] == hit
    assert t.fwd_calls[1] == b.fwd_calls[1]
    assert t.bwd_calls == b.bwd_calls
    assert rt.loss == rb.loss
    # the repaired chains no longer route through the dead relay
    for job in tr.last_resolution.completed:
        assert job.chain[1] != relay


# ---------------------------------------------------------------------------
# Requeue-instead-of-drop (satellite: executor drop semantics)
# ---------------------------------------------------------------------------

def _fixed_policy_net():
    """2 stages x 2 relays, 1 data node: ids 0=dn, 1-2=stage0, 3-4=stage1."""
    return geo_distributed_network(
        num_stages=2, relay_capacities=[1, 1, 1, 1], num_data_nodes=1,
        data_capacity=2, rng=np.random.default_rng(7))


def test_requeue_onto_another_chain_instead_of_drop():
    """A policy with no reroute (FixedPolicy always fails recovery)
    used to silently drop the microbatch; the runtime now requeues it
    onto another planned complete-flow chain from the same data node."""
    cfg = tiny_cfg()
    net = _fixed_policy_net()
    paths = [[0, 1, 3, 0], [0, 2, 4, 0]]
    tr = RuntimeTrainer(cfg, net, lr=3e-3, seed=0,
                        policy=FixedPolicy(net, paths),
                        churn_model=TraceChurn([(0, "crash", 1, 0.1)]))
    mbs = make_shard(cfg, seed=3).microbatches()[:1]
    r = tr.iteration({0: mbs})
    assert r.launched == 1
    assert r.dropped == 0
    assert r.completed == 1
    assert r.rerouted == 1 and r.requeued == 1
    # the job adopted the second chain
    assert tr.last_resolution.completed[0].chain == [0, 2, 4, 0]


def test_drop_only_when_no_live_chain_exists():
    cfg = tiny_cfg()
    net = _fixed_policy_net()
    paths = [[0, 1, 3, 0], [0, 2, 4, 0]]
    tr = RuntimeTrainer(cfg, net, lr=3e-3, seed=0,
                        policy=FixedPolicy(net, paths),
                        churn_model=TraceChurn(
                            [(0, "crash", 1, 0.1), (0, "crash", 2, 0.1)]))
    mbs = make_shard(cfg, seed=3).microbatches()[:1]
    r = tr.iteration({0: mbs})
    assert r.launched == 1
    assert r.completed == 0
    assert r.dropped == 1
    assert r.requeued == 0


# ---------------------------------------------------------------------------
# Fig. 6 semantics under churn (satellite: churned convergence)
# ---------------------------------------------------------------------------

def test_churned_loss_strictly_decreases():
    """10% churn, fixed batch: the loss strictly decreases across all 8
    iterations and every iteration completes microbatches — repair, not
    restart, is what keeps the trajectory clean."""
    cfg = tiny_cfg()
    net = make_net(seed=0)
    mbs = make_shard(cfg, seed=0).microbatches()
    tr = DecentralizedTrainer(cfg, net, churn=0.1, lr=3e-3, seed=0)
    dn = net.data_nodes()[0].id
    reroutes = 0
    for _ in range(8):
        r = tr.iteration({dn: mbs})
        assert r.completed > 0
        reroutes += r.rerouted
    assert all(b < a for a, b in zip(tr.losses, tr.losses[1:]))
    assert reroutes > 0        # churn actually exercised the repair path


def test_churned_microbatch_grads_match_centralized():
    """Every completed microbatch's gradient under churn equals the
    centralized whole-model gradient for the same tokens — stage-local
    recompute is numerically invisible (Fig. 6's precondition)."""
    cfg = tiny_cfg()
    net = make_net(seed=1)
    mbs = make_shard(cfg, seed=1).microbatches()
    probe = RuntimeTrainer(cfg, make_net(seed=1), lr=3e-3, seed=0,
                           churn_model=TraceChurn([]))
    dn = net.data_nodes()[0].id
    probe.iteration({dn: mbs})
    crash_relay = probe.last_resolution.completed[0].chain[1]
    tr = RuntimeTrainer(cfg, net, lr=3e-3, seed=0,
                        batch_microbatches=False,
                        record_microbatch_grads=True,
                        churn_model=TraceChurn(
                            [(0, "crash", crash_relay, 0.1)]))
    r = tr.iteration({dn: mbs})
    assert r.fwd_recomputes > 0            # the repair path really ran
    assert r.completed == r.launched
    assert len(tr.last_microbatch_grads) == r.completed

    S = net.num_stages
    ref = RuntimeTrainer(cfg, make_net(seed=1), lr=3e-3, seed=0,
                         churn_model=TraceChurn([]))   # pre-update params

    def full(head_p, stage_ps, tokens, labels):
        x = embed_fn(head_p, tokens)
        for s in range(S):
            x = stage_forward(stage_ps[s], x, cfg)
        return loss_fn(head_p, x, labels, cfg)

    vg = jax.jit(jax.value_and_grad(full, argnums=(0, 1)))
    for idx, g_head, g_stages in tr.last_microbatch_grads:
        mb = mbs[idx]
        _, (gh, gs) = vg(ref.head_params[dn], ref.stage_params,
                         jnp.asarray(mb["tokens"]),
                         jnp.asarray(mb["labels"]))
        for a, b in zip(jax.tree.leaves((g_head, g_stages)),
                        jax.tree.leaves((gh, list(gs)))):
            a = np.asarray(a, np.float64)
            b = np.asarray(b, np.float64)
            scale = np.abs(b).max() + 1e-12
            assert np.abs(a - b).max() <= 1e-5 * scale


# ---------------------------------------------------------------------------
# Checkpoint plumbing (tentpole: unified churn/checkpoint path)
# ---------------------------------------------------------------------------

def test_checkpoint_resume_round_trip(tmp_path):
    cfg = tiny_cfg()
    net = make_net(seed=4)
    mbs = make_shard(cfg, seed=4).microbatches()
    dn = net.data_nodes()[0].id
    tr = DecentralizedTrainer(cfg, net, churn=0.0, lr=3e-3, seed=0,
                              checkpoint_dir=str(tmp_path),
                              checkpoint_every=2)
    tr.iteration({dn: mbs})
    tr.iteration({dn: mbs})                  # snapshot written at step 2
    fresh = DecentralizedTrainer(cfg, make_net(seed=4), churn=0.0,
                                 lr=3e-3, seed=0)
    assert fresh.restore_checkpoint(str(tmp_path)) == 2
    for a, b in zip(jax.tree.leaves(fresh.stage_params),
                    jax.tree.leaves(tr.stage_params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(fresh.stage_opt),
                    jax.tree.leaves(tr.stage_opt)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # resumed training continues on the same trajectory
    r1 = tr.iteration({dn: mbs})
    r2 = fresh.iteration({dn: mbs})
    assert r1.loss == r2.loss


def test_rejoining_node_bootstraps_from_stage_snapshot(tmp_path):
    """Sec. V-E: a node that rejoins downloads its stage's snapshot
    (restore_stage) before re-entering the flow graph."""
    cfg = tiny_cfg()
    net = make_net(seed=5)
    mbs = make_shard(cfg, seed=5).microbatches()
    dn = net.data_nodes()[0].id
    relay = [n.id for n in net.nodes.values() if not n.is_data][0]
    trace = TraceChurn([(0, "crash", relay, 0.95),
                        (2, "rejoin", relay)])
    tr = DecentralizedTrainer(cfg, net, lr=3e-3, seed=0,
                              churn_model=trace,
                              checkpoint_dir=str(tmp_path),
                              checkpoint_every=1)
    for _ in range(3):
        tr.iteration({dn: mbs})
    assert tr.joins_bootstrapped == 1
    assert net.nodes[relay].alive
