"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see 1 CPU device;
only launch/dryrun.py forces 512 host devices."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
