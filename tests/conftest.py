"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see 1 CPU device;
only launch/dryrun.py forces 512 host devices.

``runtime_env`` is the session-cached JAX model fixture: it warms the
process-wide kernel/param caches (`repro.core.runtime.cache`) for the
tiny runtime config once, so every runtime-involving test (and the
scenario harness's runtime leg, which keys the same caches through the
trainers) reuses the compiled stage kernels instead of recompiling.
"""
import dataclasses

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def runtime_env():
    """Session-cached stage kernels + initial params for the tiny
    runtime config (same ``ModelConfig`` as tests/test_runtime.py's
    ``tiny_cfg``, so both files share one cache entry)."""
    from repro.configs import get_config
    from repro.core.runtime import cache

    cfg = dataclasses.replace(
        get_config("gwtf-llama-300m").reduced(num_layers=4, d_model=128),
        vocab_size=256)
    stages = 2
    kernels = cache.kernels(cfg, donate=False)
    params = cache.initial_params(cfg, stages, 0)
    return {"cfg": cfg, "stages": stages, "kernels": kernels,
            "params": params}
