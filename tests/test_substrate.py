"""Substrate layers: optimizer, data pipeline, checkpointing, sharding."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import store
from repro.data.pipeline import DataConfig, DataNodeShard, SyntheticCorpus
from repro.optim.adamw import AdamW, SGD
from repro.parallel.sharding import ShardingRules, shard, use_rules


class TestAdamW:
    def test_quadratic_convergence(self):
        opt = AdamW(lr=0.1, weight_decay=0.0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = opt.init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}          # d/dw w^2
            params, state = opt.update(grads, state, params)
        assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2

    def test_grad_clip(self):
        opt = AdamW(lr=1e-3, grad_clip=1.0)
        params = {"w": jnp.zeros(3)}
        state = opt.init(params)
        p1, _ = opt.update({"w": jnp.full(3, 1e6)}, state, params)
        assert np.all(np.isfinite(np.asarray(p1["w"])))

    def test_bf16_params_f32_moments(self):
        opt = AdamW(lr=1e-2)
        params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
        state = opt.init(params)
        assert state.m["w"].dtype == jnp.float32
        new_p, _ = opt.update({"w": jnp.ones((4, 4), jnp.bfloat16)},
                              state, params)
        assert new_p["w"].dtype == jnp.bfloat16

    def test_sgd_descends(self):
        opt = SGD(lr=0.1)
        params = jnp.array([4.0])
        state = opt.init(params)
        for _ in range(50):
            params, state = opt.update(2 * params, state, params)
        assert abs(float(params[0])) < 1e-3


class TestData:
    def test_deterministic(self):
        a = SyntheticCorpus(100, seed=3).sample(50)
        b = SyntheticCorpus(100, seed=3).sample(50)
        np.testing.assert_array_equal(a, b)

    def test_bigram_structure(self):
        """Sticky successor structure must dominate: P(succ|prev) >> uniform."""
        c = SyntheticCorpus(50, seed=0, stickiness=0.8)
        toks = c.sample(20000)
        hits = np.mean(toks[1:] == c.successor[toks[:-1]])
        assert hits > 0.5

    def test_microbatch_shapes(self):
        dc = DataConfig(vocab_size=64, seq_len=16, batch_size=8,
                        microbatch_size=2, seed=0)
        mbs = DataNodeShard(dc, 0, 1).microbatches()
        assert len(mbs) == 4
        for mb in mbs:
            assert mb["tokens"].shape == (2, 16)
            assert mb["labels"].shape == (2, 16)

    def test_shards_differ(self):
        dc = DataConfig(vocab_size=64, seq_len=16, batch_size=4,
                        microbatch_size=2, seed=0)
        a = DataNodeShard(dc, 0, 2).next_batch()["tokens"]
        b = DataNodeShard(dc, 1, 2).next_batch()["tokens"]
        assert not np.array_equal(a, b)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "b": {"c": jnp.ones(4, jnp.bfloat16)}}
        path = str(tmp_path / "ckpt.npz")
        store.save(path, tree, step=17)
        restored, step = store.restore(path, tree)
        assert step == 17
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))
        assert restored["b"]["c"].dtype == jnp.bfloat16

    def test_shape_mismatch_raises(self, tmp_path):
        path = str(tmp_path / "c.npz")
        store.save(path, {"a": jnp.zeros((2, 2))})
        with pytest.raises(ValueError):
            store.restore(path, {"a": jnp.zeros((3, 3))})

    def test_stage_checkpoints(self, tmp_path):
        p0 = {"w": jnp.ones((3, 3))}
        store.save_stage(str(tmp_path), 0, p0, step=5)
        r, step = store.restore_stage(str(tmp_path), 0, p0)
        assert step == 5
        np.testing.assert_array_equal(np.asarray(r["w"]), np.ones((3, 3)))


class TestShardingRules:
    def test_noop_without_rules(self):
        x = jnp.ones((4, 4))
        assert shard(x, "batch", "tp") is x

    def test_resolution(self):
        r = ShardingRules()
        assert r.resolve("tp") == "model"
        assert r.resolve("batch") == ("pod", "data")
        assert r.resolve(None) is None

    def test_param_spec_tree_names(self):
        from repro.parallel.sharding import param_spec_tree
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        params = {"blocks": {"attn": {"wq": jnp.zeros((4, 8, 16))},
                             "mlp": {"w_down": jnp.zeros((4, 16, 8))}},
                  "final_norm": {"scale": jnp.zeros(8)}}
        specs = param_spec_tree(params, ShardingRules(), mesh)
        wq = specs["blocks"]["attn"]["wq"].spec
        assert len(wq) == 3 and wq[0] is None     # stacked layer dim


@settings(max_examples=10, deadline=None)
@given(lr=st.floats(1e-4, 1e-1), steps=st.integers(5, 30))
def test_property_adamw_monotone_on_convex(lr, steps):
    """AdamW on f(w)=|w|^2 never diverges from a bounded start."""
    opt = AdamW(lr=lr, weight_decay=0.0, grad_clip=None)
    params = jnp.array([2.0])
    state = opt.init(params)
    for _ in range(steps):
        params, state = opt.update(2 * params, state, params)
    assert float(jnp.abs(params[0])) <= 2.0 + lr * 2
