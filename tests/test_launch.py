"""Launch layer: input specs, sharding spec trees, HLO analysis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.hlo_analysis import analyze_hlo, f32_legalization_bytes
from repro.launch.specs import (abstract_params, decode_cache_len,
                                input_specs)
from repro.models.config import INPUT_SHAPES
from repro.parallel.sharding import ShardingRules, param_spec_tree

ASSIGNED = [a for a in ARCH_IDS if not a.startswith("gwtf_")]


class TestInputSpecs:
    @pytest.mark.parametrize("arch", ASSIGNED)
    @pytest.mark.parametrize("shape", list(INPUT_SHAPES))
    def test_specs_are_abstract(self, arch, shape):
        cfg = get_config(arch)
        specs = input_specs(cfg, shape)
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)

    def test_train_shapes(self):
        cfg = get_config("tinyllama-1.1b")
        s = input_specs(cfg, "train_4k", grad_accum=8)
        assert s["tokens"].shape == (8, 32, 4096)
        assert s["labels"].shape == (8, 32, 4096)

    def test_audio_gets_embeds(self):
        cfg = get_config("musicgen-medium")
        s = input_specs(cfg, "train_4k")
        assert "embeds" in s and s["embeds"].shape == (256, 4096, 1536)
        assert "tokens" not in s

    def test_vlm_gets_vision(self):
        cfg = get_config("llama-3.2-vision-90b")
        s = input_specs(cfg, "prefill_32k")
        assert s["vision"].shape == (32, 1601, 7680)

    def test_long_decode_uses_window_cache(self):
        cfg = get_config("gemma-7b")
        assert decode_cache_len(cfg, INPUT_SHAPES["long_500k"]) == 4096
        assert decode_cache_len(cfg, INPUT_SHAPES["decode_32k"]) == 32768
        s = input_specs(cfg, "long_500k")
        assert s["cache"]["attn"]["k"].shape[-2] == 4096

    def test_ssm_decode_cache_is_state(self):
        cfg = get_config("mamba2-130m")
        s = input_specs(cfg, "long_500k")
        assert "attn" not in s["cache"]
        assert s["cache"]["ssm"]["ssm"].shape[-1] == cfg.ssm_state

    @pytest.mark.parametrize("arch", ASSIGNED)
    def test_abstract_params_match_analytic_count(self, arch):
        """eval_shape param count within 2% of the analytic formula."""
        cfg = get_config(arch)
        params = abstract_params(cfg)
        n = sum(np.prod(l.shape) for l in jax.tree.leaves(params))
        expected = cfg.param_count()
        assert abs(n - expected) / expected < 0.02, (n, expected)


class TestParamSpecs:
    def test_fsdp_tp_2d_sharding(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        cfg = get_config("tinyllama-1.1b")
        params = abstract_params(cfg)
        specs = param_spec_tree(params, ShardingRules(), mesh)
        wq = specs["blocks"]["attn"]["wq"].spec
        assert wq[-2:] == ("data", "model")      # (fsdp, tp)
        wo = specs["blocks"]["attn"]["wo"].spec
        assert wo[-2:] == ("model", "data")      # row-parallel
        assert tuple(specs["final_norm"]["scale"].spec) in ((), (None,))

    def test_moe_expert_weights(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        cfg = get_config("granite-moe-3b-a800m")
        params = abstract_params(cfg)
        specs = param_spec_tree(params, ShardingRules(), mesh)
        wg = specs["blocks"]["moe"]["w_gate"].spec
        # (L, E, D, F) -> (None, expert=None, fsdp, tp)
        assert wg[-2:] == ("data", "model")
        assert wg[0] is None and wg[1] is None

    def test_indivisible_dims_dropped(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        params = {"attn": {"wq": jnp.zeros((2, 7, 13))}}   # nothing divides
        # mesh sizes are 1 so everything divides; use a fake bigger mesh
        # by checking the rule path instead
        specs = param_spec_tree(params, ShardingRules(), mesh)
        assert len(specs["attn"]["wq"].spec) == 3


class TestHLOAnalysis:
    def test_nested_scan_multiplier(self):
        L1, L2, D = 3, 5, 16

        def f(w, x):
            def outer(c, wl):
                def inner(c2, _):
                    return jnp.tanh(c2 @ wl), None
                c, _ = jax.lax.scan(inner, c, None, length=L2)
                return c, None
            y, _ = jax.lax.scan(outer, x, w)
            return y

        c = jax.jit(f).lower(jnp.zeros((L1, D, D)),
                             jnp.zeros((2, D))).compile()
        costs = analyze_hlo(c.as_text())
        expect = L1 * L2 * 2 * 2 * D * D
        assert abs(costs.dot_flops - expect) / expect < 0.01

    def test_collective_counting(self):
        import os
        devs = jax.devices()
        if len(devs) < 2:
            pytest.skip("needs >1 device")

    def test_f32_legalization_detection(self):
        text = """
ENTRY %main (p: bf16[1000,100000]) -> f32[1000,100000] {
  %p = bf16[1000,100000]{1,0} parameter(0)
  ROOT %c = f32[1000,100000]{1,0} convert(%p)
}
"""
        assert f32_legalization_bytes(text, min_bytes=1000) == 4e8

    def test_empty_text(self):
        costs = analyze_hlo("")
        assert costs.dot_flops == 0
