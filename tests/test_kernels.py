"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

Kernels run in interpret mode on CPU (the TPU lowering is the target;
interpret executes the same kernel body in Python).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.ref import attention_reference, ssd_scan_reference
from repro.kernels.ssd_scan import ssd_scan_bhsp

TOL = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S", [128, 256, 512])
@pytest.mark.parametrize("D", [64, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(S, D, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(S + D), 3)
    BH = 2
    q = jax.random.normal(k1, (BH, S, D), dtype)
    k = jax.random.normal(k2, (BH, S, D), dtype)
    v = jax.random.normal(k3, (BH, S, D), dtype)
    out = flash_attention_bhsd(q, k, v, causal=True, block_q=64, block_k=64,
                               interpret=True)
    ref = attention_reference(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("window", [32, 64, 128])
def test_flash_attention_window(window):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(window), 3)
    BH, S, D = 2, 256, 64
    q = jax.random.normal(k1, (BH, S, D))
    k = jax.random.normal(k2, (BH, S, D))
    v = jax.random.normal(k3, (BH, S, D))
    out = flash_attention_bhsd(q, k, v, causal=True, window=window,
                               block_q=64, block_k=64, interpret=True)
    ref = attention_reference(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("blocks", [(64, 128), (128, 64), (256, 256)])
def test_flash_attention_block_shapes(blocks):
    bq, bk = blocks
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    BH, S, D = 1, 256, 64
    q = jax.random.normal(k1, (BH, S, D))
    k = jax.random.normal(k2, (BH, S, D))
    v = jax.random.normal(k3, (BH, S, D))
    out = flash_attention_bhsd(q, k, v, block_q=bq, block_k=bk,
                               interpret=True)
    ref = attention_reference(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_gqa_wrapper():
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    B, S, H, KH, D = 2, 128, 8, 2, 64
    q = jax.random.normal(k1, (B, S, H, D))
    k = jax.random.normal(k2, (B, S, KH, D))
    v = jax.random.normal(k3, (B, S, KH, D))
    out = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    kr = jnp.repeat(k, H // KH, axis=2)
    vr = jnp.repeat(v, H // KH, axis=2)
    ref = attention_reference(
        q.transpose(0, 2, 1, 3).reshape(B * H, S, D),
        kr.transpose(0, 2, 1, 3).reshape(B * H, S, D),
        vr.transpose(0, 2, 1, 3).reshape(B * H, S, D))
    ref = ref.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,chunk", [(128, 32), (256, 64), (256, 128)])
@pytest.mark.parametrize("N", [16, 64])
def test_ssd_scan_shapes(S, chunk, N):
    key = jax.random.PRNGKey(S + N)
    ks = jax.random.split(key, 5)
    B, H, P = 2, 3, 32
    x = jax.random.normal(ks[0], (B, H, S, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, H, S)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y, hf = ssd_scan_bhsp(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    yr, hfr = ssd_scan_reference(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hfr),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_dtypes(dtype):
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 5)
    B, H, S, P, N = 1, 2, 128, 16, 16
    x = jax.random.normal(ks[0], (B, H, S, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, H, S))).astype(jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, N), dtype)
    Cm = jax.random.normal(ks[4], (B, S, N), dtype)
    y, hf = ssd_scan_bhsp(x, dt, A, Bm, Cm, chunk=64, interpret=True)
    yr, hfr = ssd_scan_reference(x, dt, A, Bm, Cm)
    tol = TOL[dtype]
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=tol["rtol"] * 10, atol=tol["atol"] * 10)


def test_ssd_model_chunked_matches_sequential():
    """The model's chunked SSD (jnp twin of the kernel) matches the
    sequential recurrence for several chunk sizes."""
    from repro.models.ssm import ssd_chunked, ssd_reference
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 5)
    b, S, H, P, N = 2, 192, 3, 16, 8
    x = jax.random.normal(ks[0], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (b, S, N))
    Cm = jax.random.normal(ks[4], (b, S, N))
    y_ref, h_ref = ssd_reference(x, dt, A, Bm, Cm)
    for chunk in (16, 48, 96, 192):
        y, h = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                                   rtol=3e-4, atol=3e-4)
