"""Pod-slice scheduling: the paper's flow algorithm on the TPU target."""
import pytest

from repro.configs import get_config
from repro.core.podmap import (carve_pod, ici_hop_distance, lose_slice,
                               pod_flow_network, schedule_pipelines)


def test_carve_pod():
    slices = carve_pod((16, 16), (4, 4))
    assert len(slices) == 16
    assert all(s.chips == 16 for s in slices)


def test_torus_distance_symmetric_and_wrapping():
    slices = carve_pod((16, 16), (4, 4))
    a, b = slices[0], slices[3]          # opposite edge: torus wrap
    assert ici_hop_distance(a, b) == ici_hop_distance(b, a)
    # wrap-around shorter than straight-line
    assert ici_hop_distance(a, b) <= 12


def test_schedule_builds_flows():
    cfg = get_config("gemma-7b")
    proto, net = schedule_pipelines(cfg, num_stages=5, seed=0)
    flows = proto.complete_flows()
    assert len(flows) >= 4
    for f in flows:
        assert f[0] == f[-1] == 0                # back to the data slice
        stages = [net.nodes[n].stage for n in f[1:-1]]
        assert stages == sorted(stages)          # stage order

def test_slice_preemption_repair():
    cfg = get_config("gemma-7b")
    proto, net = schedule_pipelines(cfg, num_stages=5, seed=1)
    before = proto.complete_flows()
    victim = before[0][2]
    after = lose_slice(proto, net, victim)
    assert after, "no flows survived repair"
    assert all(victim not in f for f in after)


def test_data_slice_loss_rejected():
    cfg = get_config("tinyllama-1.1b")
    proto, net = schedule_pipelines(cfg, num_stages=3, seed=2)
    with pytest.raises(ValueError):
        lose_slice(proto, net, 0)


def test_costs_scale_with_model():
    small = get_config("tinyllama-1.1b")
    big = get_config("gemma-7b")
    n_small = pod_flow_network(small, num_stages=5, microbatch_tokens=4096)
    n_big = pod_flow_network(big, num_stages=5, microbatch_tokens=4096)
    # bigger model -> higher compute cost per slice
    assert (n_big.nodes[1].compute_cost > n_small.nodes[1].compute_cost)
