"""Beyond-fail-stop fault layer: adversarial fault models, the shared
fault timeline, and the reputation/quarantine pricing in Eq. 1.

The cross-layer contracts (sim timeline == runtime timeline, exact
screen precision/recall) are enforced by the scenario harness
(`tests/test_scenarios.py::TestAdversarialTier`); this file unit-tests
the building blocks plus the runtime gradient screen end-to-end on the
contamination regimes the harness cannot sweep cheaply.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.flow.graph import (QUARANTINE_THRESHOLD, REPORT_DROP,
                                   REPUTATION_FLOOR,
                                   geo_distributed_network)
from repro.core.sim.faults import (AdversarialPlan, ComposedChurn,
                                   CorruptGradientChurn, FlakyLinkChurn,
                                   StragglerChurn, adversarial_plan)
from repro.core.sim.timeline import (CROSS_LAYER_FAULTS, FaultRecord,
                                     FaultTimeline, record_injections)


# ---------------------------------------------------------------------------
# Fault-model construction + window semantics (numpy-only)
# ---------------------------------------------------------------------------

class TestFaultModelValidation:
    def test_straggler_rejects_speedups(self):
        with pytest.raises(ValueError, match=">= 1"):
            StragglerChurn({3: 0.5})

    def test_straggler_rejects_unknown_nodes(self):
        with pytest.raises(ValueError, match="unknown node 9"):
            StragglerChurn({9: 2.0}, known_ids=[0, 1, 2])
        with pytest.raises(ValueError, match="unknown node 9"):
            StragglerChurn(hangs=[9], known_ids=[0, 1, 2])

    def test_corrupt_rejects_bad_mode_scale_empty(self):
        with pytest.raises(ValueError, match="unknown corruption mode"):
            CorruptGradientChurn([1], mode="invert")
        with pytest.raises(ValueError, match="scale must be positive"):
            CorruptGradientChurn([1], scale=0.0)
        with pytest.raises(ValueError, match=">= 1 node"):
            CorruptGradientChurn([])

    def test_flaky_rejects_bad_probability(self):
        with pytest.raises(ValueError, match=r"\[0, 1\]"):
            FlakyLinkChurn(1.5)


class TestFaultWindows:
    def test_window_is_half_open(self):
        m = StragglerChurn({1: 2.0}, at_iteration=1, duration=2)
        assert [m.active(i) for i in range(5)] == \
               [False, True, True, False, False]
        assert adversarial_plan(m, 0) is None
        assert adversarial_plan(m, 1).slow == {1: 2.0}
        assert adversarial_plan(m, 3) is None

    def test_duration_zero_means_forever(self):
        m = CorruptGradientChurn([2], at_iteration=1)
        assert not m.active(0)
        assert m.active(10_000)

    def test_sample_draws_nothing(self):
        """Adversarial models publish plans via the side channel only;
        their sample() crashes nobody and never touches the shared
        churn RNG (that is what keeps fail-stop RNG streams identical
        with and without an adversarial clause)."""
        for m in (StragglerChurn({1: 2.0}), CorruptGradientChurn([1]),
                  FlakyLinkChurn(0.3)):
            assert m.sample(None) == {}


class TestPlanComposition:
    def test_merge_compounds_and_unions(self):
        a = AdversarialPlan(slow={1: 2.0, 2: 3.0}, hung=frozenset({4}),
                            corrupt={5: ("perturb", 1.0, 7)})
        b = AdversarialPlan(slow={1: 4.0}, hung=frozenset({6}),
                            corrupt={5: ("zero", 2.0, 9)},
                            flaky=(FlakyLinkChurn(0.1),))
        m = AdversarialPlan.merge([a, None, b])
        assert m.slow == {1: 8.0, 2: 3.0}        # slowdowns compound
        assert m.hung == {4, 6}
        assert m.corrupt[5] == ("perturb", 1.0, 7)   # first model wins
        assert m.flaky_episodes == 1

    def test_merge_of_nothing_is_none(self):
        assert AdversarialPlan.merge([]) is None
        assert AdversarialPlan.merge([None, AdversarialPlan()]) is None

    def test_composed_churn_exposes_merged_plan(self):
        model = ComposedChurn([
            StragglerChurn({1: 2.0}),
            CorruptGradientChurn([3], mode="perturb", seed=5),
            FlakyLinkChurn(0.2, at_iteration=1),
        ])
        p0 = adversarial_plan(model, 0)
        assert p0.slow == {1: 2.0}
        assert set(p0.corrupt) == {3}
        assert p0.flaky_episodes == 0           # window not open yet
        assert adversarial_plan(model, 1).flaky_episodes == 1


class TestFlakyDeterminism:
    def test_counter_based_coins_are_order_independent(self):
        m = FlakyLinkChurn(0.5, seed=3)
        keys = [(0, mb, d, pos, att) for mb in range(4)
                for d in ("fwd", "bwd") for pos in range(3)
                for att in range(2)]
        first = [m.leg_ok(*k) for k in keys]
        # evaluate in reverse order, interleaved with unrelated draws:
        # every decision must be a pure function of its key
        rng = np.random.default_rng(0)
        second = []
        for k in reversed(keys):
            rng.uniform()
            second.append(m.leg_ok(*k))
        assert first == list(reversed(second))
        assert 0 < sum(first) < len(first)      # p=0.5 actually flips

    def test_probability_edges(self):
        assert FlakyLinkChurn(0.0).leg_ok(0, 0, "fwd", 0, 0)
        assert not FlakyLinkChurn(1.0).leg_ok(0, 0, "fwd", 0, 0)

    def test_attempts_reflip_independently(self):
        m = FlakyLinkChurn(0.5, seed=11)
        flips = {m.leg_ok(0, 0, "fwd", 0, att) for att in range(32)}
        assert flips == {True, False}


# ---------------------------------------------------------------------------
# Shared fault timeline (numpy-only)
# ---------------------------------------------------------------------------

class TestTimeline:
    def test_rejects_unknown_vocabulary(self):
        with pytest.raises(ValueError, match="unknown fault class"):
            FaultRecord(0, "gremlin", "injection")
        with pytest.raises(ValueError, match="unknown record kind"):
            FaultRecord(0, "crash", "suspicion")

    def test_comparable_counts_excludes_engine_local_kinds(self):
        tl = FaultTimeline()
        tl.record(0, "flaky_link", "injection")
        tl.record(0, "flaky_link", "detection", 4)
        tl.record(0, "crash", "injection", 1)
        tl.record(0, "crash", "detection", 1)
        tl.record(0, "straggler", "detection", 2)
        tl.record(1, "corrupt_gradient", "repair", 3)
        cmp = tl.comparable_counts()
        # all injections stay; detection/repair only for the
        # iteration-granular cross-layer faults
        assert cmp == {
            (0, "flaky_link", "injection"): 1,
            (0, "crash", "injection"): 1,
            (0, "straggler", "detection"): 1,
            (1, "corrupt_gradient", "repair"): 1,
        }
        assert set(CROSS_LAYER_FAULTS) == {"straggler", "corrupt_gradient"}

    def test_record_injections_is_deterministic(self):
        plan = AdversarialPlan(slow={5: 2.0}, hung=frozenset({5, 7}),
                               corrupt={2: ("zero", 1.0, 0)},
                               flaky=(FlakyLinkChurn(0.1),))
        a, b = FaultTimeline(), FaultTimeline()
        for tl in (a, b):
            record_injections(tl, 3, {9: 0.5, 1: 0.25}, plan)
        assert a.records == b.records
        assert a.counts() == {
            (3, "crash", "injection"): 2,
            (3, "straggler", "injection"): 2,   # slow ∪ hung = {5, 7}
            (3, "corrupt_gradient", "injection"): 1,
            (3, "flaky_link", "injection"): 1,
        }
        empty = FaultTimeline()
        record_injections(empty, 0, {}, None)
        assert len(empty) == 0


# ---------------------------------------------------------------------------
# Reputation pricing / quarantine / rehabilitation (flow layer, numpy-only)
# ---------------------------------------------------------------------------

def _net():
    return geo_distributed_network(
        num_stages=2, relay_capacities=[2] * 6, num_data_nodes=1,
        data_capacity=4, rng=np.random.default_rng(0))


class TestReputationPricing:
    def test_trivial_state_is_bit_identical_and_cached(self):
        net, fresh = _net(), _net()
        cm = net.cost_matrix()
        assert net.cost_matrix() is cm          # same cached object
        assert not net.reputation_active()
        np.testing.assert_array_equal(cm, fresh.cost_matrix())

    def test_report_prices_only_the_accused_column(self):
        net = _net()
        base = net.cost_matrix().copy()
        v0 = net.cost_version
        net.report_fault(3)
        assert net.cost_version > v0            # planners must refresh
        rep = net.reputation(3)
        assert rep == pytest.approx(REPORT_DROP)
        cm = net.cost_matrix()
        expect_pen = net.reputation_weight * (1.0 / rep - 1.0)
        np.testing.assert_allclose(cm[:, 3] - base[:, 3], expect_pen)
        others = [j for j in range(cm.shape[1]) if j != 3]
        np.testing.assert_array_equal(cm[:, others], base[:, others])

    def test_quarantine_threshold_and_floor(self):
        net = _net()
        net.set_reputation(3, QUARANTINE_THRESHOLD)
        assert not net.quarantined(3)           # threshold is exclusive
        net.report_fault(3)                     # 0.5 * 0.2 = 0.1
        assert net.quarantined(3)
        for _ in range(50):
            net.report_fault(3)
        assert net.reputation(3) == REPUTATION_FLOOR
        assert np.isfinite(net.cost_matrix()).all()

    def test_single_report_already_quarantines(self):
        net = _net()
        net.report_fault(3)
        assert net.reputation(3) == pytest.approx(REPORT_DROP)
        assert net.quarantined(3)               # 0.2 < 0.5

    def test_decay_rehabilitates_back_to_exact_trivial(self):
        net = _net()
        base = net.cost_matrix().copy()
        net.report_fault(3)
        net.report_fault(3)
        assert net.quarantined(3)
        saw_release = False
        for _ in range(100):
            net.decay_reputations()
            if not net.quarantined(3):
                saw_release = True
        assert saw_release
        # full rehabilitation snaps storage back to None: pricing is
        # the *exact* trivial arithmetic again, not merely close to it
        assert not net.reputation_active()
        np.testing.assert_array_equal(net.cost_matrix(), base)

    def test_quarantine_survives_crash_and_rejoin(self):
        """A node that rejoins mid-quarantine is still distrusted:
        reputation tracks identity, not liveness, so a byzantine relay
        cannot launder its record by bouncing."""
        net = _net()
        net.report_fault(3)
        net.report_fault(3)
        net.kill_node(3)
        assert not net.nodes[3].alive
        net.nodes[3].alive = True               # rejoin
        assert net.quarantined(3)
        assert net.reputation(3) == pytest.approx(REPORT_DROP ** 2)

    def test_set_reputation_validates(self):
        net = _net()
        with pytest.raises(ValueError, match="reputation"):
            net.set_reputation(3, 0.0)
        with pytest.raises(ValueError, match="reputation"):
            net.set_reputation(3, 1.5)
        net.set_reputation(3, 0.3)
        assert net.quarantined(3)


# ---------------------------------------------------------------------------
# Runtime gradient screen end-to-end (real compute)
# ---------------------------------------------------------------------------

def _byz_trainer(churn_model=None, grad_screen=None, caps=None):
    from repro.configs import get_config
    from repro.core.runtime.trainer import RuntimeTrainer

    cfg = dataclasses.replace(
        get_config("gwtf-llama-300m").reduced(num_layers=2, d_model=32),
        vocab_size=512)
    net = (geo_distributed_network(
        num_stages=2, relay_capacities=caps, num_data_nodes=1,
        data_capacity=4, rng=np.random.default_rng(0))
        if caps else _net())
    if churn_model is not None and not isinstance(churn_model, ComposedChurn):
        churn_model = churn_model(net)
    return RuntimeTrainer(cfg, net, lr=1e-3, seed=0,
                          churn_model=churn_model, grad_screen=grad_screen)


def _batches(batch_size: int = 4):
    from repro.data.pipeline import DataConfig, DataNodeShard

    dc = DataConfig(vocab_size=512, seq_len=16, batch_size=batch_size,
                    microbatch_size=1, seed=3)
    return {0: DataNodeShard(dc, 0, 1).microbatches()}


class TestRuntimeGradientScreen:
    def test_screen_survives_half_contamination(self):
        """Node 2 carries 2 of the 4 planned chains — exactly 50%
        contamination, the regime where an interpolated median mixes
        honest and poisoned norms.  The lower-median screen must flag
        exactly the corrupt contributions, accuse only node 2, drive
        it into quarantine, and let decay rehabilitate it afterwards."""
        tr = _byz_trainer(
            churn_model=lambda net: CorruptGradientChurn(
                [2], mode="perturb", scale=1.0, seed=7,
                known_ids=net.nodes.keys()),
            grad_screen=None)                   # auto-on
        batches = _batches()
        ever_quarantined = False
        for _ in range(4):
            tr.iteration(batches)
            ever_quarantined = ever_quarantined or tr.net.quarantined(2)
        counts = tr.timeline.counts()
        det = {(it, n) for r in tr.timeline.records
               for it, n in [(r.iteration, r.node)]
               if r.fault == "corrupt_gradient" and r.kind == "detection"}
        assert counts.get((0, "corrupt_gradient", "detection"), 0) == 2
        assert {n for _, n in det} == {2}       # precision: only node 2
        assert ever_quarantined
        # decay rehabilitation: fault-free iterations (the plan routed
        # around node 2) lift its reputation back over the threshold
        assert not tr.net.quarantined(2)
        assert tr.net.reputation(2) > QUARANTINE_THRESHOLD

    def test_zero_mode_caught_below_half_contamination(self):
        """Deflation attacks (zeroed gradients) sort *below* the lower
        median, so at exactly half contamination the reference norm is
        itself poisoned and the screen goes blind by design (documented
        boundary).  Strictly below half — capacity 1 pins node 2 to a
        single chain of the 4, 25% contamination — the lower median
        stays honest and the norm floor catches the zeroed
        contribution."""
        tr = _byz_trainer(
            churn_model=lambda net: CorruptGradientChurn(
                [2], mode="zero", scale=1.0, seed=7,
                known_ids=net.nodes.keys()),
            grad_screen=None, caps=[2, 1, 2, 2, 2, 2])
        r = tr.iteration(_batches())
        assert r.grads_flagged == 1
        det_nodes = {rec.node for rec in tr.timeline.records
                     if rec.fault == "corrupt_gradient"
                     and rec.kind == "detection"}
        assert det_nodes == {2}
        assert tr.net.quarantined(2)

    def test_quarantine_reroutes_flow_off_corrupt_node(self):
        tr = _byz_trainer(
            churn_model=lambda net: CorruptGradientChurn(
                [2], mode="perturb", scale=1.0, seed=7,
                known_ids=net.nodes.keys()),
            grad_screen=None)
        batches = _batches()
        tr.iteration(batches)                   # detection + reports
        assert tr.net.quarantined(2)
        tr.iteration(batches)                   # replanned
        chains = tr.policy.protocol.complete_flows()
        assert all(2 not in chain[1:-1] for chain in chains)
        # ...and the screen consequently finds nothing more to flag
        assert tr.timeline.counts().get(
            (1, "corrupt_gradient", "detection"), 0) == 0

    def test_forced_screen_is_bit_identical_when_clean(self):
        """grad_screen=True defers aggregation until the screen has
        seen every contribution; with nothing flagged it must rebuild
        the same jnp.add chain in the same job order — losses
        bit-identical to the inline per-microbatch path.  (Both runs
        pin batch_microbatches=False: an enabled screen forces the
        per-microbatch path anyway, and the batched path associates
        floats differently by construction.)"""
        batches = _batches()
        losses = {}
        for screen in (False, True):
            tr = _byz_trainer(grad_screen=screen)
            tr.batch_microbatches = False
            rs = [tr.iteration(batches) for _ in range(2)]
            losses[screen] = [float(r.loss) for r in rs]
            assert tr.timeline.counts() == {}
            assert all(r.grads_flagged == 0 for r in rs)
        assert losses[False] == losses[True]

    def test_screen_off_lets_poison_through(self):
        tr = _byz_trainer(
            churn_model=lambda net: CorruptGradientChurn(
                [2], mode="perturb", scale=1.0, seed=7,
                known_ids=net.nodes.keys()),
            grad_screen=False)
        r = tr.iteration(_batches())
        assert r.grads_flagged == 0
        assert not tr.net.reputation_active()
        assert tr.timeline.counts(kinds=("detection",)) == {}
