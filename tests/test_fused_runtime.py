"""Fused residual-carrying dispatch, int8 activation codec, donation
gating, session kernel/param caches (the PR-7 runtime rework)."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.flow.graph import geo_distributed_network
from repro.core.runtime import cache
from repro.core.runtime.activations import (ActivationStore, Int8Codec,
                                            make_codec)
from repro.core.runtime.stages import StageCompute, _donate_supported
from repro.core.runtime.trainer import (CentralizedTrainer, RuntimeTrainer,
                                        auto_chunk)
from repro.core.sim.faults import TraceChurn
from repro.data.pipeline import DataConfig, DataNodeShard


def tiny_cfg():
    cfg = get_config("gwtf-llama-300m").reduced(num_layers=4, d_model=128)
    return dataclasses.replace(cfg, vocab_size=256)


def make_net(seed=0, stages=2):
    return geo_distributed_network(
        num_stages=stages, relay_capacities=[3] * (3 * stages),
        num_data_nodes=1, data_capacity=4,
        rng=np.random.default_rng(seed))


def make_mbs(cfg, seed=0):
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, batch_size=8,
                    microbatch_size=2, seed=seed)
    return DataNodeShard(dc, 0, 1).microbatches()


def tree_equal(a, b) -> bool:
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# Fused vs remat: bit-equality per stage and per trainer
# ---------------------------------------------------------------------------

def test_fused_forward_and_backward_bitwise_match_remat(rng):
    """Per-stage oracle: the fused dispatch's primal output equals the
    plain forward bitwise, and the backward from stored residuals
    equals the rematerialising backward bitwise — with the dispatch
    counters telling the two modes apart."""
    cfg = tiny_cfg()
    S = 2
    stage_p, _ = cache.initial_params(cfg, S, 0)
    sc = StageCompute(cfg, S)
    x = jnp.asarray(rng.normal(size=(4, 16, cfg.d_model)).astype(np.float32))
    g = jnp.asarray(rng.normal(size=(4, 16, cfg.d_model)).astype(np.float32))

    out_plain = sc.forward(0, stage_p[0], x)
    out_fused, resid = sc.forward_fused(0, stage_p[0], x)
    assert np.array_equal(np.asarray(out_plain), np.asarray(out_fused))

    dp_f, dx_f = sc.backward_from_residuals(0, resid, jnp.copy(g))
    dp_r, dx_r = sc.backward(0, stage_p[0], x, jnp.copy(g))
    assert tree_equal(dp_f, dp_r)
    assert np.array_equal(np.asarray(dx_f), np.asarray(dx_r))

    assert sc.fwd_calls[0] == 2          # plain + fused
    assert sc.bwd_calls[0] == 2          # residual + remat
    assert sc.remat_recomputes[0] == 1   # only the remat backward
    assert sc.stage_dispatches == 4


def test_fused_and_remat_trainers_bit_identical():
    """Trainer-level oracle: ``remat=True`` (the fallback) and the
    default fused path produce bit-identical loss trajectories and
    final parameters, while only the remat path recomputes forwards."""
    cfg = tiny_cfg()
    mbs = make_mbs(cfg)
    dn = make_net().data_nodes()[0].id
    fused = RuntimeTrainer(cfg, make_net(), lr=3e-3, seed=0,
                           churn_model=TraceChurn([]))
    remat = RuntimeTrainer(cfg, make_net(), lr=3e-3, seed=0,
                           churn_model=TraceChurn([]), remat=True)
    for _ in range(3):
        rf = fused.iteration({dn: mbs})
        rr = remat.iteration({dn: mbs})
        assert rf.loss == rr.loss
    assert fused.stages.snapshot()["fwd"] == remat.stages.snapshot()["fwd"]
    assert fused.stages.snapshot()["bwd"] == remat.stages.snapshot()["bwd"]
    assert fused.stages.remat_recompute_count == 0
    assert remat.stages.remat_recompute_count == sum(
        remat.stages.bwd_calls)
    assert tree_equal(fused.stage_params, remat.stage_params)
    assert tree_equal(fused.head_params, remat.head_params)
    # the fused path keeps residuals resident; remat only boundaries
    assert fused.last_store_peak_bytes > remat.last_store_peak_bytes


def test_zero_churn_fused_bit_identical_to_centralized():
    cfg = tiny_cfg()
    mbs = make_mbs(cfg)
    dn = make_net().data_nodes()[0].id
    rt = RuntimeTrainer(cfg, make_net(), lr=3e-3, seed=0,
                        churn_model=TraceChurn([]))
    cen = CentralizedTrainer(cfg, 2, lr=3e-3, seed=0)
    for _ in range(2):
        r = rt.iteration({dn: mbs})
        assert r.loss == cen.iteration(mbs)
    assert tree_equal(rt.stage_params, cen.stage_params)


# ---------------------------------------------------------------------------
# int8 codec
# ---------------------------------------------------------------------------

def test_int8_roundtrip_error_bound(rng):
    """Elementwise |x - dq(q(x))| <= scale/2 for per-tensor symmetric
    quantisation with round-to-nearest."""
    codec = Int8Codec()
    for shape, scale_mag in [((64, 32), 1.0), ((8, 128), 37.5),
                             ((100,), 1e-4), ((3, 5, 7), 1e3)]:
        x = jnp.asarray(
            (rng.normal(size=shape) * scale_mag).astype(np.float32))
        enc = codec.encode(x)
        dq = codec.decode(enc)
        scale = float(jnp.max(jnp.abs(x))) / 127.0
        err = np.abs(np.asarray(x) - np.asarray(dq))
        assert err.max() <= scale / 2 + 1e-7 * max(1.0, scale_mag)
    # degenerate: all-zero tensor survives (scale fallback, exact)
    z = jnp.zeros((4, 4), jnp.float32)
    assert np.array_equal(np.asarray(codec.decode(codec.encode(z))),
                          np.zeros((4, 4), np.float32))
    # non-float leaves pass through untouched
    ints = jnp.arange(10, dtype=jnp.int32)
    assert codec.encode(ints) is ints


def test_int8_store_shrinks_resident_bytes(rng):
    """Boundary activations AND residual trees shrink ~4x (>= 3x with
    the fp32 scale overhead)."""
    x = jnp.asarray(rng.normal(size=(8, 64, 128)).astype(np.float32))
    resid = {"a": x * 2, "b": jnp.asarray(
        rng.normal(size=(4, 32, 128)).astype(np.float32)),
        "ids": jnp.arange(8, dtype=jnp.int32)}
    fp = ActivationStore()
    q8 = ActivationStore(codec="int8")
    for store in (fp, q8):
        store.put(0, (0, 1), x)
        store.put_residuals(0, (0, 1), resid)
    assert fp.nbytes() / q8.nbytes() >= 3.0
    # round-trip through the store stays within the codec bound
    got = q8.stacked(0, (0, 1))
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    assert float(jnp.max(jnp.abs(got - x))) <= scale / 2 + 1e-7
    r = q8.residuals(0, (0, 1))
    assert np.array_equal(np.asarray(r["ids"]), np.arange(8))
    # drop releases both boundary and residuals
    q8.drop(0, (0, 1))
    assert len(q8) == 0 and q8.nbytes() == 0
    assert q8.peak_bytes > 0


def test_int8_trainer_close_to_fp_and_3x_smaller():
    cfg = tiny_cfg()
    mbs = make_mbs(cfg)
    dn = make_net().data_nodes()[0].id
    fp = RuntimeTrainer(cfg, make_net(), lr=3e-3, seed=0,
                        churn_model=TraceChurn([]))
    q8 = RuntimeTrainer(cfg, make_net(), lr=3e-3, seed=0,
                        churn_model=TraceChurn([]), activation_codec="int8")
    for _ in range(3):
        rf = fp.iteration({dn: mbs})
        rq = q8.iteration({dn: mbs})
    assert rf.store_peak_bytes / rq.store_peak_bytes >= 3.0
    assert np.isfinite(rq.loss)
    assert abs(rq.loss - rf.loss) < 0.25      # bounded fidelity cost
    assert q8.losses[-1] < q8.losses[0]       # still trains


def test_unknown_codec_rejected():
    with pytest.raises(ValueError, match="unknown activation codec"):
        make_codec("fp8")


# ---------------------------------------------------------------------------
# Recovery replays from residuals: zero forward recompute
# ---------------------------------------------------------------------------

def test_backward_crash_replays_from_residuals_no_forward_recompute():
    """A backward crash on the fused path is repaired from the stored
    residuals: the extra work is backward dispatches only — forward
    counters and remat recomputes stay at the healthy baseline."""
    cfg = tiny_cfg()
    mbs = make_mbs(cfg, seed=1)
    base = RuntimeTrainer(cfg, make_net(1), lr=3e-3, seed=0,
                          churn_model=TraceChurn([]))
    dn = make_net(1).data_nodes()[0].id
    rb = base.iteration({dn: mbs})
    relay = base.last_resolution.completed[0].chain[2]
    hit = sum(1 for j in base.last_resolution.completed
              if j.chain[2] == relay)
    tr = RuntimeTrainer(cfg, make_net(1), lr=3e-3, seed=0,
                        churn_model=TraceChurn([(0, "crash", relay, 0.6)]))
    rt = tr.iteration({dn: mbs})
    assert rt.completed == rt.launched
    assert rt.bwd_replays == hit >= 1
    assert rt.loss == rb.loss
    b, t = base.stages, tr.stages
    # zero forward recompute: pinned via stage_dispatches split
    assert t.fwd_calls == b.fwd_calls
    assert t.remat_recompute_count == b.remat_recompute_count == 0
    assert sum(t.bwd_calls) - sum(b.bwd_calls) == hit
    assert t.stage_dispatches - b.stage_dispatches == hit


# ---------------------------------------------------------------------------
# Donation gating
# ---------------------------------------------------------------------------

def test_donate_supported_gating():
    assert _donate_supported("cpu") is False
    for b in ("gpu", "cuda", "rocm", "tpu"):
        assert _donate_supported(b) is True
    # default reflects the live backend
    assert _donate_supported() == (jax.default_backend()
                                   in ("gpu", "cuda", "rocm", "tpu"))


def test_both_donation_branches_identical_numerics(rng):
    """Force both donation branches (CPU ignores donation but compiles
    the donated program): identical numerics, no use-after-donate."""
    cfg = tiny_cfg()
    stage_p, _ = cache.initial_params(cfg, 2, 0)
    x = jnp.asarray(rng.normal(size=(4, 16, cfg.d_model)).astype(np.float32))
    g0 = rng.normal(size=(4, 16, cfg.d_model)).astype(np.float32)
    results = {}
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")   # 'donation is not implemented'
        for donate in (False, True):
            sc = StageCompute(cfg, 2, donate=donate)
            assert sc.donate is donate
            out, resid = sc.forward_fused(0, stage_p[0], x)
            dp, dx = sc.backward_from_residuals(0, resid, jnp.asarray(g0))
            # residuals were NOT donated: a second replay (the crash
            # path) from the same stored residuals must still work
            dp2, dx2 = sc.backward_from_residuals(0, resid,
                                                  jnp.asarray(g0))
            assert tree_equal(dp, dp2) and tree_equal(dx, dx2)
            results[donate] = (out, dp, dx)
    for a, b in zip(results[False], results[True]):
        assert tree_equal(a, b)


# ---------------------------------------------------------------------------
# Session caches: shared kernels/params, no state leak across hits
# ---------------------------------------------------------------------------

def test_kernel_cache_shared_counters_isolated():
    cfg = tiny_cfg()
    sc1 = StageCompute(cfg, 2, donate=False)
    sc2 = StageCompute(cfg, 2, donate=False)
    assert sc1._k is sc2._k               # one compiled kernel set
    stage_p, head_p = cache.initial_params(cfg, 2, 0)
    toks = jnp.zeros((2, 8), jnp.int32)
    sc1.embed(head_p, toks)
    assert sc1.embed_calls == 1 and sc2.embed_calls == 0


def test_param_cache_hit_does_not_leak_training_state(runtime_env):
    """Train a cached-init trainer, then check a fresh cache hit still
    hands out the pristine initial parameters."""
    cfg, S = runtime_env["cfg"], runtime_env["stages"]
    before = jax.tree.map(lambda a: np.asarray(a).copy(),
                          cache.initial_params(cfg, S, 0))
    mbs = make_mbs(cfg)
    dn = make_net().data_nodes()[0].id
    tr = RuntimeTrainer(cfg, make_net(), lr=3e-3, seed=0,
                        churn_model=TraceChurn([]))
    tr.iteration({dn: mbs})
    after = cache.initial_params(cfg, S, 0)
    assert tree_equal(before, after)
    # trained params did move (the trainer replaced, not mutated)
    assert not tree_equal(tr.stage_params, list(after[0]))
    info = cache.cache_info()
    assert info["initial_params"]["hits"] >= 1


# ---------------------------------------------------------------------------
# Dispatch chunking
# ---------------------------------------------------------------------------

def test_auto_chunk_rule():
    # small microbatches stack up to the cap...
    assert auto_chunk(32, 1, 32, 128) == 4
    assert auto_chunk(2, 1, 32, 128) == 2
    # ...huge ones fall back to per-microbatch dispatch
    assert auto_chunk(8, 2, 512, 512) == 1
    assert auto_chunk(0, 1, 32, 128) >= 1


def test_dispatch_chunk_override_keeps_trainers_bit_identical():
    cfg = tiny_cfg()
    mbs = make_mbs(cfg)                       # 4 microbatches
    dn = make_net().data_nodes()[0].id
    rt = RuntimeTrainer(cfg, make_net(), lr=3e-3, seed=0,
                        churn_model=TraceChurn([]), dispatch_chunk=2)
    cen = CentralizedTrainer(cfg, 2, lr=3e-3, seed=0, dispatch_chunk=2)
    r = rt.iteration({dn: mbs})
    assert r.loss == cen.iteration(mbs)
    assert rt.stages.fwd_calls == [2, 2]      # 4 mbs / chunks of 2
    assert cen.stages.fwd_calls == [2, 2]
