"""checkpoint.store: bf16 + optimizer-state round trips, validation."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.optim.adamw import AdamW


def _tree_equal(a, b):
    import jax
    la, ta = jax.tree.flatten(a)
    lb, tb = jax.tree.flatten(b)
    assert ta == tb
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype and x.shape == y.shape
        # bf16 compares exactly through the uint16 bit pattern
        if x.dtype.name == "bfloat16":
            assert np.array_equal(x.view(np.uint16), y.view(np.uint16))
        else:
            assert np.array_equal(x, y)


def _stage_tree(seed=0):
    rng = np.random.default_rng(seed)
    params = {
        "w": jnp.asarray(rng.standard_normal((8, 16)), jnp.bfloat16),
        "scale": jnp.asarray(rng.standard_normal(16), jnp.float32),
    }
    opt = AdamW(lr=1e-3).init(params)
    return {"params": params, "opt": opt}


def test_bf16_adamw_stage_round_trip(tmp_path):
    """save_stage/restore_stage round-trip a bf16 stage + AdamW state."""
    tree = _stage_tree()
    # advance the opt state so moments are non-trivial
    opt = AdamW(lr=1e-3)
    grads = {"w": jnp.ones((8, 16), jnp.bfloat16),
             "scale": jnp.ones(16, jnp.float32)}
    new_p, new_s = opt.update(grads, tree["opt"], tree["params"])
    tree = {"params": new_p, "opt": new_s}
    store.save_stage(str(tmp_path), 3, tree, step=17)
    like = _stage_tree(seed=99)   # same structure, different values
    restored, step = store.restore_stage(str(tmp_path), 3, like)
    assert step == 17
    _tree_equal(restored, tree)


def test_restore_rejects_structure_mismatch(tmp_path):
    store.save(str(tmp_path / "ck.npz"), {"a": np.zeros(3), "b": np.ones(2)})
    with pytest.raises(ValueError, match="structure mismatch"):
        store.restore(str(tmp_path / "ck.npz"), {"a": np.zeros(3)})


def test_restore_rejects_corrupt_sidecar(tmp_path):
    path = str(tmp_path / "ck.npz")
    store.save(path, {"a": np.zeros(3)})
    with open(path + ".json") as f:
        sidecar = json.load(f)
    sidecar["num_leaves"] = 7
    with open(path + ".json", "w") as f:
        json.dump(sidecar, f)
    with pytest.raises(ValueError, match="corrupt"):
        store.restore(path, {"a": np.zeros(3)})


def test_sidecar_counts_leaves_not_markers(tmp_path):
    """The sidecar's num_leaves must count pytree leaves, not the
    bf16 marker entries the archive adds alongside them."""
    path = str(tmp_path / "ck.npz")
    tree = {"x": jnp.ones((2, 2), jnp.bfloat16), "y": np.zeros(3)}
    store.save(path, tree)
    with open(path + ".json") as f:
        sidecar = json.load(f)
    assert sidecar["num_leaves"] == 2
    restored, _ = store.restore(path, tree)
    _tree_equal(restored, tree)


def test_shape_mismatch_still_detected(tmp_path):
    path = str(tmp_path / "ck.npz")
    store.save(path, {"a": np.zeros((3, 3))})
    with pytest.raises(ValueError, match="shape mismatch"):
        store.restore(path, {"a": np.zeros((4, 3))})


def test_save_leaves_no_temp_residue(tmp_path):
    """A completed save leaves exactly the final npz + sidecar — the
    temp files the atomic write goes through are always renamed away."""
    import os

    path = str(tmp_path / "ck.npz")
    store.save(path, {"a": np.zeros(3)})
    assert sorted(os.listdir(tmp_path)) == ["ck.npz", "ck.npz.json"]


def test_crashed_save_preserves_previous_checkpoint(tmp_path, monkeypatch):
    """A save killed mid-archive-write (any churn model can kill a node
    at an arbitrary time) must leave the previous checkpoint readable
    under the final name, not a truncated archive."""
    import os

    path = str(tmp_path / "ck.npz")
    store.save(path, {"a": np.zeros(3)}, step=1)

    def dying_savez(f, **kw):
        f.write(b"\x00" * 16)            # truncated garbage, then die
        raise RuntimeError("killed mid-write")

    monkeypatch.setattr(np, "savez", dying_savez)
    with pytest.raises(RuntimeError, match="killed mid-write"):
        store.save(path, {"a": np.ones(3)}, step=2)
    monkeypatch.undo()
    restored, step = store.restore(path, {"a": np.zeros(3)})
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.zeros(3))
    assert sorted(os.listdir(tmp_path)) == ["ck.npz", "ck.npz.json"]
