"""`hypothesis` import shim for environments without the package.

The property tests in this repo use a small, fixed subset of the
hypothesis API (`@settings(max_examples=..., deadline=None)`,
`@given(name=st.integers/floats/sampled_from)`).  When hypothesis is
installed we re-export the real thing; otherwise we fall back to a
deterministic sampler that draws `max_examples` examples per strategy
from a seeded numpy Generator and runs the test body once per example.

This keeps tier-1 tests runnable in hermetic containers (no pip
installs) while preserving full shrinking/search behaviour on machines
that do have hypothesis.
"""
from __future__ import annotations

try:  # pragma: no cover - depends on environment
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    import functools
    import inspect

    import numpy as np

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(options):
            opts = list(options)
            return _Strategy(lambda rng: opts[int(rng.integers(len(opts)))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.draw(rng) for _ in range(n)]
            return _Strategy(draw)

    st = _Strategies()

    def settings(max_examples: int = 20, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = np.random.default_rng(0)
                n = getattr(wrapper, "_max_examples", 20)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)
            wrapper._max_examples = getattr(fn, "_max_examples", 20)
            # pytest must not try to inject the strategy kwargs as fixtures:
            # expose a signature without them (also stops __wrapped__
            # unwinding in inspect.signature).
            sig = inspect.signature(fn)
            keep = [p for name, p in sig.parameters.items()
                    if name not in strategies]
            wrapper.__signature__ = sig.replace(parameters=keep)
            return wrapper
        return deco
