"""StarCoder2-7B — GQA kv=4, RoPE, layernorm + gelu MLP. [arXiv:2402.19173]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    arch_type="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    qkv_bias=True,
    mlp_type="gelu",
    norm_type="layernorm",
    rope_theta=100_000.0,
    sliding_window=4096,
    source="arXiv:2402.19173",
)
