"""Architecture config registry.

One module per assigned architecture (``--arch <id>``); each exposes
``CONFIG`` with the exact published dimensions plus ``input_specs(shape)``
helpers via the registry.
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.models.config import ModelConfig

ARCH_IDS = [
    "musicgen_medium",
    "mamba2_130m",
    "qwen1_5_4b",
    "gemma_7b",
    "tinyllama_1_1b",
    "hymba_1_5b",
    "granite_moe_3b_a800m",
    "llama3_2_vision_90b",
    "qwen2_moe_a2_7b",
    "starcoder2_7b",
    # the paper's own evaluation models
    "gwtf_llama_300m",
    "gwtf_gpt_300m",
    "gwtf_llama_7b",
]

_ALIASES = {
    "musicgen-medium": "musicgen_medium",
    "mamba2-130m": "mamba2_130m",
    "qwen1.5-4b": "qwen1_5_4b",
    "gemma-7b": "gemma_7b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "hymba-1.5b": "hymba_1_5b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "llama-3.2-vision-90b": "llama3_2_vision_90b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "starcoder2-7b": "starcoder2_7b",
    "gwtf-llama-300m": "gwtf_llama_300m",
    "gwtf-gpt-300m": "gwtf_gpt_300m",
    "gwtf-llama-7b": "gwtf_llama_7b",
}


def get_config(arch: str) -> ModelConfig:
    mod_name = _ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
