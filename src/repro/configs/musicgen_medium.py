"""MusicGen-medium — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284]  Audio modality frontend (EnCodec + codebook interleave)
is a stub: ``input_specs`` supplies precomputed frame embeddings (B, S, D);
the decoder transformer below is fully implemented.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    arch_type="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    mlp_type="gelu",
    norm_type="layernorm",
    sliding_window=4096,
    audio_frontend=True,
    source="arXiv:2306.05284",
)
