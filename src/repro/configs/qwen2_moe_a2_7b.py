"""Qwen2-MoE-A2.7B — 60 routed experts top-4 + 4 shared experts.

[hf:Qwen/Qwen1.5-MoE-A2.7B]  Shared expert = one dense MLP of width
4 x 1408; router renormalises top-4 probs.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    arch_type="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=151936,
    qkv_bias=True,
    num_experts=60,
    num_experts_per_tok=4,
    num_shared_experts=4,
    rope_theta=1_000_000.0,
    sliding_window=4096,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
