"""Mamba2-130m — attention-free SSD (state-space duality) decoder.

[arXiv:2405.21060]  d_inner = 2*768 = 1536; 24 SSD heads of dim 64;
state N=128.  long_500k runs on the native O(1)-state decode path.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    arch_type="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_heads=24,
    ssm_head_dim=64,
    source="arXiv:2405.21060",
)
