"""The paper's LLaMA-like evaluation model (Sec. VI: d_model=1024, 16
layers; the paper lists n_heads=18 which does not divide 1024 — we use 16
heads of dim 64 and note the adjustment in DESIGN.md)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gwtf-llama-300m",
    arch_type="dense",
    num_layers=16,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_size=32000,
    source="GWTF paper Sec. VI",
)
