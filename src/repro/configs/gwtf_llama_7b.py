"""LLaMA-7B as used in the paper's convergence experiment (Sec. VI)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gwtf-llama-7b",
    arch_type="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=32000,
    source="GWTF paper Sec. VI / arXiv:2302.13971",
)
