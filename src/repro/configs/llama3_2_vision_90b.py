"""Llama-3.2-Vision-90B — 100 layers: gated cross-attention every 5th.

[hf:meta-llama/Llama-3.2-11B-Vision family]  The ViT vision encoder +
projector is a stub (``input_specs`` supplies patch embeddings of shape
(B, 1601, 7680)); the language decoder with interleaved gated cross-attn
layers is fully implemented.  100 layers = 20 superblocks x (1 cross + 4
self).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    arch_type="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500_000.0,
    cross_attn_every=5,
    num_image_tokens=1601,
    vision_dim=7680,
    sliding_window=4096,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
