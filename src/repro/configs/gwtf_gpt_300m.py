"""The paper's GPT-like evaluation model (Sec. VI)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gwtf-gpt-300m",
    arch_type="dense",
    num_layers=16,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=50257,
    mlp_type="gelu",
    norm_type="layernorm",
    tie_embeddings=True,
    source="GWTF paper Sec. VI",
)
