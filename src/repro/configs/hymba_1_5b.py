"""Hymba-1.5B — hybrid: parallel attention + mamba heads per layer.

[arXiv:2411.13676]  25 attn heads (hd 64, kv=5) in parallel with SSD heads
(d_inner 3200, 50 heads, state 16); outputs mean-combined.  Meta tokens
are not modelled (noted in DESIGN.md).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_heads=50,
    ssm_head_dim=64,
    sliding_window=4096,
    source="arXiv:2411.13676",
)
