"""Core neural layers: norms, RoPE, MLP, chunked attention, embeddings.

Everything is functional: ``init_*`` builds a param dict, the apply
functions are pure.  Attention uses an online-softmax scan over KV blocks
(the XLA-portable twin of the Pallas flash kernel in ``repro.kernels``),
so 32k-context prefill never materialises an S x S score matrix.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

# ---------------------------------------------------------------------------
# Initialisers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, dim: Optional[int] = None):
    d = dim or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p, x, cfg: ModelConfig, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: (..., S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (dense)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, dtype):
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], (D, F), dtype),
            "w_up": dense_init(ks[1], (D, F), dtype),
            "w_down": dense_init(ks[2], (F, D), dtype),
        }
    return {
        "w_up": dense_init(ks[0], (D, F), dtype),
        "w_down": dense_init(ks[1], (F, D), dtype),
    }


def apply_mlp(p, x, cfg: ModelConfig):
    from repro.parallel.sharding import shard
    if cfg.mlp_type in ("swiglu", "geglu"):
        g = shard(x @ p["w_gate"], "batch", None, "tp")
        act = jax.nn.silu(g) if cfg.mlp_type == "swiglu" else jax.nn.gelu(g)
        h = act * (x @ p["w_up"])
    else:
        h = shard(jax.nn.gelu(x @ p["w_up"]), "batch", None, "tp")
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype, kv_in_dim: Optional[int] = None):
    """kv_in_dim overrides the K/V input width (cross-attention)."""
    D = cfg.d_model
    kv_in = kv_in_dim or D
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, cfg.q_dim), dtype),
        "wk": dense_init(ks[1], (kv_in, cfg.kv_dim), dtype),
        "wv": dense_init(ks[2], (kv_in, cfg.kv_dim), dtype),
        "wo": dense_init(ks[3], (cfg.q_dim, D), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dtype)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dtype)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dtype)
    return p


def _online_attention(q, k, v, q_offset, causal: bool, window: Optional[int],
                      kv_len_valid=None, q_block: int = 512):
    """Flash-style attention: scan over query blocks, full K/V per block.

    q: (B, Sq, H, hd); k/v: (B, Sk, KH, hd).  GQA via head repeat.
    q_offset: absolute position of q[0] (int or traced scalar).
    kv_len_valid: optional scalar — number of valid KV entries (cache decode).
    Memory per block: B*H*q_block*Sk — bounded, never S^2.
    """
    B, Sq, H, hd = q.shape
    _, Sk, KH, _ = k.shape
    rep = H // KH
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = hd ** -0.5
    kv_pos = jnp.arange(Sk)

    def block_attn(q_blk, q_pos):
        # q_blk: (B, qb, H, hd); q_pos: (qb,)
        # No explicit input convert: bf16 x bf16 -> f32 accumulation via
        # preferred_element_type (native on the MXU; an explicit astype
        # would get loop-hoisted by XLA into a full-cache f32 copy).
        s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k,
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((q_pos.shape[0], Sk), bool)
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= kv_pos[None, :] > q_pos[:, None] - window
        if kv_len_valid is not None:
            mask &= kv_pos[None, :] < kv_len_valid
        s = jnp.where(mask[None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v,
                          preferred_element_type=jnp.float32).astype(q.dtype)

    if Sq <= q_block:
        return block_attn(q, q_offset + jnp.arange(Sq))

    n_blocks = Sq // q_block
    assert Sq % q_block == 0, f"Sq={Sq} not divisible by q_block={q_block}"
    qs = q.reshape(B, n_blocks, q_block, H, hd).transpose(1, 0, 2, 3, 4)

    def body(_, qb_i):
        qb, i = qb_i
        pos = q_offset + i * q_block + jnp.arange(q_block)
        return None, block_attn(qb, pos)

    _, outs = jax.lax.scan(body, None, (qs, jnp.arange(n_blocks)))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)


def _decode_attention(q, ck, cv, kv_valid, KH, hd, block: int = 2048):
    """Single-token attention against a long KV cache, scanned in chunks.

    q: (B, 1, H, hd); ck/cv: (B, C, KH*hd) flattened cache.  Online
    softmax over KV chunks keeps the working set to one (B, block, KH, hd)
    slice — and, critically, the per-chunk dynamic-slice depends on the
    loop index, so XLA cannot loop-hoist a bf16->f32 convert of the whole
    cache (a CPU-backend artifact that doubles analysed memory; on TPU the
    chunked form is simply the right VMEM-bounded pattern).
    """
    B, _, H, _ = q.shape
    C = ck.shape[1]
    block = min(block, C)
    n = C // block
    rem = C - n * block
    assert rem == 0, (C, block)
    rep = H // KH
    scale = hd ** -0.5
    qf = (q[:, 0] * scale).astype(q.dtype)                 # (B, H, hd)

    def chunk(carry, i):
        m_prev, l_prev, acc = carry
        kc = jax.lax.dynamic_slice_in_dim(ck, i * block, block, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(cv, i * block, block, axis=1)
        kc = kc.reshape(B, block, KH, hd)
        vc = vc.reshape(B, block, KH, hd)
        if rep > 1:
            kc = jnp.repeat(kc, rep, axis=2)
            vc = jnp.repeat(vc, rep, axis=2)
        sc = jnp.einsum("bhd,bkhd->bhk", qf, kc,
                        preferred_element_type=jnp.float32)   # (B, H, block)
        pos = i * block + jnp.arange(block)
        mask = pos[None, None, :] < kv_valid
        sc = jnp.where(mask, sc, -1e30)
        m_cur = jnp.max(sc, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        pch = jnp.exp(sc - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(pch, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhk,bkhd->bhd", pch.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc), None

    init = (jnp.full((B, H), -1e30, jnp.float32),
            jnp.zeros((B, H), jnp.float32),
            jnp.zeros((B, H, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(chunk, init, jnp.arange(n))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out[:, None].astype(q.dtype)                    # (B, 1, H, hd)


def _constrain_attention_operands(q, k, v, H, KH):
    """Pick the TP layout for train/prefill attention.

    * H %% tp == 0: shard Q by heads evenly; K/V replicated when their
      head count does not also divide (GSPMD would otherwise shard K's
      head_dim and psum every score tensor).
    * H %% tp != 0 (e.g. 36, 25, 20 heads on a 16-way axis): shard Q heads
      *unevenly* (GSPMD pads) and replicate K/V — the padding wastes
      ceil/floor FLOPs but removes the partial-sum all-reduces entirely.
    """
    from repro.parallel.sharding import shard, shard_heads, tp_size
    tp = tp_size()
    if tp <= 1:
        return q, k, v
    if H % tp == 0:
        # even head counts: GSPMD already finds a psum-free layout
        # (measured: constraining K/V replicated here ADDS ~0.8e12 bytes
        # of k/v gathers on llama-90b — leave it alone).
        return q, k, v
    if KH > tp // 2:
        # uneven heads but near-MHA K/V (musicgen 24/24, qwen1.5 20/20):
        # replicating K/V would all-gather d_model-sized tensors per layer
        # (measured 5-10x collective regression) — GSPMD's default layout
        # is the better trade.
        return q, k, v
    # uneven Q heads + genuinely small GQA K/V (starcoder2 36/4, hymba
    # 25/5): pad-shard Q heads, replicate the small K/V — removes the
    # partial-sum score all-reduces (measured 9.2x on starcoder2 prefill).
    q = shard_heads(q, 2)
    k = shard(k, "batch", None, None, None)
    v = shard(v, "batch", None, None, None)
    return q, k, v


def apply_attention(p, x, cfg: ModelConfig, *, positions, causal=True,
                    window=None, kv_x=None, cache=None, write_index=None,
                    kv_valid=None, use_kernel: bool = False):
    """Self- or cross-attention with optional KV cache.

    x: (B, S, D).  kv_x: cross-attention memory (B, M, Dv) or None.
    cache: dict(k=(B, C, kv_dim), v=(B, C, kv_dim)) — kv dims kept
    *flattened* so the 'model'-axis sharding always divides (kvH*hd % 16
    == 0 for every assigned arch even when kvH itself is not).

    Decode semantics: K/V of this step are written at slot ``write_index``
    (``index % window`` for a ring buffer, else ``index``); ``kv_valid``
    is the number of live slots; attention attends to all live slots —
    every live slot is in the past, so no causal mask is needed for the
    single-token query.  RoPE uses absolute ``positions`` so ring slots
    are order-independent under softmax.

    Returns (out, new_cache).
    """
    from repro.parallel.sharding import shard

    B, S, D = x.shape
    H, KH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    q = shard(x @ p["wq"], "batch", None, "tp")
    src = kv_x if kv_x is not None else x
    k = shard(src @ p["wk"], "batch", None, "tp")
    v = shard(src @ p["wv"], "batch", None, "tp")
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]

    q = q.reshape(B, S, H, hd)
    if kv_x is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k.reshape(B, -1, KH, hd), positions, cfg.rope_theta)
        k = k.reshape(B, -1, cfg.kv_dim)

    new_cache = None
    if cache is not None:
        # head-padded cache layout (hillclimb D): zero-pad K/V (and Q by
        # whole head groups) so each device owns whole heads; the padded
        # head outputs are sliced away before wo.
        cache_kvd = cache["k"].shape[-1]
        pad_kv = cache_kvd - cfg.kv_dim
        KH_eff, H_eff = KH, H
        if pad_kv > 0:
            rep = H // KH
            KH_eff = cache_kvd // hd
            H_eff = KH_eff * rep
            k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_kv)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_kv)))
            q = jnp.pad(q, ((0, 0), (0, 0), (0, H_eff - H), (0, 0)))
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, write_index, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, write_index, 0))
        # no shard() here: the cache layout is pinned by in_shardings and a
        # constraint would materialise an extra full-cache copy.
        new_cache = {"k": ck, "v": cv}
        C = ck.shape[1]
        if S == 1:
            # single new token: every live slot is in the past -> no mask
            out = _decode_attention(q, ck, cv, kv_valid, KH_eff, hd)
            if pad_kv > 0:
                out = out[:, :, :H, :]
        else:
            # multi-token prefill: the cache was empty, so attention only
            # covers this step's own K/V — use the pre-write tensors, NOT
            # the tp-sharded cache (reading the head-dim-sharded cache
            # back would psum every score tensor).
            k4 = k.reshape(B, S, KH_eff, hd)
            v4 = v.reshape(B, S, KH_eff, hd)
            qh, k4, v4 = _constrain_attention_operands(q, k4, v4, H_eff,
                                                       KH_eff)
            out = _online_attention(qh, k4, v4, q_offset=positions[0],
                                    causal=True, window=None)
            if pad_kv > 0:
                out = out[:, :, :H, :]
    else:
        k = k.reshape(B, -1, KH, hd)
        v = v.reshape(B, -1, KH, hd)
        q, k, v = _constrain_attention_operands(q, k, v, H, KH)
        if use_kernel and kv_x is None and causal:
            from repro.kernels import ops as kops
            out = kops.flash_attention(q, k, v, causal=True, window=window)
        else:
            out = _online_attention(q, k, v, q_offset=0,
                                    causal=causal and kv_x is None,
                                    window=window)

    out = out.reshape(B, S, cfg.q_dim)
    return out @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# Embedding + LM head
# ---------------------------------------------------------------------------

def init_embed(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    p = {"table": dense_init(k1, (cfg.vocab_size, cfg.d_model), dtype, scale=0.02)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(k2, (cfg.d_model, cfg.vocab_size), dtype)
    return p


def embed_tokens(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def lm_logits(p, x, cfg: ModelConfig):
    w = p["table"].T if cfg.tie_embeddings else p["lm_head"]
    return x @ w


def chunked_xent_loss(embed_p, x, labels, cfg: ModelConfig, chunk: int = 512):
    """Cross-entropy without materialising (B, S, V) for 256k vocabs.

    Scans over sequence chunks; logits exist only per-chunk.
    x: (B, S, D), labels: (B, S) -> scalar mean loss.
    """
    B, S, D = x.shape
    w = embed_p["table"].T if cfg.tie_embeddings else embed_p["lm_head"]
    n = S // chunk if S % chunk == 0 else 1
    if n == 1:
        chunk = S
    xs = x.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(acc, xl):
        xc, lc = xl
        logits = (xc @ w).astype(jnp.float32)              # (B, chunk, V)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(body, jnp.float32(0.0), (xs, ls))
    return total / (B * S)
