"""Model configuration for all supported architectures.

One frozen dataclass covers the 6 architecture families assigned to this
paper (dense / ssm / moe / hybrid / vlm / audio) plus the paper's own
GPT-like and LLaMA-like models.  Every field is explicit so a config file
under ``repro/configs/`` is a single readable literal.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense | ssm | moe | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                      # query heads (0 for attention-free)
    num_kv_heads: int
    head_dim: int
    d_ff: int                           # dense-MLP hidden (per-expert size for MoE)
    vocab_size: int

    # --- attention ---
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None  # decode-time SWA window (long_500k)

    # --- mlp / norm ---
    mlp_type: str = "swiglu"            # swiglu | geglu | gelu
    norm_type: str = "rmsnorm"          # rmsnorm | layernorm

    # --- ssm (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_expand: int = 2

    # --- moe ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0         # qwen2-moe style shared expert(s)
    router_aux_coef: float = 0.01       # load-balance loss coefficient

    # --- vlm (cross-attention image layers) ---
    cross_attn_every: int = 0           # every k-th layer is cross-attn (0 = none)
    num_image_tokens: int = 0
    vision_dim: int = 0                 # stub vision-encoder output dim

    # --- audio (decoder over codec-frame embeddings) ---
    audio_frontend: bool = False        # inputs are precomputed frame embeddings

    # --- misc ---
    tie_embeddings: bool = False
    param_dtype: str = "bfloat16"
    remat: bool = True                  # activation checkpointing on layer blocks
    source: str = ""                    # citation

    # ------------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def has_attention(self) -> bool:
        return self.num_heads > 0

    @property
    def has_ssm(self) -> bool:
        return self.arch_type in ("ssm", "hybrid")

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def reduced(self, num_layers: int = 2, d_model: int = 256,
                max_experts: int = 4) -> "ModelConfig":
        """Smoke-test variant: same family, tiny dims, runnable on CPU."""
        scale = d_model / self.d_model
        head_dim = min(self.head_dim, 64)
        num_heads = max(1, min(self.num_heads, d_model // head_dim)) if self.num_heads else 0
        num_kv = max(1, min(self.num_kv_heads, num_heads)) if self.num_kv_heads else 0
        if num_heads and num_heads % max(num_kv, 1):
            num_kv = 1
        experts = min(self.num_experts, max_experts)
        topk = min(self.num_experts_per_tok, experts) if experts else 0
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=num_layers,
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=num_kv,
            head_dim=head_dim,
            d_ff=max(64, int(self.d_ff * scale)) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            ssm_state=min(self.ssm_state, 16),
            ssm_heads=min(self.ssm_heads, 4) if self.ssm_heads else 0,
            ssm_head_dim=min(self.ssm_head_dim, 32),
            num_experts=experts,
            num_experts_per_tok=topk,
            num_shared_experts=min(self.num_shared_experts, 1),
            cross_attn_every=min(self.cross_attn_every, num_layers) if self.cross_attn_every else 0,
            num_image_tokens=min(self.num_image_tokens, 16),
            vision_dim=min(self.vision_dim, 128) if self.vision_dim else 0,
            param_dtype="float32",
            remat=False,
        )

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        per_layer = 0
        if self.has_attention:
            per_layer += D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D
            if self.qkv_bias:
                per_layer += self.q_dim + 2 * self.kv_dim
        if self.has_ssm:
            di = self.d_inner
            ns, nh = self.ssm_state, self.ssm_heads
            per_layer += D * (2 * di + 2 * ns + nh) + di * D + di  # in/out proj + conv-ish
        if self.is_moe:
            per_layer += D * self.num_experts                      # router
            e_ff = 3 * D * F if self.mlp_type in ("swiglu", "geglu") else 2 * D * F
            per_layer += self.num_experts * e_ff
            per_layer += self.num_shared_experts * e_ff
        elif F:
            per_layer += (3 if self.mlp_type in ("swiglu", "geglu") else 2) * D * F
        if self.cross_attn_every:
            # cross-attn layers mirror self-attn layers (K/V consume the
            # projected vision embeddings at d_model width) + one vision
            # projector; total layer params ~ per_layer * L.
            per_layer_total = per_layer * L + self.vision_dim * D
        else:
            per_layer_total = per_layer * L
        embed = V * D * (1 if self.tie_embeddings else 2)
        return per_layer_total + embed + 2 * L * D  # + norms


# ---------------------------------------------------------------------------
# Input shapes assigned to this paper.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                            # train | prefill | decode


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
