"""Composable decoder transformer covering all six assigned arch families.

* dense  — (GQA/MQA attention + gated MLP)           [qwen1.5, gemma, tinyllama, starcoder2]
* ssm    — attention-free Mamba2/SSD blocks          [mamba2-130m]
* moe    — attention + routed experts (+shared)      [granite-moe, qwen2-moe]
* hybrid — parallel attention + SSM heads per layer  [hymba]
* vlm    — self-attn blocks with interleaved gated
           cross-attention to stub patch embeddings  [llama-3.2-vision]
* audio  — decoder over stub codec-frame embeddings  [musicgen]

Layers are stacked and iterated with ``lax.scan`` so the lowered HLO is
O(1) in depth — 100-layer configs compile fast in the 512-device dry-run.
VLM interleaving is handled by scanning *superblocks* (1 cross-attn layer
+ (k-1) self-attn layers), keeping the scan body homogeneous.

Decode semantics (serve_step): ONE new token against a KV cache.
``decode_32k`` uses a full-length cache; ``long_500k`` uses a sliding-
window ring buffer (sub-quadratic variant) — slot = index % window, RoPE
at absolute positions, softmax is slot-order independent.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.config import ModelConfig
from repro.parallel.sharding import shard


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"ln1": L.init_norm(cfg)}
    if cfg.arch_type == "ssm":
        p["mamba"] = SSM.init_mamba(ks[0], cfg, dtype)
        return p
    p["attn"] = L.init_attention(ks[0], cfg, dtype)
    if cfg.arch_type == "hybrid":
        p["mamba"] = SSM.init_mamba(ks[1], cfg, dtype)
    p["ln2"] = L.init_norm(cfg)
    if cfg.is_moe:
        p["moe"] = MOE.init_moe(ks[2], cfg, dtype)
    else:
        p["mlp"] = L.init_mlp(ks[2], cfg, dtype)
    return p


def _init_cross_block(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.init_norm(cfg),
        "xattn": L.init_attention(ks[0], cfg, dtype),
        "gate_attn": jnp.zeros((), jnp.float32),
        "ln2": L.init_norm(cfg),
        "mlp": L.init_mlp(ks[1], cfg, dtype),
        "gate_mlp": jnp.zeros((), jnp.float32),
    }


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    k_embed, k_blocks, k_cross, k_proj = jax.random.split(key, 4)
    params: Dict[str, Any] = {"embed": L.init_embed(k_embed, cfg, dtype),
                              "final_norm": L.init_norm(cfg)}
    Ln = cfg.num_layers
    if cfg.arch_type == "vlm" and cfg.cross_attn_every:
        k = cfg.cross_attn_every
        nb = Ln // k
        self_keys = jax.random.split(k_blocks, nb * (k - 1)).reshape(nb, k - 1, 2)
        cross_keys = jax.random.split(k_cross, nb)
        params["self_blocks"] = jax.vmap(jax.vmap(
            lambda kk: _init_block(kk, cfg, dtype)))(self_keys)
        params["cross_blocks"] = jax.vmap(
            lambda kk: _init_cross_block(kk, cfg, dtype))(cross_keys)
        params["vision_proj"] = {
            "w_proj": L.dense_init(k_proj, (cfg.vision_dim, cfg.d_model), dtype)}
    else:
        keys = jax.random.split(k_blocks, Ln)
        params["blocks"] = jax.vmap(lambda kk: _init_block(kk, cfg, dtype))(keys)
    return params


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=jnp.bfloat16,
               kv_heads_override: Optional[int] = None) -> Dict[str, Any]:
    """Allocate the decode cache.  ``cache_len`` = min(seq_len, window).

    kv_heads_override > num_kv_heads pads the cache's head dim so it
    shards evenly over the model axis (launch/specs.pad_kv_heads)."""
    kvd = (kv_heads_override or cfg.num_kv_heads) * cfg.head_dim

    def attn_cache(lead):
        return {
            "k": jnp.zeros(lead + (batch, cache_len, kvd), dtype),
            "v": jnp.zeros(lead + (batch, cache_len, kvd), dtype),
        }

    def ssm_cache(lead):
        base = SSM.init_mamba_cache(cfg, batch, dtype)
        return jax.tree.map(lambda x: jnp.zeros(lead + x.shape, x.dtype), base)

    Ln = cfg.num_layers
    c: Dict[str, Any] = {}
    if cfg.arch_type == "ssm":
        c["ssm"] = ssm_cache((Ln,))
    elif cfg.arch_type == "hybrid":
        c["attn"] = attn_cache((Ln,))
        c["ssm"] = ssm_cache((Ln,))
    elif cfg.arch_type == "vlm" and cfg.cross_attn_every:
        k = cfg.cross_attn_every
        c["attn"] = attn_cache((Ln // k, k - 1))
    else:
        c["attn"] = attn_cache((Ln,))
    return c


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _apply_block(bp, x, cfg: ModelConfig, *, positions, window, cache,
                 write_index, kv_valid, moe_impl, use_kernel):
    """One decoder layer.  Returns (x, aux, new_cache)."""
    aux = jnp.float32(0.0)
    h = L.apply_norm(bp["ln1"], x, cfg)
    new_cache: Dict[str, Any] = {}
    if cfg.arch_type == "ssm":
        out, nc = SSM.apply_mamba(bp["mamba"], h, cfg,
                                  cache=cache.get("ssm") if cache else None)
        if cache is not None:
            new_cache["ssm"] = nc
        return x + out, aux, new_cache

    a_out, nc_a = L.apply_attention(
        bp["attn"], h, cfg, positions=positions, window=window,
        cache=cache.get("attn") if cache else None,
        write_index=write_index, kv_valid=kv_valid, use_kernel=use_kernel)

    if cfg.arch_type == "hybrid":
        s_out, nc_s = SSM.apply_mamba(bp["mamba"], h, cfg,
                                      cache=cache.get("ssm") if cache else None)
        if cache is not None:
            new_cache["attn"], new_cache["ssm"] = nc_a, nc_s
        x = x + 0.5 * (a_out + s_out)
    else:
        if cache is not None:
            new_cache["attn"] = nc_a
        x = x + a_out

    h2 = L.apply_norm(bp["ln2"], x, cfg)
    if cfg.is_moe:
        m_out, aux = MOE.apply_moe(bp["moe"], h2, cfg, impl=moe_impl)
    else:
        m_out = L.apply_mlp(bp["mlp"], h2, cfg)
    # Megatron-style sequence parallelism: the residual stream between
    # blocks is sharded along S over the 'model' axis (rules.seq); XLA
    # turns the row-parallel psum into reduce-scatter + all-gather pairs.
    return shard(x + m_out, "batch", "seq", None), aux, new_cache


def _apply_cross_block(bp, x, vision, cfg: ModelConfig):
    """Gated cross-attention layer (llama-3.2-vision style)."""
    h = L.apply_norm(bp["ln1"], x, cfg)
    out, _ = L.apply_attention(bp["xattn"], h, cfg, positions=None,
                               causal=False, kv_x=vision)
    x = x + jnp.tanh(bp["gate_attn"]).astype(x.dtype) * out
    h2 = L.apply_norm(bp["ln2"], x, cfg)
    x = x + jnp.tanh(bp["gate_mlp"]).astype(x.dtype) * L.apply_mlp(bp["mlp"], h2, cfg)
    return x


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def forward_hidden(params, cfg: ModelConfig, *, tokens=None, embeds=None,
                   vision=None, window=None, cache=None, abs_index=None,
                   write_index=None, moe_impl: str = "dense",
                   use_kernel: bool = False, remat: Optional[bool] = None):
    """Run the decoder stack.  Returns (hidden, aux_loss, new_cache).

    abs_index:   absolute position of the first input token (decode).
    write_index: cache slot to write K/V at (ring slot for SWA decode).
    """
    if embeds is not None:
        x = embeds.astype(jnp.dtype(cfg.param_dtype))
    else:
        x = L.embed_tokens(params["embed"], tokens)
    x = shard(x, "batch", "seq", None)
    B, S, _ = x.shape

    if abs_index is not None:
        positions = abs_index + jnp.arange(S)
        kv_valid = None
        if cache is not None and "attn" in cache:
            cache_len = cache["attn"]["k"].shape[-2]
            kv_valid = jnp.minimum(abs_index + S, cache_len)
        if write_index is None:
            write_index = abs_index
    else:
        positions = jnp.arange(S)
        kv_valid = None

    do_remat = cfg.remat if remat is None else remat
    block = functools.partial(_apply_block, cfg=cfg, positions=positions,
                              window=window, write_index=write_index,
                              kv_valid=kv_valid, moe_impl=moe_impl,
                              use_kernel=use_kernel)

    aux0 = jnp.float32(0.0)
    if cfg.arch_type == "vlm" and cfg.cross_attn_every:
        vis = (vision.astype(x.dtype) @ params["vision_proj"]["w_proj"]
               if vision is not None else None)

        def inner(carry, layer_in):
            x2, aux2 = carry
            if cache is not None:
                sp, sc = layer_in
                x2, a, nc = block(sp, x2, cache={"attn": sc})
                nc = nc["attn"]
            else:
                sp = layer_in
                x2, a, nc = block(sp, x2, cache=None)
                nc = 0.0  # scan needs a pytree; dummy leaf
            return (x2, aux2 + a), nc

        def superblock(carry, layer_in):
            x1, aux1 = carry
            if cache is not None:
                cross_p, self_p, self_cache = layer_in
                inner_xs = (self_p, self_cache)
            else:
                cross_p, self_p = layer_in
                inner_xs = self_p
            if vis is not None:
                x1 = _apply_cross_block(cross_p, x1, vis, cfg)
            (x1, aux1), new_sc = jax.lax.scan(inner, (x1, aux1), inner_xs)
            return (x1, aux1), new_sc

        if do_remat:
            superblock = jax.checkpoint(superblock)
        if cache is not None:
            xs = (params["cross_blocks"], params["self_blocks"], cache["attn"])
            (x, aux), new_attn = jax.lax.scan(superblock, (x, aux0), xs)
            new_cache = {"attn": new_attn}
        else:
            xs = (params["cross_blocks"], params["self_blocks"])
            (x, aux), _ = jax.lax.scan(superblock, (x, aux0), xs)
            new_cache = None
    else:
        def layer(carry, layer_in):
            x2, aux2 = carry
            if cache is not None:
                bp, lc = layer_in
                x2, a, nc = block(bp, x2, cache=lc)
            else:
                bp = layer_in
                x2, a, nc = block(bp, x2, cache=None)
                nc = 0.0
            return (x2, aux2 + a), nc

        if do_remat:
            layer = jax.checkpoint(layer)
        xs = (params["blocks"], cache) if cache is not None else params["blocks"]
        (x, aux), new_cache = jax.lax.scan(layer, (x, aux0), xs)

    x = L.apply_norm(params["final_norm"], x, cfg)
    return x, aux, new_cache


# ---------------------------------------------------------------------------
# Entry points: train loss / prefill / decode
# ---------------------------------------------------------------------------

def train_loss(params, batch, cfg: ModelConfig, *, moe_impl="dense",
               use_kernel=False):
    """batch: dict(tokens (B,S) | embeds (B,S,D), labels (B,S), [vision])."""
    hidden, aux, _ = forward_hidden(
        params, cfg, tokens=batch.get("tokens"), embeds=batch.get("embeds"),
        vision=batch.get("vision"), moe_impl=moe_impl, use_kernel=use_kernel)
    loss = L.chunked_xent_loss(params["embed"], hidden, batch["labels"], cfg)
    return loss + cfg.router_aux_coef * aux


def prefill(params, cfg: ModelConfig, *, tokens=None, embeds=None,
            vision=None, cache=None, moe_impl="dense"):
    """Fill the cache with a full prompt; returns (last_logits, cache).

    Assumes prompt length <= cache length (no ring wrap during prefill)."""
    hidden, _, new_cache = forward_hidden(
        params, cfg, tokens=tokens, embeds=embeds, vision=vision,
        cache=cache, abs_index=jnp.int32(0), write_index=jnp.int32(0),
        moe_impl=moe_impl, remat=False)
    logits = L.lm_logits(params["embed"], hidden[:, -1:], cfg)
    return logits[:, 0], new_cache


def decode_step(params, cfg: ModelConfig, *, tokens=None, embeds=None,
                vision=None, cache, index, window=None, moe_impl="dense"):
    """One decode step at absolute position ``index`` (scalar int32)."""
    if "attn" in cache:
        cache_len = cache["attn"]["k"].shape[-2]
        write_index = index % cache_len if window is not None else index
    else:
        write_index = index
    hidden, _, new_cache = forward_hidden(
        params, cfg, tokens=tokens, embeds=embeds, vision=vision,
        cache=cache, abs_index=index, write_index=write_index,
        moe_impl=moe_impl, remat=False)
    logits = L.lm_logits(params["embed"], hidden[:, -1:], cfg)
    return logits[:, 0], new_cache
