"""Mixture-of-Experts layer (granite-moe, qwen2-moe style).

Two execution modes:

* ``dense``  — every expert computes every token; router combine-weights
  zero out the non-selected ones.  Simple, shards trivially (expert d_ff on
  the 'model' axis), but wastes E/topk of the FLOPs.  This is the paper-
  faithful baseline mode (GWTF does not optimise intra-stage compute).
* ``ragged`` — tokens are sorted by expert and computed with
  ``jax.lax.ragged_dot`` so only active (token, expert) pairs cost FLOPs.
  This is the beyond-paper optimisation used in the §Perf hillclimb.

Router load-balance auxiliary loss (Switch-style) is returned so training
can keep experts balanced — GWTF's bottleneck-stage argument applied to
experts.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init


def init_moe(key, cfg: ModelConfig, dtype):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (D, E), jnp.float32, scale=0.02),
        "w_gate": dense_init(ks[1], (E, D, F), dtype),
        "w_up": dense_init(ks[2], (E, D, F), dtype),
        "w_down": dense_init(ks[3], (E, F, D), dtype),
    }
    if cfg.num_shared_experts:
        Fs = F * cfg.num_shared_experts
        sk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(sk[0], (D, Fs), dtype),
            "w_up": dense_init(sk[1], (D, Fs), dtype),
            "w_down": dense_init(sk[2], (Fs, D), dtype),
        }
    return p


def _route(p, x, cfg: ModelConfig):
    """Returns (weights (T,E) combine weights, aux_loss). x: (T, D)."""
    logits = x.astype(jnp.float32) @ p["router"]          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    k = cfg.num_experts_per_tok
    topv, topi = jax.lax.top_k(probs, k)                  # (T, k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)   # renormalise
    combine = jnp.zeros_like(probs).at[
        jnp.arange(x.shape[0])[:, None], topi].set(topv)  # (T, E)
    # Switch aux loss: E * sum_e (frac_tokens_e * mean_prob_e)
    frac = jnp.mean((combine > 0).astype(jnp.float32), axis=0)
    aux = cfg.num_experts * jnp.sum(frac * jnp.mean(probs, axis=0))
    return combine, topi, topv, aux


def _expert_mlp_dense(p, x, combine, cfg: ModelConfig):
    """All-experts path as a scan over experts. x: (T, D); combine: (T, E).

    A naive ``einsum('td,edf->tef')`` makes XLA broadcast x to every
    expert ((E, D, T) — tens of GB at 32k context) and materialise a
    (T, E, D) output.  Scanning experts keeps the live set to one
    (T, F) block; combine-weights fold in *before* the down-projection so
    the output accumulates directly into (T, D).  FLOPs are identical
    (this is the paper-faithful dense baseline the §Perf ragged
    optimisation is measured against).
    """
    from repro.parallel.sharding import shard

    def one_expert(acc, ewc):
        wg, wu, wd, c_e = ewc                  # (D,F), (D,F), (F,D), (T,)
        g = shard(x @ wg, "batch", "tp")
        act = jax.nn.silu(g) if cfg.mlp_type == "swiglu" else jax.nn.gelu(g)
        h = act * (x @ wu)                     # (T, F)
        h = h * c_e[:, None].astype(h.dtype)
        return acc + h @ wd, None

    acc0 = jnp.zeros_like(x)
    out, _ = jax.lax.scan(
        one_expert, acc0,
        (p["w_gate"], p["w_up"], p["w_down"], combine.T.astype(x.dtype)))
    return out


def _expert_mlp_ragged(p, x, topi, topv, cfg: ModelConfig):
    """Active-only path: sort (token, expert) pairs by expert, ragged_dot.

    FLOPs ~ T*topk*D*F instead of T*E*D*F.
    """
    T, D = x.shape
    k = cfg.num_experts_per_tok
    E = cfg.num_experts
    flat_e = topi.reshape(-1)                              # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)                  # (T*k,)
    flat_w = topv.reshape(-1)
    order = jnp.argsort(flat_e)                            # stable sort by expert
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    xs = x[st]                                             # (T*k, D) gathered
    group_sizes = jnp.bincount(se, length=E).astype(jnp.int32)
    g = jax.lax.ragged_dot(xs, p["w_gate"], group_sizes)
    u = jax.lax.ragged_dot(xs, p["w_up"], group_sizes)
    act = jax.nn.silu(g) if cfg.mlp_type == "swiglu" else jax.nn.gelu(g)
    y = jax.lax.ragged_dot((act * u).astype(xs.dtype), p["w_down"], group_sizes)
    y = y * sw[:, None].astype(y.dtype)
    return jnp.zeros_like(x).at[st].add(y)


def _expert_mlp_capacity(p, x, topi, topv, cfg: ModelConfig,
                         capacity_factor: float = 2.0):
    """Active-only path via capacity-bounded dispatch (Switch-style).

    Tokens are sorted by expert; each expert processes at most
    C = capacity_factor * T * topk / E tokens (overflow dropped, weights
    renormalised by construction).  All shapes static, all ops standard
    (gather / batched dot / scatter) — lowers everywhere and keeps FLOPs
    at ~capacity_factor x the active compute instead of E/topk x.
    """
    from repro.parallel.sharding import shard
    T, D = x.shape
    k = cfg.num_experts_per_tok
    E = cfg.num_experts
    C = max(8, int(capacity_factor * T * k / E))
    flat_e = topi.reshape(-1)                       # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_w = topv.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    counts = jnp.bincount(se, length=E)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(T * k) - starts[se]            # slot within expert
    keep = pos < C
    pos = jnp.where(keep, pos, 0)
    wk = jnp.where(keep, sw, 0.0)
    buf = jnp.zeros((E, C, D), x.dtype).at[se, pos].set(
        jnp.where(keep[:, None], x[st], 0))
    g = shard(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]),
              None, None, "tp")
    act = jax.nn.silu(g) if cfg.mlp_type == "swiglu" else jax.nn.gelu(g)
    h = act * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"])  # (E, C, D)
    out = jnp.zeros_like(x).at[st].add(
        y[se, pos] * wk[:, None].astype(y.dtype))
    return out


def apply_moe(p, x, cfg: ModelConfig, impl: str = "dense"):
    """x: (B, S, D) -> (out, aux_loss).

    The MoE block runs with the sequence dim *gathered* (no seq sharding):
    merging a batch-sharded dim with a seq-sharded dim would force GSPMD
    into pathological resharding of the (T, E, F) expert tensors.  The
    surrounding block re-applies the sequence-parallel constraint.
    """
    from repro.parallel.sharding import shard
    x = shard(x, "batch", None, None)
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    combine, topi, topv, aux = _route(p, xt, cfg)
    if impl == "ragged":
        out = _expert_mlp_ragged(p, xt, topi, topv, cfg)
    elif impl == "capacity":
        out = _expert_mlp_capacity(p, xt, topi, topv, cfg)
    else:
        out = _expert_mlp_dense(p, xt, combine, cfg)
    if cfg.num_shared_experts:
        sp = p["shared"]
        g = xt @ sp["w_gate"]
        act = jax.nn.silu(g) if cfg.mlp_type == "swiglu" else jax.nn.gelu(g)
        out = out + (act * (xt @ sp["w_up"])) @ sp["w_down"]
    return out.reshape(B, S, D), aux
