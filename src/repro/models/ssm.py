"""Mamba2 / SSD (state-space duality) block — arXiv:2405.21060.

Implements the chunked SSD algorithm: intra-chunk quadratic (attention-like)
term + inter-chunk recurrence carried by ``lax.scan``.  A single-step decode
path maintains (conv_state, ssm_state) caches for O(1) per-token decoding —
this is what makes ``long_500k`` tractable for the ssm/hybrid archs.

The pure-jnp math here doubles as the oracle for the Pallas ``ssd_scan``
kernel (see repro/kernels/ref.py which re-exports ``ssd_reference``).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def ssd_reference(x, dt, A, B, C, h0=None):
    """Sequential SSD recurrence — the oracle.

    x: (b, S, H, P); dt: (b, S, H); A: (H,); B, C: (b, S, N).
    h_t = exp(dt_t A) h_{t-1} + dt_t * x_t (x) B_t ;  y_t = h_t . C_t
    Returns y: (b, S, H, P), h_final: (b, H, P, N).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((b, H, P, N), jnp.float32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp           # (b,H,P), (b,H), (b,N), (b,N)
        a = jnp.exp(dtt * A)            # (b,H)
        h = a[..., None, None] * h + (dtt[..., None] * xt)[..., None] * Bt[:, None, None, :]
        y = jnp.einsum("bhpn,bn->bhp", h, Ct)
        return h, y

    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          B.transpose(1, 0, 2), C.transpose(1, 0, 2))
    hf, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3), hf


def ssd_chunked(x, dt, A, B, C, h0=None, chunk: int = 64):
    """Chunked SSD: O(S*Q) intra-chunk matmuls + O(S/Q) sequential scan.

    Same signature/semantics as ``ssd_reference`` (float32 internal math).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    assert S % chunk == 0, f"S={S} % chunk={chunk}"
    nc = S // chunk
    if h0 is None:
        h0 = jnp.zeros((b, H, P, N), jnp.float32)

    xf = x.astype(jnp.float32).reshape(b, nc, chunk, H, P)
    dtf = dt.astype(jnp.float32).reshape(b, nc, chunk, H)
    Bf = B.astype(jnp.float32).reshape(b, nc, chunk, N)
    Cf = C.astype(jnp.float32).reshape(b, nc, chunk, N)

    # move chunk dim to front for scan
    xs = (xf.transpose(1, 0, 2, 3, 4), dtf.transpose(1, 0, 2, 3),
          Bf.transpose(1, 0, 2, 3), Cf.transpose(1, 0, 2, 3))

    idx = jnp.arange(chunk)
    causal = idx[:, None] >= idx[None, :]                 # (Q, Q) s <= t

    def per_chunk(h, inp):
        xc, dtc, Bc, Cc = inp            # (b,Q,H,P) (b,Q,H) (b,Q,N) (b,Q,N)
        loga = dtc * A                   # (b,Q,H) log decay per step
        L = jnp.cumsum(loga, axis=1)     # inclusive cumulative log decay
        # intra-chunk: M[t,s] = exp(L[t]-L[s]) * dt[s] * (C[t].B[s]), s<=t
        CB = jnp.einsum("btn,bsn->bts", Cc, Bc)            # (b,Q,Q)
        delta = L[:, :, None, :] - L[:, None, :, :]        # (b,t,s,H)
        # mask the exponent *before* exp: the s>t half would overflow to
        # +inf (L is non-increasing) and poison gradients through where().
        delta = jnp.where(causal[None, :, :, None], delta, 0.0)
        M = CB[..., None] * jnp.exp(delta) * dtc[:, None, :, :]
        M = jnp.where(causal[None, :, :, None], M, 0.0)
        y_intra = jnp.einsum("btsh,bshp->bthp", M, xc)
        # contribution of the incoming state: y += exp(L[t]) * C[t] . h
        y_state = jnp.einsum("bhpn,btn->bthp", h, Cc) * jnp.exp(L)[..., None]
        # new state: h' = exp(L[Q-1]) h + sum_s exp(L[Q-1]-L[s]) dt_s x_s (x) B_s
        last = L[:, -1:, :]                                # (b,1,H)
        w = jnp.exp(last - L) * dtc                        # (b,Q,H)
        h_new = jnp.exp(last[:, 0])[:, :, None, None] * h + \
            jnp.einsum("bqh,bqhp,bqn->bhpn", w, xc, Bc)
        return h_new, y_intra + y_state

    hf, ys = jax.lax.scan(per_chunk, h0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, S, H, P)
    return y.astype(x.dtype), hf


def ssd_decode_step(h, xt, dtt, A, Bt, Ct):
    """One-token SSD update. h: (b,H,P,N); xt: (b,H,P); dtt: (b,H)."""
    a = jnp.exp(dtt * A)
    h = a[..., None, None] * h + (dtt[..., None] * xt)[..., None] * Bt[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", h, Ct)
    return h, y


# ---------------------------------------------------------------------------
# Mamba2 block (in_proj -> conv -> SSD -> gated norm -> out_proj)
# ---------------------------------------------------------------------------

def init_mamba(key, cfg: ModelConfig, dtype):
    D = cfg.d_model
    di = cfg.d_inner
    N, H = cfg.ssm_state, cfg.ssm_heads
    P = di // H
    conv_dim = di + 2 * N
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (D, 2 * di + 2 * N + H), dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim), jnp.float32)
                   * (cfg.ssm_conv ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[3], (di, D), dtype),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x: (B,S,C); w: (K,C). Returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)              # (B, S+K-1, C)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K)) + b
    new_state = xp[:, -(K - 1):, :] if K > 1 else None
    return y, new_state


def apply_mamba(p, x, cfg: ModelConfig, *, cache=None, chunk: int = 64):
    """x: (B, S, D). cache: dict(conv=(B,K-1,conv_dim), ssm=(B,H,P,N)) or None.
    Returns (out, new_cache)."""
    B_, S, D = x.shape
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P = di // H

    zxbcdt = x @ p["in_proj"]
    z, xs, Bc, Cc, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)

    conv_in = jnp.concatenate([xs, Bc, Cc], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"], conv_state)
    conv_out = jax.nn.silu(conv_out)
    xs, Bc, Cc = jnp.split(conv_out, [di, di + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])     # (B,S,H)
    A = -jnp.exp(p["A_log"])                                        # (H,)
    xh = xs.reshape(B_, S, H, P)

    if cache is not None and S == 1:
        h, y = ssd_decode_step(cache["ssm"], xh[:, 0].astype(jnp.float32),
                               dt[:, 0], A, Bc[:, 0].astype(jnp.float32),
                               Cc[:, 0].astype(jnp.float32))
        y = y[:, None].astype(x.dtype)                              # (B,1,H,P)
        new_cache = {"conv": new_conv, "ssm": h}
    else:
        ck = chunk if S % chunk == 0 else S
        h0 = cache["ssm"] if cache is not None else None
        y, h = ssd_chunked(xh, dt, A, Bc, Cc, h0=h0, chunk=ck)
        new_cache = {"conv": new_conv, "ssm": h} if cache is not None else None

    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B_, S, di)
    # gated RMSNorm (mamba2 style)
    g = y * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    g = g * jax.lax.rsqrt(ms + 1e-6) * p["norm_scale"]
    return g.astype(x.dtype) @ p["out_proj"], new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    P = di // H
    conv_dim = di + 2 * N
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
    }
