"""Flash attention Pallas TPU kernel (causal + sliding-window).

TPU-native adaptation: instead of a CUDA warp-level streaming softmax, the
kernel tiles Q into MXU-aligned (block_q x head_dim) VMEM blocks and
iterates KV blocks along an 'arbitrary' grid dimension, carrying the
online-softmax state (m, l, acc) in VMEM scratch between grid steps —
the canonical TPU flash pattern (HBM -> VMEM via BlockSpec, compute on the
MXU, no S x S materialisation).

Layout: inputs are (BH, S, D) with batch*heads flattened into the leading
grid dimension; GQA head-repeat happens in ops.py before the call.

Validated on CPU with interpret=True against kernels/ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, seq_len: int, causal: bool,
                  window, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # skip fully-masked KV blocks (beyond the causal frontier / window)
    first_q = qi * block_q
    last_q = first_q + block_q - 1
    first_k = ki * block_k
    last_k = first_k + block_k - 1
    need = True
    if causal:
        need = jnp.asarray(first_k <= last_q)
    if window is not None:
        need = jnp.logical_and(need, jnp.asarray(last_k > first_q - window))

    @pl.when(need)
    def _compute():
        q = q_ref[...].astype(jnp.float32) * scale        # (block_q, d)
        k = k_ref[...].astype(jnp.float32)                # (block_k, d)
        v = v_ref[...].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        mask = k_pos < seq_len
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                               # (block_q, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                            # (block_q, block_k)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_scr[...]
        o_ref[...] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window=None,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = False):
    """q, k, v: (BH, S, D) — same head count (repeat GQA beforehand)."""
    BH, S, D = q.shape
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    grid = (BH, S // block_q, S // block_k)
    scale = D ** -0.5

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, seq_len=S,
        causal=causal, window=window, scale=scale)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, D), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((None, block_k, D), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((None, block_k, D), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, D), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum l
            pltpu.VMEM((block_q, D), jnp.float32),   # output accumulator
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
