"""Chunked Mamba2/SSD scan as a Pallas TPU kernel.

TPU-native adaptation of the SSD algorithm (arXiv:2405.21060): the GPU
implementation leans on warp-level scans; on TPU we tile the sequence into
(chunk x P) VMEM blocks, compute the intra-chunk quadratic term on the MXU
(chunk-sized matmuls are MXU-aligned at chunk=128, P=64..128), and carry
the inter-chunk SSM state (P x N) in VMEM scratch across an 'arbitrary'
grid dimension — the recurrence becomes a grid-carried accumulator exactly
like flash attention's (m, l, acc).

Layouts: x (B, H, S, P); dt (B, H, S, 1); A (H, 1, 1); Bm/Cm (B, S, N)
shared across heads.  Outputs: y (B, H, S, P) and the final state
(B, H, P, N) written at the last chunk step.

Validated on CPU with interpret=True against kernels/ref.py
(ssd_reference — the sequential recurrence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hf_ref, h_scr, *,
                chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[...].astype(jnp.float32)            # (chunk, P)
    dt = dt_ref[...].astype(jnp.float32)          # (chunk, 1)
    A = a_ref[0, 0]                               # scalar
    Bm = b_ref[...].astype(jnp.float32)           # (chunk, N)
    Cm = c_ref[...].astype(jnp.float32)           # (chunk, N)

    loga = dt[:, 0] * A                           # (chunk,)
    Lc = jnp.cumsum(loga)                         # inclusive
    idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jdx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = idx >= jdx

    CB = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)
    delta = Lc[:, None] - Lc[None, :]
    delta = jnp.where(causal, delta, 0.0)         # mask exponent pre-exp
    M = CB * jnp.exp(delta) * dt[:, 0][None, :]
    M = jnp.where(causal, M, 0.0)
    y_intra = jnp.dot(M, x, preferred_element_type=jnp.float32)

    h = h_scr[...]                                # (P, N)
    y_state = jnp.dot(Cm, h.T,
                      preferred_element_type=jnp.float32) * jnp.exp(Lc)[:, None]

    w = jnp.exp(Lc[-1] - Lc) * dt[:, 0]           # (chunk,)
    h_new = jnp.exp(Lc[-1]) * h + jnp.dot(
        (x * w[:, None]).T, Bm, preferred_element_type=jnp.float32)
    h_scr[...] = h_new

    y_ref[...] = (y_intra + y_state).astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _final():
        hf_ref[...] = h_scr[...].astype(hf_ref.dtype)


def ssd_scan_bhsp(x, dt, A, Bm, Cm, *, chunk: int = 128,
                  interpret: bool = False):
    """x: (B, H, S, P); dt: (B, H, S); A: (H,); Bm/Cm: (B, S, N).

    Returns (y (B, H, S, P), h_final (B, H, P, N)) with zero initial state.
    """
    B, H, S, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    grid = (B, H, S // chunk)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, hf = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((None, None, chunk, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((None, 1, 1), lambda b, h, c: (h, 0, 0)),
            pl.BlockSpec((None, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((None, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((None, None, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt.reshape(B, H, S, 1), A.reshape(H, 1, 1), Bm, Cm)
    return y, hf
