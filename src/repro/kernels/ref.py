"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels must match (tests sweep shapes and
dtypes against them, interpret=True on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# SSD oracle: the sequential recurrence (also used by the model code)
from repro.models.ssm import ssd_reference  # noqa: F401  (re-export)


def attention_reference(q, k, v, *, causal: bool = True, window=None):
    """q, k, v: (BH, S, D) — plain softmax attention, f32 math."""
    BH, S, D = q.shape
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_scan_reference(x, dt, A, Bm, Cm):
    """Kernel-layout wrapper around ssd_reference.

    x: (B, H, S, P); dt: (B, H, S); A: (H,); Bm/Cm: (B, S, N)
    -> (y (B, H, S, P), h_final (B, H, P, N))
    """
    xs = x.transpose(0, 2, 1, 3)           # (B, S, H, P)
    dts = dt.transpose(0, 2, 1)            # (B, S, H)
    y, hf = ssd_reference(xs, dts, A, Bm, Cm)
    return y.transpose(0, 2, 1, 3).astype(x.dtype), hf
