"""Jit'd public wrappers for the Pallas kernels.

On a real TPU runtime the kernels compile natively; on the CPU container
they run in interpret mode (``REPRO_KERNEL_INTERPRET=1``, the default when
no TPU is present) so correctness is testable everywhere.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.ssd_scan import ssd_scan_bhsp


def _interpret() -> bool:
    env = os.environ.get("REPRO_KERNEL_INTERPRET")
    if env is not None:
        return env == "1"
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    block_q: int = 128, block_k: int = 128):
    """q: (B, S, H, hd); k, v: (B, S, KH, hd) — GQA handled here.

    Returns (B, S, H, hd).
    """
    B, S, H, D = q.shape
    KH = k.shape[2]
    rep = H // KH
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    out = flash_attention_bhsd(qf, kf, vf, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=_interpret())
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128):
    """Model-layout SSD: x (B, S, H, P), dt (B, S, H), Bm/Cm (B, S, N).

    Returns (y (B, S, H, P), h_final (B, H, P, N)).
    """
    xk = x.transpose(0, 2, 1, 3)
    dtk = dt.transpose(0, 2, 1)
    y, hf = ssd_scan_bhsp(xk, dtk, A, Bm, Cm, chunk=chunk,
                          interpret=_interpret())
    return y.transpose(0, 2, 1, 3), hf
