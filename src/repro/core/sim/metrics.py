"""Metrics layer of the simulation engine (paper Tables II/III columns).

Shared by the fast event core (`repro.core.sim.engine`) and the
pre-refactor reference loop (`repro.core.sim.reference`) so benchmark
comparisons read the same records.

`IterationMetrics` carries the paper's per-iteration columns (duration,
time per microbatch, throughput, communication time, wasted GPU time,
aggregation time) plus the engine's observability fields: processed
event count, event-loop wall time, reroute count, peak/total relay
queue depth, and a `truncated` flag set when the event budget
(`max_events`) was exhausted before the calendar drained — a truncated
iteration reports a *lower bound* on duration, not a clean result.

The serving plane adds `RequestMetrics` (per-decode-request lifecycle:
arrival, first token, completion — TTFT/TPOT derive from these) and
`ServingIterationMetrics` (the per-iteration conservation ledger);
`summarize_serving` pools them into the p50/p99 TTFT/TPOT row the
serving bench and golden files pin.

`summarize` folds a run's iteration list into table-style mean/std
pairs — the Table II/III columns plus the queue-depth and
reroute-count series (used by `examples/churn_recovery.py`; the crash
benchmarks keep their own fold because their cells carry
per-repetition stds in paper units).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclass
class ModelProfile:
    """Per-stage costs derived from a ModelConfig split into stages."""
    fwd_compute: float            # seconds per microbatch per stage (forward)
    bwd_mult: float = 2.0         # backward = bwd_mult * forward
    activation_bytes: float = 4 * 512 * 1024 * 2 * 32
    stage_param_bytes: float = 50e6 * 2

    @classmethod
    def from_config(cls, cfg, *, num_stages: int, microbatch: int = 4,
                    seq_len: int = 512, comm_scale: float = 32.0,
                    flops_per_sec: float = 2.0e13):
        layers_per_stage = max(1, cfg.num_layers // num_stages)
        # 6ND for train fwd+bwd; fwd alone is 2ND
        params_per_layer = (cfg.param_count() - 2 * cfg.vocab_size * cfg.d_model
                            ) / cfg.num_layers
        tokens = microbatch * seq_len
        fwd_flops = 2 * params_per_layer * layers_per_stage * tokens
        act = microbatch * seq_len * cfg.d_model * 2 * comm_scale
        return cls(fwd_compute=fwd_flops / flops_per_sec,
                   activation_bytes=act,
                   stage_param_bytes=params_per_layer * layers_per_stage * 2)


@dataclass
class IterationMetrics:
    duration: float = 0.0
    completed: int = 0
    launched: int = 0
    comm_time: float = 0.0
    wasted_gpu: float = 0.0
    aggregation_time: float = 0.0
    # --- engine observability (new in the layered engine) -------------
    events: int = 0               # calendar pops processed this iteration
    loop_seconds: float = 0.0     # wall time spent inside the event loop
    plan_seconds: float = 0.0     # wall time spent in policy.plan()
    #   (planning vs event-loop split: surfaced by bench_sim --profile)
    reroutes: int = 0             # successful fault reroutes/restarts
    queue_depth_peak: int = 0     # max concurrent queued microbatches
    queue_enqueues: int = 0       # total capacity-wait enqueues
    truncated: bool = False       # max_events exhausted before drain
    plan_overrun: bool = False    # plan_seconds blew past the engine's
    #   plan_overrun_factor x loop_seconds guard (policy was asked to
    #   throttle its planning effort)
    cost_ratio_vs_optimal: Optional[float] = None
    #   live optimality gap: (this iteration's planned-flow cost) /
    #   (dial MinCostFlow oracle cost on the same alive network); None
    #   unless the policy tracks it (GWTFPolicy(track_optimality=True))
    bytes_on_wire: float = 0.0    # encoded bytes actually moved by comm
    #   legs this iteration (= raw activation bytes x the chosen wire
    #   codec's ratio per leg; equals sends * activation_bytes when the
    #   network's codec menu is fp32-only)
    codec_legs: Optional[Dict[str, int]] = None
    #   chosen-codec histogram over comm legs ({codec name: leg count});
    #   None when the menu is trivial (every leg fp32)
    timeouts: int = 0             # deadline (CHECK) fires that found a
    #   stalled microbatch — dead, hung, or dropped-delivery receiver
    retries: int = 0              # recovery attempts spent (bounded by
    #   max_retries per microbatch; includes flaky-leg resends)

    @property
    def time_per_microbatch(self) -> float:
        return self.duration / max(1, self.completed)

    @property
    def events_per_sec(self) -> float:
        return self.events / self.loop_seconds if self.loop_seconds > 0 else 0.0


#: (metric label, per-iteration extractor) pairs for `summarize`.
_COLUMNS = (
    ("time_per_mb", lambda m: m.time_per_microbatch),
    ("throughput", lambda m: float(m.completed)),
    ("comm_time", lambda m: m.comm_time),
    ("wasted_gpu", lambda m: m.wasted_gpu),
    ("aggregation_time", lambda m: m.aggregation_time),
    ("reroutes", lambda m: float(m.reroutes)),
    ("queue_depth_peak", lambda m: float(m.queue_depth_peak)),
    ("queue_enqueues", lambda m: float(m.queue_enqueues)),
    ("bytes_on_wire", lambda m: m.bytes_on_wire),
    ("timeouts", lambda m: float(m.timeouts)),
    ("retries", lambda m: float(m.retries)),
)


def _percentile(xs: List[float], q: float) -> float:
    """Deterministic linear-interpolation percentile (numpy default)."""
    if not xs:
        return 0.0
    return float(np.percentile(np.asarray(xs, dtype=float), q))


@dataclass
class RequestMetrics:
    """One decode request's lifecycle through the serving plane.

    All times are simulated seconds on the engine's global clock.
    ``first_token``/``completion`` stay ``None`` while the request is
    in flight; under the drop-and-retry baseline a restart resets
    ``first_token``, so TTFT always measures arrival to the first token
    of the attempt that ultimately completed (the latency a client
    actually observes).
    """
    rid: int
    arrival: float
    prompt_len: int
    gen_tokens: int
    first_token: Optional[float] = None
    completion: Optional[float] = None
    requeues: int = 0             # defended chain migrations survived
    restarts: int = 0             # drop-and-retry from-scratch attempts
    migrated_kv_bytes: float = 0.0
    dropped: bool = False

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token (arrival -> first decoded token)."""
        if self.first_token is None:
            return None
        return self.first_token - self.arrival

    @property
    def tpot(self) -> Optional[float]:
        """Time per output token after the first (steady decode rate)."""
        if self.completion is None or self.first_token is None:
            return None
        if self.gen_tokens <= 1:
            return 0.0
        return (self.completion - self.first_token) / (self.gen_tokens - 1)


@dataclass
class ServingIterationMetrics:
    """Per-iteration serving ledger (the request-conservation unit).

    ``admitted``/``completed``/``dropped`` count events *within* the
    iteration; ``in_flight`` is the end-of-iteration census, so the
    cumulative invariant ``sum(admitted) == sum(completed) +
    sum(dropped) + in_flight`` must hold exactly after every iteration.
    """
    admitted: int = 0
    completed: int = 0
    dropped: int = 0
    in_flight: int = 0            # end-of-iteration census
    queued: int = 0               # subset of in_flight not yet on a chain
    requeues: int = 0             # defended reroutes of live sequences
    restarts: int = 0             # drop-and-retry from-scratch attempts
    migrated_kv_bytes: float = 0.0
    kv_peak: int = 0              # max resident sequences on any node
    ttfts: List[float] = None     # TTFTs of requests completed this iter
    tpots: List[float] = None

    def __post_init__(self):
        if self.ttfts is None:
            self.ttfts = []
        if self.tpots is None:
            self.tpots = []


def summarize_serving(
        metrics: List["ServingIterationMetrics"]) -> Dict[str, float]:
    """Fold a serving run into the bench/golden scalar row.

    Latency percentiles (p50/p99 TTFT and TPOT, simulated seconds) pool
    every completed request across iterations; the counters are run
    totals.  All values are deterministic functions of the spec, so the
    row pins byte-for-byte in golden files.
    """
    ttfts = [t for m in metrics for t in m.ttfts]
    tpots = [t for m in metrics for t in m.tpots]
    return {
        "admitted": float(sum(m.admitted for m in metrics)),
        "completed": float(sum(m.completed for m in metrics)),
        "dropped": float(sum(m.dropped for m in metrics)),
        "in_flight": float(metrics[-1].in_flight) if metrics else 0.0,
        "requeues": float(sum(m.requeues for m in metrics)),
        "restarts": float(sum(m.restarts for m in metrics)),
        "migrated_kv_bytes": float(
            sum(m.migrated_kv_bytes for m in metrics)),
        "kv_peak": float(max((m.kv_peak for m in metrics), default=0)),
        "p50_ttft": _percentile(ttfts, 50.0),
        "p99_ttft": _percentile(ttfts, 99.0),
        "p50_tpot": _percentile(tpots, 50.0),
        "p99_tpot": _percentile(tpots, 99.0),
    }


def summarize(metrics: List[IterationMetrics], *,
              warmup: int = 0) -> Dict[str, Tuple[float, float]]:
    """Fold per-iteration metrics into `{column: (mean, std)}` rows.

    Covers the paper's Table II/III columns plus the engine's
    queue-depth and reroute-count series.  `warmup` iterations are
    dropped from the front (pipeline fill).  Also reports
    `truncated_iterations` as (count, 0.0) so silent event-budget
    exhaustion shows up in any table built from this summary.
    """
    ms = metrics[warmup:]
    if not ms:
        return {}
    out = {name: (float(np.mean([fn(m) for m in ms])),
                  float(np.std([fn(m) for m in ms])))
           for name, fn in _COLUMNS}
    out["truncated_iterations"] = (float(sum(m.truncated for m in ms)), 0.0)
    return out
