"""Event core of the layered simulation engine (paper Sec. VI).

`SimulationEngine` runs the discrete-event clock that times one
training iteration: per-node compute slots (capacity) with FIFO
queueing, per-link transfer delays, mid-iteration crashes, and
timeout-based fault discovery.  Routing and recovery decisions are
delegated to a `RoutingPolicy` (scheduler layer) and crash/rejoin
sampling to a `ChurnModel` (fault layer), so the core contains no
scheduler- or fault-specific branches.

Design of the fast core
-----------------------
* **Typed event records.**  Events are flat 7-tuples
  ``(time, seq, kind, mb, node, leg, frm)`` with integer kinds
  (ARRIVE/DONE/CHECK) — no nested payload tuples, no string dispatch.
  ``seq`` is a global monotonic counter so simultaneous events pop in
  push order (deterministic FIFO tie-break).
* **Two-level batched calendar.**  The calendar is split into a small
  ``near`` binary heap (every pending event with time ≤ a moving
  boundary ``B``) and an unsorted ``far`` list (everything later).
  Pushes compare once against ``B`` and either ``heappush`` into
  ``near`` or plain-``append`` to ``far``; when ``near`` drains, one
  bulk ``far.sort()`` (Timsort in C over the ``(time, seq, ...)``
  records) promotes the next batch — at least 256 events, half of
  ``far`` when larger, always extended across time ties so ``far``
  holds strictly-later events only.  A sorted ascending run is already
  a valid min-heap, so promotion is a slice, and every ``heappush`` /
  ``heappop`` works a heap of batch size instead of total calendar
  residency — that breaks the ~µs/event floor a single monolithic heap
  hits past ~10k concurrent microbatches (log-factor tuple compares
  per operation), while bulk Timsort amortizes ordering at
  O(log batch) compares per event.  Pop order is *provably identical*
  to the single heap: ``far`` only ever holds events strictly later
  than everything in ``near``, and ``(time, seq)`` is a unique total
  order (so sorting never compares the payload fields).  (A bucketed
  calendar queue was measured slower here: its per-event bucket scan
  runs in bytecode, while the sort/heap primitives run in C.)
* **Lazy timeout records.**  The pre-refactor loop pushed one CHECK
  event per send; in a healthy iteration every one of them pops stale.
  A timeout can only ever *fire* if the microbatch actually stalled,
  and the loop observes every stall directly: an arrival dropped at a
  dead receiver, a compute lost to a mid-compute crash, or a
  capacity-wait enqueue.  The core therefore materializes the CHECK
  record (with the deadline computed at send time, so fire times are
  bit-identical) only at those three points.  This removes a third of
  all calendar traffic and keeps the calendar an order of magnitude
  smaller — long-deadline timeout records no longer dominate its
  residency.  Caveat: on calendars with *exactly* tying float
  timestamps (e.g. all-integer link costs) a fired timeout may
  tie-break differently against a simultaneous arrival than the
  reference loop; on the continuous geo topologies used by the tests
  and benchmarks, seeded runs are metric- and RNG-identical.
* **Batched cost lookups.**  All per-event cost queries are resolved
  against per-iteration tables derived from ``FlowNetwork``'s cached
  Eq. 1 matrices: the dense communication and edge-cost matrices
  (``FlowNetwork.comm_matrix`` / ``edge_matrix`` at the profile's
  activation size, lowered to nested Python lists so the hot loop and
  the fault path do plain float indexing) and per-node
  forward/backward compute-time vectors.  The pre-refactor loop
  resolved every one of these through two or three method calls per
  event.
* **Per-iteration event accounting.**  The loop counts calendar pops,
  capacity-wait enqueues, peak queue depth, reroutes, and its own wall
  time into `IterationMetrics` (``events``, ``events_per_sec``), which
  is what ``benchmarks/bench_sim.py`` measures against the
  pre-refactor loop kept in `repro.core.sim.reference`.

Semantics are identical to the pre-refactor ``TrainingSimulator``
(same RNG stream, same float arithmetic, same tie-breaking) with two
deliberate, documented exceptions:

* the SWARM backward-restart slot leak is fixed — restarting
  microbatches release their slots through ``release_slot`` so queued
  microbatches behind them wake immediately instead of stalling until
  their sender's timeout;
* ``max_events`` exhaustion is surfaced (``IterationMetrics.truncated``
  + a ``RuntimeWarning``) instead of silently reporting a short, clean
  iteration.

Planning-overrun guard: when ``policy.plan()`` wall time exceeds the
event-loop wall time by ``plan_overrun_factor`` (and is long enough in
absolute terms to matter — ``plan_overrun_min_seconds``), the engine
warns, flags the iteration (``IterationMetrics.plan_overrun``), and
asks the policy to cap its planning effort via an optional
``throttle_planning()`` hook — a planner regression now surfaces in CI
profiles instead of silently turning the simulator superlinear.
"""
from __future__ import annotations

import heapq
import itertools
import time
import warnings
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.flow.graph import FlowNetwork
from repro.core.sim.faults import (BernoulliChurn, ChurnContext, ChurnModel,
                                   adversarial_plan)
from repro.core.sim.metrics import (IterationMetrics, ModelProfile,
                                    RequestMetrics, ServingIterationMetrics)
from repro.core.sim.policies import FaultView, RoutingPolicy
from repro.core.sim.timeline import FaultTimeline, record_injections

# Typed event kinds (ints: cheap compares, no string dispatch)
ARRIVE, DONE, CHECK = 0, 1, 2

# two-level calendar: minimum promotion batch (events) pulled from the
# far list each time the near heap drains
_PROMOTE_MIN = 256


@dataclass(slots=True)
class _MB:
    """One microbatch's lifecycle."""
    id: int
    data_node: int
    path: List[int]                   # planned chain (GWTF) / realised (SWARM)
    pos: int = 0                      # index into path
    direction: str = "fwd"
    compute_history: List[Tuple[int, float]] = field(default_factory=list)
    slots: set = field(default_factory=set)   # nodes holding memory for us
    leg: int = 0                  # increments on every send; stale events ignored
    retries: int = 0
    done: bool = False
    failed: bool = False
    # current leg's timeout deadline + sender, stamped by send() so a
    # lazily-materialized CHECK record is bit-identical to an eager one
    deadline: float = 0.0
    sent_from: int = -1
    # node whose capacity-wait queue currently holds us (-1 = none);
    # lets the queue-depth gauge drop entries that leave the waiting
    # state sideways (rerouted away, failed, stranded at a crashed
    # node) instead of only when their queue entry is popped
    wait_node: int = -1
    # adversarial per-leg markers: the leg whose delivery was dropped
    # by a flaky link / whose receiver is a deadline-catchable
    # straggler (-1 = none).  Lets the CHECK handler attribute the
    # fired deadline to its cause without payload-tuple changes.
    dropped_leg: int = -1
    slow_leg: int = -1


class SimulationEngine:
    """The event core: policy + churn model + profile -> timed iterations.

    Memory semantics: a relay node's capacity counts *in-flight*
    microbatches — the slot is held from forward arrival until the
    backward pass completes at that node (activations must be kept for
    the backward).  This is exactly why heterogeneous capacities
    matter: SWARM routes capacity-blind and serialises on cap-1 nodes;
    GWTF's flows respect capacity by construction.
    """

    def __init__(self, net: FlowNetwork, policy: RoutingPolicy, *,
                 churn_model: Optional[ChurnModel] = None,
                 profile: Optional[ModelProfile] = None,
                 timeout: float = 30.0, max_retries: int = 2,
                 rng: Optional[np.random.Generator] = None,
                 max_events: int = 500_000,
                 plan_overrun_factor: float = 100.0,
                 plan_overrun_min_seconds: float = 0.5,
                 deadline_defense: bool = True,
                 corrupt_screen: bool = True):
        self.net = net
        self.policy = policy
        self.churn_model = churn_model or BernoulliChurn(0.0)
        self.profile = profile or ModelProfile(fwd_compute=2.0)
        self.timeout = timeout
        self.max_retries = max_retries
        # adversarial defenses: deadline-triggered re-dispatch for
        # hung/straggling/dropped legs, and the (modelled) gradient
        # screen for corrupt contributions.  Both are inert unless the
        # churn model publishes an AdversarialPlan.
        self.deadline_defense = deadline_defense
        self.corrupt_screen = corrupt_screen
        self.timeline = FaultTimeline()
        self.rng = rng or np.random.default_rng(0)
        self.max_events = max_events
        self.plan_overrun_factor = plan_overrun_factor
        self.plan_overrun_min_seconds = plan_overrun_min_seconds
        self._mb_ids = itertools.count()
        self._iteration = 0
        self._tables_key = None          # (cost_version, size, N)
        self._comm_rows: List[List[float]] = []
        self._edge_rows: List[List[float]] = []
        self._codec_names: Tuple[str, ...] = ("fp32",)
        self._codec_rows: Optional[List[List[int]]] = None
        self._legbytes_rows: Optional[List[List[float]]] = None
        self._node_tables_key = None     # (cost_version, N)
        self._fwd_t: List[float] = []
        self._bwd_t: List[float] = []
        self._caps: List[int] = []

    # ------------------------------------------------------------------
    # Batched per-iteration cost tables
    # ------------------------------------------------------------------
    def _cost_tables(self, n_nodes: int) -> Tuple[List[List[float]],
                                                  List[List[float]]]:
        """Dense comm-only and full-edge Eq. 1 matrices at the profile's
        activation size, lowered to nested lists (plain-float reads in
        the hot loop and the fault path).  Rebuilt only when the
        network's cost epoch moves.

        With a non-trivial wire-codec menu the matrices are already
        codec-priced (encoded bytes + encode/decode delay baked into
        each entry by ``FlowNetwork``); this also lowers the per-link
        chosen-codec indices and encoded-bytes-per-leg tables the event
        loop charges ``bytes_on_wire`` / ``codec_legs`` against."""
        key = (self.net.cost_version, self.profile.activation_bytes, n_nodes)
        if key != self._tables_key:
            size = self.profile.activation_bytes
            self._comm_rows = self.net.comm_matrix(size)[
                :n_nodes, :n_nodes].tolist()
            self._edge_rows = self.net.edge_matrix(size)[
                :n_nodes, :n_nodes].tolist()
            names = self.net.wire_codec_names()
            self._codec_names = names
            if len(names) > 1:
                choice = self.net.wire_codec_matrix(size)[:n_nodes, :n_nodes]
                ratios = self.net.wire_codec_ratios()
                self._codec_rows = choice.tolist()
                self._legbytes_rows = (ratios[choice] * float(size)).tolist()
            else:
                self._codec_rows = None
                self._legbytes_rows = None
            self._tables_key = key
        return self._comm_rows, self._edge_rows

    def _estimate_iteration(self) -> float:
        S = self.net.num_stages
        costs = [n.compute_cost for n in self.net.alive_nodes() if not n.is_data]
        mean_c = float(np.mean(costs)) if costs else 1.0
        per_hop = mean_c * (1 + self.profile.bwd_mult)
        return max(60.0, S * (per_hop + 10.0))

    # ------------------------------------------------------------------
    # One training iteration
    # ------------------------------------------------------------------
    def run_iteration(self) -> IterationMetrics:
        net = self.net
        m = IterationMetrics()

        # ---- fault layer: sample crashes/rejoins ----------------------
        it = self._iteration
        crash_times = self.churn_model.sample(ChurnContext(
            net=net, rng=self.rng, horizon=self._estimate_iteration(),
            iteration=it, on_rejoin=self.policy.on_rejoin))
        self._iteration += 1
        # adversarial side channel (None for plain fail-stop models —
        # every branch it gates below is then skipped, keeping the
        # fail-stop event stream bit-identical to the reference loop)
        adv = adversarial_plan(self.churn_model, it)
        record_injections(self.timeline, it, crash_times, adv)
        slow = adv.slow if adv is not None else {}
        hung = adv.hung if adv is not None else frozenset()
        corrupt = adv.corrupt if adv is not None else {}
        flaky = adv is not None and bool(adv.flaky)
        deadline_defense = self.deadline_defense

        # ---- scheduler layer: build this iteration's paths ------------
        plan_t0 = time.perf_counter()
        mbs = [_MB(next(self._mb_ids), path[0], list(path))
               for path in self.policy.plan()]
        m.plan_seconds = time.perf_counter() - plan_t0
        m.launched = len(mbs)
        m.cost_ratio_vs_optimal = getattr(self.policy,
                                          "last_cost_ratio", None)

        # ---- batched cost tables (resolved against the Eq. 1 caches) --
        N = (max(net.nodes) + 1) if net.nodes else 0
        comm, edge = self._cost_tables(N)
        # node-attribute tables: compute times and capacities move only
        # with the cost epoch / membership size, so they are part of the
        # reusable planning context; liveness is per-iteration state
        nt_key = (net.cost_version, N)
        if nt_key != self._node_tables_key:
            fwd_t = [0.05] * N
            caps = [0] * N
            for nid, node in net.nodes.items():
                fwd_t[nid] = max(0.05, node.compute_cost)
                caps[nid] = node.capacity
            bwd_mult = self.profile.bwd_mult
            self._fwd_t = fwd_t
            self._bwd_t = [c * bwd_mult for c in fwd_t]
            self._caps = caps
            self._node_tables_key = nt_key
        fwd_t, bwd_t, caps = self._fwd_t, self._bwd_t, self._caps
        # effective compute times under straggler slowdowns; deadlines
        # keep being stamped from the *healthy* tables (fwd_t/bwd_t in
        # send()), which is exactly what lets the deadline catch a
        # pathological slowdown
        if slow:
            eff_fwd, eff_bwd = list(fwd_t), list(bwd_t)
            for s_nid, s_f in slow.items():
                if s_nid < N:
                    eff_fwd[s_nid] *= s_f
                    eff_bwd[s_nid] *= s_f
        else:
            eff_fwd, eff_bwd = fwd_t, bwd_t
        alive = [False] * N
        for nid, node in net.nodes.items():
            alive[nid] = node.alive
        INF = float("inf")
        crash = [INF] * N
        for nid, ct in crash_times.items():
            crash[nid] = ct

        # ---- per-iteration node state ---------------------------------
        busy = [0] * N
        queues = [deque() for _ in range(N)]   # capacity-wait FIFOs

        view = FaultView()
        view.net = net
        view.activation_bytes = self.profile.activation_bytes
        # hung nodes (and stragglers slow enough that the deadline is
        # guaranteed to fire on any forward leg) are alive but useless
        # this iteration: mark them crashed-at-0 in the *policy's* view
        # (not the engine's own liveness tables) so recovery never
        # substitutes a microbatch onto one.  The runtime's
        # RecoveryManager applies the same predicate to its view.
        blocked = set(hung)
        for s_nid, s_f in slow.items():
            if s_nid < N and fwd_t[s_nid] * (s_f - 1.0) > self.timeout:
                blocked.add(s_nid)
        if blocked:
            vcrash = list(crash)
            for b_nid in blocked:
                if b_nid < N:
                    vcrash[b_nid] = 0.0
            view.alive, view.crash = alive, vcrash
        else:
            view.alive, view.crash = alive, crash
        view.busy, view.queues = busy, queues
        view.fwd_t, view.bwd_t = fwd_t, bwd_t
        view.comm_rows, view.edge_rows = comm, edge
        _stage_cache: Dict[int, list] = {}

        def stage_nodes(s: int) -> list:
            nodes = _stage_cache.get(s)
            if nodes is None:
                nodes = net.stage_nodes(s)     # membership frozen mid-loop
                _stage_cache[s] = nodes
            return nodes

        view.stage_nodes = stage_nodes

        # ---- event calendar (two-level: near heap + far list) ---------
        near: List[tuple] = []        # heap: every pending event t <= boundary
        far: List[tuple] = []         # unsorted: every pending event t > boundary
        boundary = float("-inf")      # initial launches bulk-sort on first pop
        heappush, heappop = heapq.heappush, heapq.heappop
        far_append = far.append
        seq = itertools.count()
        timeout = self.timeout
        comm_total = 0.0
        qdepth = 0
        sends = 0
        timeouts_ctr = 0
        retries_ctr = 0
        rep_reports: List[int] = []       # detection-attributed nodes
        wire_bytes = 0.0
        codec_rows, legb = self._codec_rows, self._legbytes_rows
        codec_hist = [0] * len(self._codec_names)

        def push(ev: tuple):
            if ev[0] <= boundary:
                heappush(near, ev)
            else:
                far_append(ev)

        def send(mb: _MB, frm: int, to: int, t: float):
            nonlocal comm_total, sends, wire_bytes
            mb.leg += 1
            c = comm[frm][to]
            comm_total += c
            sends += 1
            if legb is not None:
                # leg priced at the link's chosen codec: encoded bytes
                # on the wire, encode/decode delay already inside c
                wire_bytes += legb[frm][to]
                codec_hist[codec_rows[frm][to]] += 1
            # sender expects a COMPLETE within comm+compute+timeout; a slow
            # (overloaded) peer is indistinguishable from a dead one.  The
            # CHECK record itself is materialized lazily, at the stall.
            expect = c + (bwd_t[to] if mb.direction == "bwd"
                          else fwd_t[to]) + timeout
            mb.deadline = t + expect
            mb.sent_from = frm
            if (flaky and to != mb.data_node
                    and not adv.leg_ok(it, mb.id, mb.direction, mb.pos,
                                       mb.retries)):
                # delivery dropped on the wire (bytes were still spent):
                # the receiver never sees the ARRIVE, so the stall point
                # is known immediately — materialize the CHECK now
                mb.dropped_leg = mb.leg
                push((mb.deadline, next(seq), CHECK, mb, to, mb.leg, frm))
                return
            push((t + c, next(seq), ARRIVE, mb, to, mb.leg, frm))

        def release_slot(mb: _MB, nid: int, t: float):
            nonlocal qdepth
            if nid not in mb.slots:
                return
            mb.slots.discard(nid)
            busy[nid] -= 1
            q = queues[nid]
            while q and alive[nid] and t < crash[nid]:
                qmb, qleg = q.popleft()
                if qmb.done or qmb.failed or qleg != qmb.leg:
                    continue                       # stale queue entry
                qdepth -= 1
                qmb.wait_node = -1
                busy[nid] += 1
                qmb.slots.add(nid)
                push((t + (eff_bwd[nid] if qmb.direction == "bwd"
                           else eff_fwd[nid]),
                      next(seq), DONE, qmb, nid, qleg, -1))
                break

        def fail(mb: _MB, t: float):
            mb.failed = True
            m.wasted_gpu += sum(c for _, c in mb.compute_history)
            for nid in list(mb.slots):
                release_slot(mb, nid, t)

        def recover(mb: _MB, frm: int, dead: int, t: float):
            """Sender `frm` noticed `dead` is unresponsive."""
            nonlocal qdepth, retries_ctr
            if mb.wait_node >= 0:
                # leaving the waiting state sideways: the queue entry
                # goes stale (popped-and-skipped later, or stranded at a
                # crashed node) — drop it from the depth gauge now
                qdepth -= 1
                mb.wait_node = -1
            if mb.retries >= self.max_retries:
                fail(mb, t)
                return
            mb.retries += 1
            retries_ctr += 1
            decision = self.policy.recover(view, mb, frm, dead, t)
            kind = decision[0]
            if kind == "substitute":
                sub, delay = decision[1], decision[2]
                m.reroutes += 1
                mb.path[mb.pos] = sub
                send(mb, frm, sub, t + delay)
            elif kind == "restart":
                # full pipeline recomputation from the data node: all
                # forward work so far is wasted and every held slot is
                # released (through release_slot, so microbatches queued
                # behind this one wake up instead of waiting out their
                # sender's timeout — the pre-refactor loop leaked these
                # slots by decrementing busy directly).
                m.wasted_gpu += sum(c for _, c in mb.compute_history)
                mb.compute_history.clear()
                for nid2 in list(mb.slots):
                    release_slot(mb, nid2, t)
                path = decision[1]
                if path is None:
                    fail(mb, t)
                    return
                m.reroutes += 1
                mb.path = list(path)
                mb.direction = "fwd"
                mb.pos = 1
                send(mb, mb.data_node, mb.path[1], t)
            else:
                fail(mb, t)

        # ---- event loop -----------------------------------------------
        loop_t0 = time.perf_counter()
        for mb in mbs:
            mb.pos = 1
            send(mb, mb.data_node, mb.path[1], 0.0)

        end_time = 0.0
        completed = 0
        pops = 0
        max_events = self.max_events
        qdepth_peak = 0
        enqueues = 0
        while pops < max_events:
            if near:
                ev = heappop(near)
            elif far:
                # promotion: one bulk Timsort, then slice off the next
                # batch.  (time, seq) is unique, so the sort never
                # compares payload fields; the ascending run is already
                # a valid min-heap.  Extending across time ties keeps
                # the invariant that far holds strictly-later events.
                far.sort()
                nf = len(far)
                k = nf if nf <= _PROMOTE_MIN else max(_PROMOTE_MIN, nf >> 1)
                while k < nf and far[k][0] == far[k - 1][0]:
                    k += 1
                near.extend(far[:k])
                del far[:k]
                boundary = near[-1][0]
                ev = heappop(near)
            else:
                break
            pops += 1
            t, _, kind, mb, nid, leg, frm = ev
            if mb.done or mb.failed:
                continue
            if kind == ARRIVE:
                if leg != mb.leg:
                    continue                       # rerouted while in flight
                if not (alive[nid] and t < crash[nid]):
                    # dead receiver: the mb stalls until the sender's
                    # timeout — materialize the CHECK record now
                    push((mb.deadline, next(seq), CHECK, mb, nid, leg, frm))
                    continue
                if nid == mb.data_node:
                    if mb.direction == "fwd":
                        # loss computed at data node; turn around
                        mb.direction = "bwd"
                        mb.pos = len(mb.path) - 2
                        send(mb, mb.data_node, mb.path[mb.pos], t)
                    else:
                        mb.done = True
                        completed += 1
                        if t > end_time:
                            end_time = t
                    continue
                if nid in hung:
                    # hung relay: accepts the microbatch (and holds its
                    # memory slot — queued work behind it wedges, which
                    # is the cascade an undefended swarm suffers) but
                    # never completes it; only the deadline catches it
                    if nid not in mb.slots and busy[nid] < caps[nid]:
                        busy[nid] += 1
                        mb.slots.add(nid)
                    push((mb.deadline, next(seq), CHECK, mb, nid, leg, frm))
                    continue
                done_at = -1.0
                if mb.direction == "bwd":
                    if nid not in mb.slots and busy[nid] < caps[nid]:
                        busy[nid] += 1
                        mb.slots.add(nid)
                    done_at = t + eff_bwd[nid]
                    push((done_at, next(seq), DONE, mb, nid, leg, -1))
                elif nid in mb.slots:
                    done_at = t + eff_fwd[nid]
                    push((done_at, next(seq), DONE, mb, nid, leg, -1))
                elif busy[nid] < caps[nid]:
                    busy[nid] += 1
                    mb.slots.add(nid)
                    done_at = t + eff_fwd[nid]
                    push((done_at, next(seq), DONE, mb, nid, leg, -1))
                else:
                    # wait for a free slot; may outlive the sender's
                    # patience — materialize the CHECK record
                    queues[nid].append((mb, leg))
                    mb.wait_node = nid
                    push((mb.deadline, next(seq), CHECK, mb, nid, leg, frm))
                    enqueues += 1
                    qdepth += 1
                    if qdepth > qdepth_peak:
                        qdepth_peak = qdepth
                if (done_at >= 0.0 and deadline_defense and nid in slow
                        and done_at > mb.deadline):
                    # deadline-catchable straggler: hedge by
                    # materializing the CHECK at the (healthy-estimate)
                    # deadline; the re-dispatch fires there and the
                    # straggling DONE later pops stale (work wasted)
                    mb.slow_leg = leg
                    push((mb.deadline, next(seq), CHECK, mb, nid, leg, frm))
            elif kind == DONE:
                if leg != mb.leg:
                    # we were rerouted away while this node was computing:
                    # its work is wasted, its slot freed.  The waste is
                    # charged at the mb's *current* direction, which can
                    # differ from the direction this node computed in if
                    # the mb turned around before the stale DONE popped —
                    # inherited verbatim from the pre-refactor loop; a fix
                    # must change reference.py in lockstep or the CI
                    # bit-equivalence gate breaks.
                    m.wasted_gpu += (eff_bwd[nid] if mb.direction == "bwd"
                                     else eff_fwd[nid])
                    release_slot(mb, nid, t)
                    continue
                if not (alive[nid] and t < crash[nid]):
                    # crashed mid-compute: work lost; the sender's
                    # timeout recovers — materialize the CHECK record
                    m.wasted_gpu += (eff_bwd[nid] if mb.direction == "bwd"
                                     else eff_fwd[nid])
                    push((mb.deadline, next(seq), CHECK,
                          mb, nid, leg, mb.sent_from))
                    continue
                if mb.direction == "bwd":
                    mb.compute_history.append((nid, eff_bwd[nid]))
                    release_slot(mb, nid, t)
                    mb.pos -= 1
                else:
                    mb.compute_history.append((nid, eff_fwd[nid]))
                    mb.pos += 1
                pos = mb.pos
                nxt = (mb.data_node if (pos <= 0 or pos >= len(mb.path) - 1)
                       else mb.path[pos])
                send(mb, nid, nxt, t)
                if t > end_time:
                    end_time = t
            else:                                  # CHECK
                if leg != mb.leg:
                    continue                       # progressed past this leg
                # no COMPLETE for this leg: the receiver is dead OR too
                # slow (queued behind an over-committed node) — the sender
                # cannot tell the difference and reroutes either way.
                timeouts_ctr += 1
                dead_recv = not (alive[nid] and t < crash[nid])
                if dead_recv:
                    mb.slots.discard(nid)
                elif nid in hung or mb.slow_leg == leg or \
                        mb.dropped_leg == leg:
                    # adversarial stall on an alive receiver
                    if not deadline_defense:
                        continue          # undefended: the mb is stuck
                    if nid in hung or mb.slow_leg == leg:
                        mb.slow_leg = -1
                        self.timeline.record(it, "straggler",
                                             "detection", nid)
                        rep_reports.append(nid)
                        if nid in hung and nid in mb.slots:
                            # free the wedged slot without waking the
                            # queue — anything queued at a hung node
                            # must deadline out on its own
                            mb.slots.discard(nid)
                            busy[nid] -= 1
                        recover(mb, frm, nid, t)
                        if not mb.failed:
                            self.timeline.record(it, "straggler",
                                                 "repair", nid)
                        if t > end_time:
                            end_time = t
                        continue
                    # dropped delivery: bounded retry with linear
                    # backoff on the same leg before rerouting
                    mb.dropped_leg = -1
                    self.timeline.record(it, "flaky_link",
                                         "detection", nid)
                    if mb.retries < self.max_retries:
                        mb.retries += 1
                        retries_ctr += 1
                        send(mb, frm, nid, t + 0.5 * mb.retries)
                        if mb.dropped_leg != mb.leg:
                            self.timeline.record(it, "flaky_link",
                                                 "repair", nid)
                        if t > end_time:
                            end_time = t
                        continue
                recover(mb, frm, nid, t)
                if t > end_time:
                    end_time = t
        m.loop_seconds = time.perf_counter() - loop_t0
        m.events = pops
        m.completed = completed
        m.comm_time = comm_total
        m.queue_depth_peak = qdepth_peak
        m.queue_enqueues = enqueues
        m.timeouts = timeouts_ctr
        m.retries = retries_ctr
        if legb is not None:
            m.bytes_on_wire = wire_bytes
            m.codec_legs = {self._codec_names[k]: codec_hist[k]
                            for k in range(len(codec_hist)) if codec_hist[k]}
        else:
            m.bytes_on_wire = sends * self.profile.activation_bytes

        # ---- planning-overrun guard (warn-and-cap) ---------------------
        # the optimality oracle (GWTFPolicy track_optimality) is a
        # diagnostic riding inside plan(); its wall time must not trip
        # the throttle and change planning behavior under profiling
        plan_core = m.plan_seconds - getattr(self.policy,
                                             "last_oracle_seconds", 0.0)
        factor = self.plan_overrun_factor
        if (factor is not None
                and plan_core > self.plan_overrun_min_seconds
                and plan_core > factor * m.loop_seconds):
            m.plan_overrun = True
            throttle = getattr(self.policy, "throttle_planning", None)
            capped = throttle() if throttle is not None else None
            warnings.warn(
                f"planning overran the event loop: plan_seconds="
                f"{plan_core:.3f} > {factor:g} x loop_seconds="
                f"{m.loop_seconds:.3f}"
                + (f"; policy planning effort capped to {capped}"
                   if capped is not None else
                   "; policy has no throttle_planning() hook"),
                RuntimeWarning, stacklevel=2)

        if (near or far) and pops >= max_events:
            m.truncated = True
            warnings.warn(
                f"simulation iteration truncated: max_events={max_events} "
                f"exhausted with {len(near) + len(far)} events pending "
                f"({completed}/{m.launched} microbatches complete); "
                f"reported duration is a lower bound",
                RuntimeWarning, stacklevel=2)

        for mb in mbs:
            if not mb.done and not mb.failed:
                mb.failed = True
                m.wasted_gpu += sum(c for _, c in mb.compute_history)

        # ---- modelled gradient screen (corrupt contributions) ----------
        # the simulator carries no gradients; it models the runtime's
        # norm/cosine screen as catching every completed contribution
        # whose (final, post-reroute) chain crossed a corrupt node — the
        # harness' detection precision/recall check pins the runtime
        # screen to exactly this on deterministic programs
        if corrupt and self.corrupt_screen:
            cset = frozenset(corrupt)
            for mb in mbs:
                if not mb.done:
                    continue
                for c_nid in sorted(cset.intersection(mb.path)):
                    self.timeline.record(it, "corrupt_gradient",
                                         "detection", c_nid)
                    self.timeline.record(it, "corrupt_gradient",
                                         "repair", c_nid)
                    rep_reports.append(c_nid)

        # ---- aggregation phase (Sec. V-E) ------------------------------
        m.aggregation_time = self._aggregation_time(crash_times)
        m.duration = end_time + m.aggregation_time

        # ---- commit crashes for the next iteration ---------------------
        for nid in crash_times:
            net.kill_node(nid)
            self.policy.on_crash(nid)

        # ---- reputation: decay first (rehabilitation), then charge this
        # iteration's detections, so the next plan prices fresh faults at
        # full strength.  Both are exact no-ops on an all-1.0 network.
        if rep_reports or net.reputation_active():
            net.decay_reputations()
            for r_nid in rep_reports:
                net.report_fault(r_nid)
        return m

    # ------------------------------------------------------------------
    def _aggregation_time(self, crash_times: Dict[int, float]) -> float:
        """BEGIN-AGGREGATION wave + intra-stage weight exchange + CAN-TAKE.

        The worst pairwise weight-exchange cost per stage is the max
        over the off-diagonal of the stage's slice of the cached comm
        matrix — elementwise identical to the pre-refactor O(n^2)
        per-pair ``comm_cost`` loop, but one NumPy reduction per stage.
        """
        total_wave = 0.0
        agg = 0.0
        M = None
        for s in range(self.net.num_stages):
            ids = [n.id for n in self.net.stage_nodes(s)
                   if crash_times.get(n.id) is None]
            k = len(ids)
            if k < 2:
                continue
            if M is None:
                M = self.net.comm_matrix(self.profile.stage_param_bytes)
            sub = M[np.ix_(ids, ids)]
            worst = float(sub[~np.eye(k, dtype=bool)].max())
            agg = max(agg, worst)
            total_wave += 0.05          # BEGIN AGG / CAN TAKE hop latency
        return agg + 2 * total_wave

    # ------------------------------------------------------------------
    def run(self, iterations: int) -> List[IterationMetrics]:
        return [self.run_iteration() for _ in range(iterations)]


# ---------------------------------------------------------------------------
# Serving plane: decode requests routed as flow units over the stage graph
# ---------------------------------------------------------------------------

# serving event kinds (same tie-break discipline as the training core:
# completions at exactly a crash instant beat the crash)
_S_ARRIVE, _S_DONE, _S_CRASH = 0, 1, 2


@dataclass(slots=True)
class _Req:
    """One decode request's scheduling state (analytic segments).

    A *segment* is a crash-free run of decoding on one chain: token
    ``k0 + j`` lands at ``seg_t0 + j * step`` (``k0`` tokens are in
    hand at ``seg_t0``).  A fresh segment has ``pre = 0, k0 = 1,
    seg_t0 = first-token time``; a post-requeue segment resumes with
    the ``k0`` tokens that survived the migration.  ``epoch``
    invalidates stale completion events after a reschedule.
    """
    rid: int
    rec: RequestMetrics
    chain: Optional[Tuple[int, ...]] = None   # (dn, s0..s_{S-1}, dn)
    epoch: int = 0
    pre: int = 0                  # tokens in hand before seg_t0
    k0: int = 0                   # tokens in hand at seg_t0
    seg_t0: float = 0.0
    step: float = 0.0
    t_complete: float = float("inf")
    done: bool = False
    dropped: bool = False

    def tokens_at(self, t: float, gen: int) -> int:
        if self.chain is None:
            return self.pre
        if t < self.seg_t0:
            return self.pre
        if self.step <= 0.0:
            return gen
        return min(gen, self.k0 + int((t - self.seg_t0) / self.step))


class ServingEngine:
    """Open-loop serving simulator over the planned flow chains.

    Each iteration: sample churn, let the routing policy plan its
    complete-flow chains (the same ``policy.plan()`` the training
    engine consumes — decode requests ride the *identical* chain sets,
    which is what the serving differential tier pins), admit the
    iteration's compiled arrivals, and schedule decode analytically:
    a request occupies one of ``serve_batch`` continuous-batching slots
    on a chain from prefill start to last token.  Per-request TTFT/TPOT
    land in :class:`RequestMetrics`; the per-iteration conservation
    ledger (``admitted == completed + dropped + in_flight``
    cumulatively) lands in :class:`ServingIterationMetrics`.

    Crash handling is the serving analogue of requeue-instead-of-drop
    (``reroute=True``): in-flight sequences migrate to a surviving
    planned chain, paying crash-detection delay + KV migration at the
    link's admissible wire codec for the surviving stages + re-prefill
    of only the crashed stage — the mirror of the runtime's one-stage
    activation replay.  ``reroute=False`` is the drop-and-retry
    baseline: the sequence restarts from scratch (TTFT re-measured at
    the attempt that completes), and ``max_restarts`` failures drop it.

    KV-cache residency feeds back into planning: at iteration end the
    engine publishes per-node resident-sequence counts into
    ``FlowNetwork.update_kv_residency`` (when ``net.kv_weight > 0``),
    so the next ``plan()`` prices loaded nodes per Eq. 1.  Timing
    itself only reads the physics matrices (``comm_matrix``/compute),
    which the surcharge never touches.

    All arithmetic is a deterministic function of (spec seed, arrival
    program, churn program), so metrics pin byte-for-byte in golden
    files and the runtime executor can replay identical schedules.
    """

    def __init__(self, net: FlowNetwork, policy: RoutingPolicy, *,
                 arrival_program: List[List[float]],
                 churn_model: Optional[ChurnModel] = None,
                 profile: Optional[ModelProfile] = None,
                 prompt_len: int = 8, gen_tokens: int = 8,
                 serve_batch: int = 4, tokens_per_mb: int = 128,
                 timeout: float = 5.0, reroute: bool = True,
                 max_restarts: int = 5,
                 rng: Optional[np.random.Generator] = None,
                 timeline: Optional[FaultTimeline] = None):
        self.net = net
        self.policy = policy
        self.churn_model = churn_model or BernoulliChurn(0.0)
        self.profile = profile or ModelProfile(fwd_compute=2.0)
        self.arrival_program = arrival_program
        self.prompt_len = int(prompt_len)
        self.gen_tokens = int(gen_tokens)
        self.serve_batch = int(serve_batch)
        self.tokens_per_mb = max(1, int(tokens_per_mb))
        self.timeout = float(timeout)       # crash-detection delay
        self.reroute = bool(reroute)
        self.max_restarts = int(max_restarts)
        self.rng = rng or np.random.default_rng(0)
        self.timeline = timeline if timeline is not None else FaultTimeline()
        # bytes per token crossing a stage boundary / resident per stage
        self.token_bytes = self.profile.activation_bytes / self.tokens_per_mb
        self.kv_token_bytes = 2.0 * self.token_bytes     # K and V slices
        self._iteration = 0
        self._clock = 0.0
        self._rid = itertools.count()
        self.requests: Dict[int, RequestMetrics] = {}
        self._reqs: Dict[int, _Req] = {}
        self._active: Dict[int, _Req] = {}       # on a chain right now
        self._queue: deque = deque()             # admitted, waiting
        self._load: Dict[Tuple[int, ...], int] = {}
        self._kv_counts: Dict[int, int] = {}
        self.chain_plans: List[List[Tuple[int, ...]]] = []
        self.traces: List[List[tuple]] = []      # runtime replay script
        self.metrics: List[ServingIterationMetrics] = []

    # -- per-chain timing (physics only; the KV surcharge never lands
    # here — it steers planning, not transfer speed) --------------------
    def _chain_times(self, chain: Tuple[int, ...],
                     fwd_t: List[float]) -> Tuple[float, float]:
        comm_p = self.net.comm_matrix(self.prompt_len * self.token_bytes)
        comm_t = self.net.comm_matrix(self.token_bytes)
        prefill = 0.0
        step = 0.0
        for frm, to in zip(chain, chain[1:]):
            prefill += float(comm_p[frm][to])
            step += float(comm_t[frm][to])
        per_tok = [fwd_t[nid] / self.tokens_per_mb for nid in chain[1:-1]]
        prefill += sum(per_tok) * self.prompt_len
        step += sum(per_tok)
        return prefill, step

    def _resume_time(self, chain: Tuple[int, ...], fwd_t: List[float],
                     tokens: int) -> float:
        """Time to re-materialize ``tokens`` of KV on ``chain`` (the
        prefill formula at an arbitrary token count — used when a
        queued eviction finally lands a slot and must rebuild its
        prompt + generated-token cache before decoding resumes)."""
        comm = self.net.comm_matrix(tokens * self.token_bytes)
        t = 0.0
        for frm, to in zip(chain, chain[1:]):
            t += float(comm[frm][to])
        t += sum(fwd_t[nid] / self.tokens_per_mb
                 for nid in chain[1:-1]) * tokens
        return t

    def _estimate_iteration(self) -> float:
        S = self.net.num_stages
        costs = [n.compute_cost for n in self.net.alive_nodes()
                 if not n.is_data]
        mean_c = float(np.mean(costs)) if costs else 1.0
        per_hop = mean_c * (1 + self.profile.bwd_mult)
        return max(60.0, S * (per_hop + 10.0))

    # ------------------------------------------------------------------
    def run_iteration(self) -> ServingIterationMetrics:
        net = self.net
        it = self._iteration
        self._iteration += 1
        m = ServingIterationMetrics()
        horizon = self._estimate_iteration()
        t_start, t_end = self._clock, self._clock + horizon

        # ---- fault layer ----------------------------------------------
        crash_local = self.churn_model.sample(ChurnContext(
            net=net, rng=self.rng, horizon=horizon,
            iteration=it, on_rejoin=self.policy.on_rejoin))
        record_injections(self.timeline, it, crash_local,
                          adversarial_plan(self.churn_model, it))
        crash_at = {nid: t_start + ct for nid, ct in crash_local.items()}

        # ---- scheduler layer ------------------------------------------
        paths = self.policy.plan()
        chains: List[Tuple[int, ...]] = []
        for p in paths:
            key = tuple(p)
            if key not in chains:
                chains.append(key)
        self.chain_plans.append(list(chains))

        N = (max(net.nodes) + 1) if net.nodes else 0
        fwd_t = [0.05] * N
        for nid, node in net.nodes.items():
            fwd_t[nid] = max(0.05, node.compute_cost)
        times = {c: self._chain_times(c, fwd_t) for c in chains}
        for r in self._active.values():
            if r.chain is not None and r.chain not in times:
                times[r.chain] = self._chain_times(r.chain, fwd_t)

        # loads rebuilt from the live census (plans change every
        # iteration; stale keys must not pin phantom slots)
        load: Dict[Tuple[int, ...], int] = {}
        for r in self._active.values():
            load[r.chain] = load.get(r.chain, 0) + 1
        self._load = load
        kv_counts = self._kv_counts
        kv_peak = max(kv_counts.values(), default=0)
        dead = {nid for nid, node in net.nodes.items() if not node.alive}
        trace: List[tuple] = []

        heap: List[tuple] = []
        seq = itertools.count()
        for nid, ct in sorted(crash_at.items()):
            heappush = heapq.heappush
            heappush(heap, (ct, next(seq), _S_CRASH, nid))
        for r in self._active.values():
            if r.t_complete <= t_end:
                heapq.heappush(heap, (r.t_complete, next(seq), _S_DONE,
                                      (r.rid, r.epoch)))
        offsets = (self.arrival_program[it]
                   if it < len(self.arrival_program) else [])
        for u in offsets:
            rid = next(self._rid)
            rec = RequestMetrics(rid=rid, arrival=t_start + u * horizon,
                                 prompt_len=self.prompt_len,
                                 gen_tokens=self.gen_tokens)
            self.requests[rid] = rec
            self._reqs[rid] = _Req(rid=rid, rec=rec)
            heapq.heappush(heap, (rec.arrival, next(seq), _S_ARRIVE, rid))

        gen = self.gen_tokens

        def chain_crashed(chain: Tuple[int, ...], t: float) -> bool:
            return any(nid in dead or crash_at.get(nid, float("inf")) <= t
                       for nid in chain[1:-1])

        def bump_kv(chain: Tuple[int, ...], delta: int):
            nonlocal kv_peak
            for nid in chain[1:-1]:
                c = kv_counts.get(nid, 0) + delta
                if c:
                    kv_counts[nid] = c
                else:
                    kv_counts.pop(nid, None)
                if c > kv_peak:
                    kv_peak = c

        def start(r: _Req, t: float) -> bool:
            """Begin (or resume) service on the first surviving planned
            chain with a free slot.  ``r.pre == 0`` is a fresh prefill;
            ``r.pre > 0`` resumes a queued eviction — the prompt plus
            the surviving tokens re-materialize first (prefill formula
            at prompt_len + pre tokens), the first-token time is NOT
            re-measured, and decode continues from token ``pre``."""
            for chain in chains:
                if self._load.get(chain, 0) >= self.serve_batch:
                    continue
                if chain_crashed(chain, t):
                    continue
                prefill, step = times[chain]
                r.chain = chain
                r.epoch += 1
                r.step = step
                if r.pre > 0:
                    r.k0 = r.pre
                    r.seg_t0 = t + self._resume_time(
                        chain, fwd_t, self.prompt_len + r.pre)
                else:
                    r.k0 = 1
                    r.seg_t0 = t + prefill
                    r.rec.first_token = r.seg_t0
                r.t_complete = r.seg_t0 + (gen - r.k0) * step
                self._load[chain] = self._load.get(chain, 0) + 1
                self._active[r.rid] = r
                bump_kv(chain, +1)
                trace.append(("start", t, r.rid, chain, r.pre))
                if r.t_complete <= t_end:
                    heapq.heappush(heap, (r.t_complete, next(seq), _S_DONE,
                                          (r.rid, r.epoch)))
                return True
            return False

        def release(r: _Req):
            if r.chain is not None:
                self._load[r.chain] = self._load.get(r.chain, 1) - 1
                bump_kv(r.chain, -1)
                r.chain = None

        def drain_queue(t: float):
            while self._queue:
                r = self._reqs[self._queue[0]]
                if r.done or r.dropped:
                    self._queue.popleft()
                    continue
                if not start(r, t):
                    break
                self._queue.popleft()

        def interrupt(r: _Req, nid: int, ct: float):
            """Chain member ``nid`` crashed at ``ct`` mid-service."""
            nonlocal kv_peak
            k = r.tokens_at(ct, gen)
            old = r.chain
            release(r)
            # invalidate the scheduled completion immediately: every
            # interrupt outcome (requeue, queue-wait, restart, drop)
            # reschedules or abandons it, and a stale _S_DONE firing on
            # a queued request would double-count it as completed
            r.epoch += 1
            r.t_complete = float("inf")
            td = ct + self.timeout        # crash-detection delay
            self.timeline.record(it, "crash", "detection", nid)
            if not self.reroute:
                # drop-and-retry baseline: all decode state is lost
                r.rec.restarts += 1
                m.restarts += 1
                r.pre = r.k0 = 0
                r.rec.first_token = None
                trace.append(("restart", td, r.rid))
                if r.rec.restarts > self.max_restarts:
                    r.dropped = True
                    r.rec.dropped = True
                    self._active.pop(r.rid, None)
                    m.dropped += 1
                    trace.append(("drop", td, r.rid))
                    return
                if not start(r, td):
                    self._active.pop(r.rid, None)
                    self._queue.append(r.rid)
                return
            # defended: requeue-instead-of-drop.  Find a surviving
            # planned chain with a free slot; migrate the KV slices of
            # the surviving stages (priced at the links' admissible
            # wire codec) and re-prefill only the crashed stage(s).
            target = None
            for chain in chains:
                if self._load.get(chain, 0) >= self.serve_batch:
                    continue
                if chain_crashed(chain, td):
                    continue
                target = chain
                break
            if target is None:
                # no capacity anywhere yet: keep the tokens, wait
                r.pre = r.k0 = k
                self._active.pop(r.rid, None)
                self._queue.append(r.rid)
                trace.append(("requeue_wait", td, r.rid, k))
                return
            kv_tokens = self.prompt_len + k
            kv_bytes = self.kv_token_bytes * kv_tokens
            mig = 0.0
            reprefill = 0.0
            moved = 0.0
            for s_idx in range(1, len(target) - 1):
                o_nid, n_nid = old[s_idx], target[s_idx]
                o_dead = (o_nid in dead
                          or crash_at.get(o_nid, float("inf")) <= td)
                if o_dead:
                    # crashed stage: KV is gone — re-prefill it from
                    # the surviving boundary activations
                    reprefill += (fwd_t[n_nid] / self.tokens_per_mb
                                  * kv_tokens)
                elif o_nid != n_nid:
                    mig = max(mig, net.kv_migration_cost(
                        o_nid, n_nid, kv_bytes))
                    moved += kv_bytes
            t2 = td + mig + reprefill
            prefill, step = times[target]
            r.chain = target
            r.epoch += 1
            r.step = step
            r.rec.requeues += 1
            r.rec.migrated_kv_bytes += moved
            m.requeues += 1
            m.migrated_kv_bytes += moved
            if k == 0:
                # crashed during prefill: first token still pending
                r.pre = 0
                r.k0 = 1
                r.seg_t0 = t2 + prefill
                r.rec.first_token = r.seg_t0
            else:
                r.pre = r.k0 = k
                r.seg_t0 = t2
            r.t_complete = r.seg_t0 + (gen - r.k0) * r.step
            self._load[target] = self._load.get(target, 0) + 1
            bump_kv(target, +1)
            self.timeline.record(it, "crash", "repair", nid)
            trace.append(("requeue", td, r.rid, old, target, k))
            if r.t_complete <= t_end:
                heapq.heappush(heap, (r.t_complete, next(seq), _S_DONE,
                                      (r.rid, r.epoch)))

        # requests stranded in the queue from earlier iterations get
        # first claim on the fresh plan
        drain_queue(t_start)

        while heap:
            t, _, kind, payload = heapq.heappop(heap)
            if t > t_end:
                break
            if kind == _S_ARRIVE:
                r = self._reqs[payload]
                m.admitted += 1
                if not start(r, t):
                    self._queue.append(payload)
            elif kind == _S_DONE:
                rid, epoch = payload
                r = self._reqs[rid]
                if r.done or r.dropped or r.epoch != epoch:
                    continue
                r.done = True
                r.rec.completion = r.t_complete
                release(r)
                self._active.pop(rid, None)
                m.completed += 1
                m.ttfts.append(r.rec.ttft)
                m.tpots.append(r.rec.tpot)
                trace.append(("complete", t, rid))
                drain_queue(t)
            else:                                  # _S_CRASH
                nid = payload
                hit = [r for r in self._active.values()
                       if r.chain is not None and nid in r.chain[1:-1]]
                hit.sort(key=lambda r: r.rid)
                for r in hit:
                    interrupt(r, nid, t)
                drain_queue(t)

        # ---- iteration close-out --------------------------------------
        m.in_flight = len(self._active) + len(self._queue)
        m.queued = len(self._queue)
        m.kv_peak = kv_peak
        self._clock = t_end
        self.traces.append(trace)

        # commit crashes for the next iteration (same order as training)
        for nid in crash_local:
            net.kill_node(nid)
            self.policy.on_crash(nid)

        # publish residency so the next plan prices loaded nodes; the
        # trivial (kv_weight == 0) network never sees an update, so its
        # cost epochs stay bit-identical to the serving-free stack
        if net.kv_weight > 0.0:
            net.update_kv_residency(dict(kv_counts))
        self.metrics.append(m)
        return m

    # ------------------------------------------------------------------
    def tokens_now(self, rid: int) -> int:
        """Tokens the request holds at the engine's current clock (the
        runtime executor advances real decoding to exactly this)."""
        r = self._reqs[rid]
        if r.done:
            return self.gen_tokens
        if r.dropped:
            return 0
        return r.tokens_at(self._clock, self.gen_tokens)

    def run(self, iterations: int) -> List[ServingIterationMetrics]:
        return [self.run_iteration() for _ in range(iterations)]
