"""Event core of the layered simulation engine (paper Sec. VI).

`SimulationEngine` runs the discrete-event clock that times one
training iteration: per-node compute slots (capacity) with FIFO
queueing, per-link transfer delays, mid-iteration crashes, and
timeout-based fault discovery.  Routing and recovery decisions are
delegated to a `RoutingPolicy` (scheduler layer) and crash/rejoin
sampling to a `ChurnModel` (fault layer), so the core contains no
scheduler- or fault-specific branches.

Design of the fast core
-----------------------
* **Typed event records.**  Events are flat 7-tuples
  ``(time, seq, kind, mb, node, leg, frm)`` with integer kinds
  (ARRIVE/DONE/CHECK) — no nested payload tuples, no string dispatch.
  ``seq`` is a global monotonic counter so simultaneous events pop in
  push order (deterministic FIFO tie-break).
* **Two-level batched calendar.**  The calendar is split into a small
  ``near`` binary heap (every pending event with time ≤ a moving
  boundary ``B``) and an unsorted ``far`` list (everything later).
  Pushes compare once against ``B`` and either ``heappush`` into
  ``near`` or plain-``append`` to ``far``; when ``near`` drains, one
  bulk ``far.sort()`` (Timsort in C over the ``(time, seq, ...)``
  records) promotes the next batch — at least 256 events, half of
  ``far`` when larger, always extended across time ties so ``far``
  holds strictly-later events only.  A sorted ascending run is already
  a valid min-heap, so promotion is a slice, and every ``heappush`` /
  ``heappop`` works a heap of batch size instead of total calendar
  residency — that breaks the ~µs/event floor a single monolithic heap
  hits past ~10k concurrent microbatches (log-factor tuple compares
  per operation), while bulk Timsort amortizes ordering at
  O(log batch) compares per event.  Pop order is *provably identical*
  to the single heap: ``far`` only ever holds events strictly later
  than everything in ``near``, and ``(time, seq)`` is a unique total
  order (so sorting never compares the payload fields).  (A bucketed
  calendar queue was measured slower here: its per-event bucket scan
  runs in bytecode, while the sort/heap primitives run in C.)
* **Lazy timeout records.**  The pre-refactor loop pushed one CHECK
  event per send; in a healthy iteration every one of them pops stale.
  A timeout can only ever *fire* if the microbatch actually stalled,
  and the loop observes every stall directly: an arrival dropped at a
  dead receiver, a compute lost to a mid-compute crash, or a
  capacity-wait enqueue.  The core therefore materializes the CHECK
  record (with the deadline computed at send time, so fire times are
  bit-identical) only at those three points.  This removes a third of
  all calendar traffic and keeps the calendar an order of magnitude
  smaller — long-deadline timeout records no longer dominate its
  residency.  Caveat: on calendars with *exactly* tying float
  timestamps (e.g. all-integer link costs) a fired timeout may
  tie-break differently against a simultaneous arrival than the
  reference loop; on the continuous geo topologies used by the tests
  and benchmarks, seeded runs are metric- and RNG-identical.
* **Batched cost lookups.**  All per-event cost queries are resolved
  against per-iteration tables derived from ``FlowNetwork``'s cached
  Eq. 1 matrices: the dense communication and edge-cost matrices
  (``FlowNetwork.comm_matrix`` / ``edge_matrix`` at the profile's
  activation size, lowered to nested Python lists so the hot loop and
  the fault path do plain float indexing) and per-node
  forward/backward compute-time vectors.  The pre-refactor loop
  resolved every one of these through two or three method calls per
  event.
* **Per-iteration event accounting.**  The loop counts calendar pops,
  capacity-wait enqueues, peak queue depth, reroutes, and its own wall
  time into `IterationMetrics` (``events``, ``events_per_sec``), which
  is what ``benchmarks/bench_sim.py`` measures against the
  pre-refactor loop kept in `repro.core.sim.reference`.

Semantics are identical to the pre-refactor ``TrainingSimulator``
(same RNG stream, same float arithmetic, same tie-breaking) with two
deliberate, documented exceptions:

* the SWARM backward-restart slot leak is fixed — restarting
  microbatches release their slots through ``release_slot`` so queued
  microbatches behind them wake immediately instead of stalling until
  their sender's timeout;
* ``max_events`` exhaustion is surfaced (``IterationMetrics.truncated``
  + a ``RuntimeWarning``) instead of silently reporting a short, clean
  iteration.

Planning-overrun guard: when ``policy.plan()`` wall time exceeds the
event-loop wall time by ``plan_overrun_factor`` (and is long enough in
absolute terms to matter — ``plan_overrun_min_seconds``), the engine
warns, flags the iteration (``IterationMetrics.plan_overrun``), and
asks the policy to cap its planning effort via an optional
``throttle_planning()`` hook — a planner regression now surfaces in CI
profiles instead of silently turning the simulator superlinear.
"""
from __future__ import annotations

import heapq
import itertools
import time
import warnings
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.flow.graph import FlowNetwork
from repro.core.sim.faults import (BernoulliChurn, ChurnContext, ChurnModel,
                                   adversarial_plan)
from repro.core.sim.metrics import IterationMetrics, ModelProfile
from repro.core.sim.policies import FaultView, RoutingPolicy
from repro.core.sim.timeline import FaultTimeline, record_injections

# Typed event kinds (ints: cheap compares, no string dispatch)
ARRIVE, DONE, CHECK = 0, 1, 2

# two-level calendar: minimum promotion batch (events) pulled from the
# far list each time the near heap drains
_PROMOTE_MIN = 256


@dataclass(slots=True)
class _MB:
    """One microbatch's lifecycle."""
    id: int
    data_node: int
    path: List[int]                   # planned chain (GWTF) / realised (SWARM)
    pos: int = 0                      # index into path
    direction: str = "fwd"
    compute_history: List[Tuple[int, float]] = field(default_factory=list)
    slots: set = field(default_factory=set)   # nodes holding memory for us
    leg: int = 0                  # increments on every send; stale events ignored
    retries: int = 0
    done: bool = False
    failed: bool = False
    # current leg's timeout deadline + sender, stamped by send() so a
    # lazily-materialized CHECK record is bit-identical to an eager one
    deadline: float = 0.0
    sent_from: int = -1
    # node whose capacity-wait queue currently holds us (-1 = none);
    # lets the queue-depth gauge drop entries that leave the waiting
    # state sideways (rerouted away, failed, stranded at a crashed
    # node) instead of only when their queue entry is popped
    wait_node: int = -1
    # adversarial per-leg markers: the leg whose delivery was dropped
    # by a flaky link / whose receiver is a deadline-catchable
    # straggler (-1 = none).  Lets the CHECK handler attribute the
    # fired deadline to its cause without payload-tuple changes.
    dropped_leg: int = -1
    slow_leg: int = -1


class SimulationEngine:
    """The event core: policy + churn model + profile -> timed iterations.

    Memory semantics: a relay node's capacity counts *in-flight*
    microbatches — the slot is held from forward arrival until the
    backward pass completes at that node (activations must be kept for
    the backward).  This is exactly why heterogeneous capacities
    matter: SWARM routes capacity-blind and serialises on cap-1 nodes;
    GWTF's flows respect capacity by construction.
    """

    def __init__(self, net: FlowNetwork, policy: RoutingPolicy, *,
                 churn_model: Optional[ChurnModel] = None,
                 profile: Optional[ModelProfile] = None,
                 timeout: float = 30.0, max_retries: int = 2,
                 rng: Optional[np.random.Generator] = None,
                 max_events: int = 500_000,
                 plan_overrun_factor: float = 100.0,
                 plan_overrun_min_seconds: float = 0.5,
                 deadline_defense: bool = True,
                 corrupt_screen: bool = True):
        self.net = net
        self.policy = policy
        self.churn_model = churn_model or BernoulliChurn(0.0)
        self.profile = profile or ModelProfile(fwd_compute=2.0)
        self.timeout = timeout
        self.max_retries = max_retries
        # adversarial defenses: deadline-triggered re-dispatch for
        # hung/straggling/dropped legs, and the (modelled) gradient
        # screen for corrupt contributions.  Both are inert unless the
        # churn model publishes an AdversarialPlan.
        self.deadline_defense = deadline_defense
        self.corrupt_screen = corrupt_screen
        self.timeline = FaultTimeline()
        self.rng = rng or np.random.default_rng(0)
        self.max_events = max_events
        self.plan_overrun_factor = plan_overrun_factor
        self.plan_overrun_min_seconds = plan_overrun_min_seconds
        self._mb_ids = itertools.count()
        self._iteration = 0
        self._tables_key = None          # (cost_version, size, N)
        self._comm_rows: List[List[float]] = []
        self._edge_rows: List[List[float]] = []
        self._codec_names: Tuple[str, ...] = ("fp32",)
        self._codec_rows: Optional[List[List[int]]] = None
        self._legbytes_rows: Optional[List[List[float]]] = None
        self._node_tables_key = None     # (cost_version, N)
        self._fwd_t: List[float] = []
        self._bwd_t: List[float] = []
        self._caps: List[int] = []

    # ------------------------------------------------------------------
    # Batched per-iteration cost tables
    # ------------------------------------------------------------------
    def _cost_tables(self, n_nodes: int) -> Tuple[List[List[float]],
                                                  List[List[float]]]:
        """Dense comm-only and full-edge Eq. 1 matrices at the profile's
        activation size, lowered to nested lists (plain-float reads in
        the hot loop and the fault path).  Rebuilt only when the
        network's cost epoch moves.

        With a non-trivial wire-codec menu the matrices are already
        codec-priced (encoded bytes + encode/decode delay baked into
        each entry by ``FlowNetwork``); this also lowers the per-link
        chosen-codec indices and encoded-bytes-per-leg tables the event
        loop charges ``bytes_on_wire`` / ``codec_legs`` against."""
        key = (self.net.cost_version, self.profile.activation_bytes, n_nodes)
        if key != self._tables_key:
            size = self.profile.activation_bytes
            self._comm_rows = self.net.comm_matrix(size)[
                :n_nodes, :n_nodes].tolist()
            self._edge_rows = self.net.edge_matrix(size)[
                :n_nodes, :n_nodes].tolist()
            names = self.net.wire_codec_names()
            self._codec_names = names
            if len(names) > 1:
                choice = self.net.wire_codec_matrix(size)[:n_nodes, :n_nodes]
                ratios = self.net.wire_codec_ratios()
                self._codec_rows = choice.tolist()
                self._legbytes_rows = (ratios[choice] * float(size)).tolist()
            else:
                self._codec_rows = None
                self._legbytes_rows = None
            self._tables_key = key
        return self._comm_rows, self._edge_rows

    def _estimate_iteration(self) -> float:
        S = self.net.num_stages
        costs = [n.compute_cost for n in self.net.alive_nodes() if not n.is_data]
        mean_c = float(np.mean(costs)) if costs else 1.0
        per_hop = mean_c * (1 + self.profile.bwd_mult)
        return max(60.0, S * (per_hop + 10.0))

    # ------------------------------------------------------------------
    # One training iteration
    # ------------------------------------------------------------------
    def run_iteration(self) -> IterationMetrics:
        net = self.net
        m = IterationMetrics()

        # ---- fault layer: sample crashes/rejoins ----------------------
        it = self._iteration
        crash_times = self.churn_model.sample(ChurnContext(
            net=net, rng=self.rng, horizon=self._estimate_iteration(),
            iteration=it, on_rejoin=self.policy.on_rejoin))
        self._iteration += 1
        # adversarial side channel (None for plain fail-stop models —
        # every branch it gates below is then skipped, keeping the
        # fail-stop event stream bit-identical to the reference loop)
        adv = adversarial_plan(self.churn_model, it)
        record_injections(self.timeline, it, crash_times, adv)
        slow = adv.slow if adv is not None else {}
        hung = adv.hung if adv is not None else frozenset()
        corrupt = adv.corrupt if adv is not None else {}
        flaky = adv is not None and bool(adv.flaky)
        deadline_defense = self.deadline_defense

        # ---- scheduler layer: build this iteration's paths ------------
        plan_t0 = time.perf_counter()
        mbs = [_MB(next(self._mb_ids), path[0], list(path))
               for path in self.policy.plan()]
        m.plan_seconds = time.perf_counter() - plan_t0
        m.launched = len(mbs)
        m.cost_ratio_vs_optimal = getattr(self.policy,
                                          "last_cost_ratio", None)

        # ---- batched cost tables (resolved against the Eq. 1 caches) --
        N = (max(net.nodes) + 1) if net.nodes else 0
        comm, edge = self._cost_tables(N)
        # node-attribute tables: compute times and capacities move only
        # with the cost epoch / membership size, so they are part of the
        # reusable planning context; liveness is per-iteration state
        nt_key = (net.cost_version, N)
        if nt_key != self._node_tables_key:
            fwd_t = [0.05] * N
            caps = [0] * N
            for nid, node in net.nodes.items():
                fwd_t[nid] = max(0.05, node.compute_cost)
                caps[nid] = node.capacity
            bwd_mult = self.profile.bwd_mult
            self._fwd_t = fwd_t
            self._bwd_t = [c * bwd_mult for c in fwd_t]
            self._caps = caps
            self._node_tables_key = nt_key
        fwd_t, bwd_t, caps = self._fwd_t, self._bwd_t, self._caps
        # effective compute times under straggler slowdowns; deadlines
        # keep being stamped from the *healthy* tables (fwd_t/bwd_t in
        # send()), which is exactly what lets the deadline catch a
        # pathological slowdown
        if slow:
            eff_fwd, eff_bwd = list(fwd_t), list(bwd_t)
            for s_nid, s_f in slow.items():
                if s_nid < N:
                    eff_fwd[s_nid] *= s_f
                    eff_bwd[s_nid] *= s_f
        else:
            eff_fwd, eff_bwd = fwd_t, bwd_t
        alive = [False] * N
        for nid, node in net.nodes.items():
            alive[nid] = node.alive
        INF = float("inf")
        crash = [INF] * N
        for nid, ct in crash_times.items():
            crash[nid] = ct

        # ---- per-iteration node state ---------------------------------
        busy = [0] * N
        queues = [deque() for _ in range(N)]   # capacity-wait FIFOs

        view = FaultView()
        view.net = net
        view.activation_bytes = self.profile.activation_bytes
        # hung nodes (and stragglers slow enough that the deadline is
        # guaranteed to fire on any forward leg) are alive but useless
        # this iteration: mark them crashed-at-0 in the *policy's* view
        # (not the engine's own liveness tables) so recovery never
        # substitutes a microbatch onto one.  The runtime's
        # RecoveryManager applies the same predicate to its view.
        blocked = set(hung)
        for s_nid, s_f in slow.items():
            if s_nid < N and fwd_t[s_nid] * (s_f - 1.0) > self.timeout:
                blocked.add(s_nid)
        if blocked:
            vcrash = list(crash)
            for b_nid in blocked:
                if b_nid < N:
                    vcrash[b_nid] = 0.0
            view.alive, view.crash = alive, vcrash
        else:
            view.alive, view.crash = alive, crash
        view.busy, view.queues = busy, queues
        view.fwd_t, view.bwd_t = fwd_t, bwd_t
        view.comm_rows, view.edge_rows = comm, edge
        _stage_cache: Dict[int, list] = {}

        def stage_nodes(s: int) -> list:
            nodes = _stage_cache.get(s)
            if nodes is None:
                nodes = net.stage_nodes(s)     # membership frozen mid-loop
                _stage_cache[s] = nodes
            return nodes

        view.stage_nodes = stage_nodes

        # ---- event calendar (two-level: near heap + far list) ---------
        near: List[tuple] = []        # heap: every pending event t <= boundary
        far: List[tuple] = []         # unsorted: every pending event t > boundary
        boundary = float("-inf")      # initial launches bulk-sort on first pop
        heappush, heappop = heapq.heappush, heapq.heappop
        far_append = far.append
        seq = itertools.count()
        timeout = self.timeout
        comm_total = 0.0
        qdepth = 0
        sends = 0
        timeouts_ctr = 0
        retries_ctr = 0
        rep_reports: List[int] = []       # detection-attributed nodes
        wire_bytes = 0.0
        codec_rows, legb = self._codec_rows, self._legbytes_rows
        codec_hist = [0] * len(self._codec_names)

        def push(ev: tuple):
            if ev[0] <= boundary:
                heappush(near, ev)
            else:
                far_append(ev)

        def send(mb: _MB, frm: int, to: int, t: float):
            nonlocal comm_total, sends, wire_bytes
            mb.leg += 1
            c = comm[frm][to]
            comm_total += c
            sends += 1
            if legb is not None:
                # leg priced at the link's chosen codec: encoded bytes
                # on the wire, encode/decode delay already inside c
                wire_bytes += legb[frm][to]
                codec_hist[codec_rows[frm][to]] += 1
            # sender expects a COMPLETE within comm+compute+timeout; a slow
            # (overloaded) peer is indistinguishable from a dead one.  The
            # CHECK record itself is materialized lazily, at the stall.
            expect = c + (bwd_t[to] if mb.direction == "bwd"
                          else fwd_t[to]) + timeout
            mb.deadline = t + expect
            mb.sent_from = frm
            if (flaky and to != mb.data_node
                    and not adv.leg_ok(it, mb.id, mb.direction, mb.pos,
                                       mb.retries)):
                # delivery dropped on the wire (bytes were still spent):
                # the receiver never sees the ARRIVE, so the stall point
                # is known immediately — materialize the CHECK now
                mb.dropped_leg = mb.leg
                push((mb.deadline, next(seq), CHECK, mb, to, mb.leg, frm))
                return
            push((t + c, next(seq), ARRIVE, mb, to, mb.leg, frm))

        def release_slot(mb: _MB, nid: int, t: float):
            nonlocal qdepth
            if nid not in mb.slots:
                return
            mb.slots.discard(nid)
            busy[nid] -= 1
            q = queues[nid]
            while q and alive[nid] and t < crash[nid]:
                qmb, qleg = q.popleft()
                if qmb.done or qmb.failed or qleg != qmb.leg:
                    continue                       # stale queue entry
                qdepth -= 1
                qmb.wait_node = -1
                busy[nid] += 1
                qmb.slots.add(nid)
                push((t + (eff_bwd[nid] if qmb.direction == "bwd"
                           else eff_fwd[nid]),
                      next(seq), DONE, qmb, nid, qleg, -1))
                break

        def fail(mb: _MB, t: float):
            mb.failed = True
            m.wasted_gpu += sum(c for _, c in mb.compute_history)
            for nid in list(mb.slots):
                release_slot(mb, nid, t)

        def recover(mb: _MB, frm: int, dead: int, t: float):
            """Sender `frm` noticed `dead` is unresponsive."""
            nonlocal qdepth, retries_ctr
            if mb.wait_node >= 0:
                # leaving the waiting state sideways: the queue entry
                # goes stale (popped-and-skipped later, or stranded at a
                # crashed node) — drop it from the depth gauge now
                qdepth -= 1
                mb.wait_node = -1
            if mb.retries >= self.max_retries:
                fail(mb, t)
                return
            mb.retries += 1
            retries_ctr += 1
            decision = self.policy.recover(view, mb, frm, dead, t)
            kind = decision[0]
            if kind == "substitute":
                sub, delay = decision[1], decision[2]
                m.reroutes += 1
                mb.path[mb.pos] = sub
                send(mb, frm, sub, t + delay)
            elif kind == "restart":
                # full pipeline recomputation from the data node: all
                # forward work so far is wasted and every held slot is
                # released (through release_slot, so microbatches queued
                # behind this one wake up instead of waiting out their
                # sender's timeout — the pre-refactor loop leaked these
                # slots by decrementing busy directly).
                m.wasted_gpu += sum(c for _, c in mb.compute_history)
                mb.compute_history.clear()
                for nid2 in list(mb.slots):
                    release_slot(mb, nid2, t)
                path = decision[1]
                if path is None:
                    fail(mb, t)
                    return
                m.reroutes += 1
                mb.path = list(path)
                mb.direction = "fwd"
                mb.pos = 1
                send(mb, mb.data_node, mb.path[1], t)
            else:
                fail(mb, t)

        # ---- event loop -----------------------------------------------
        loop_t0 = time.perf_counter()
        for mb in mbs:
            mb.pos = 1
            send(mb, mb.data_node, mb.path[1], 0.0)

        end_time = 0.0
        completed = 0
        pops = 0
        max_events = self.max_events
        qdepth_peak = 0
        enqueues = 0
        while pops < max_events:
            if near:
                ev = heappop(near)
            elif far:
                # promotion: one bulk Timsort, then slice off the next
                # batch.  (time, seq) is unique, so the sort never
                # compares payload fields; the ascending run is already
                # a valid min-heap.  Extending across time ties keeps
                # the invariant that far holds strictly-later events.
                far.sort()
                nf = len(far)
                k = nf if nf <= _PROMOTE_MIN else max(_PROMOTE_MIN, nf >> 1)
                while k < nf and far[k][0] == far[k - 1][0]:
                    k += 1
                near.extend(far[:k])
                del far[:k]
                boundary = near[-1][0]
                ev = heappop(near)
            else:
                break
            pops += 1
            t, _, kind, mb, nid, leg, frm = ev
            if mb.done or mb.failed:
                continue
            if kind == ARRIVE:
                if leg != mb.leg:
                    continue                       # rerouted while in flight
                if not (alive[nid] and t < crash[nid]):
                    # dead receiver: the mb stalls until the sender's
                    # timeout — materialize the CHECK record now
                    push((mb.deadline, next(seq), CHECK, mb, nid, leg, frm))
                    continue
                if nid == mb.data_node:
                    if mb.direction == "fwd":
                        # loss computed at data node; turn around
                        mb.direction = "bwd"
                        mb.pos = len(mb.path) - 2
                        send(mb, mb.data_node, mb.path[mb.pos], t)
                    else:
                        mb.done = True
                        completed += 1
                        if t > end_time:
                            end_time = t
                    continue
                if nid in hung:
                    # hung relay: accepts the microbatch (and holds its
                    # memory slot — queued work behind it wedges, which
                    # is the cascade an undefended swarm suffers) but
                    # never completes it; only the deadline catches it
                    if nid not in mb.slots and busy[nid] < caps[nid]:
                        busy[nid] += 1
                        mb.slots.add(nid)
                    push((mb.deadline, next(seq), CHECK, mb, nid, leg, frm))
                    continue
                done_at = -1.0
                if mb.direction == "bwd":
                    if nid not in mb.slots and busy[nid] < caps[nid]:
                        busy[nid] += 1
                        mb.slots.add(nid)
                    done_at = t + eff_bwd[nid]
                    push((done_at, next(seq), DONE, mb, nid, leg, -1))
                elif nid in mb.slots:
                    done_at = t + eff_fwd[nid]
                    push((done_at, next(seq), DONE, mb, nid, leg, -1))
                elif busy[nid] < caps[nid]:
                    busy[nid] += 1
                    mb.slots.add(nid)
                    done_at = t + eff_fwd[nid]
                    push((done_at, next(seq), DONE, mb, nid, leg, -1))
                else:
                    # wait for a free slot; may outlive the sender's
                    # patience — materialize the CHECK record
                    queues[nid].append((mb, leg))
                    mb.wait_node = nid
                    push((mb.deadline, next(seq), CHECK, mb, nid, leg, frm))
                    enqueues += 1
                    qdepth += 1
                    if qdepth > qdepth_peak:
                        qdepth_peak = qdepth
                if (done_at >= 0.0 and deadline_defense and nid in slow
                        and done_at > mb.deadline):
                    # deadline-catchable straggler: hedge by
                    # materializing the CHECK at the (healthy-estimate)
                    # deadline; the re-dispatch fires there and the
                    # straggling DONE later pops stale (work wasted)
                    mb.slow_leg = leg
                    push((mb.deadline, next(seq), CHECK, mb, nid, leg, frm))
            elif kind == DONE:
                if leg != mb.leg:
                    # we were rerouted away while this node was computing:
                    # its work is wasted, its slot freed.  The waste is
                    # charged at the mb's *current* direction, which can
                    # differ from the direction this node computed in if
                    # the mb turned around before the stale DONE popped —
                    # inherited verbatim from the pre-refactor loop; a fix
                    # must change reference.py in lockstep or the CI
                    # bit-equivalence gate breaks.
                    m.wasted_gpu += (eff_bwd[nid] if mb.direction == "bwd"
                                     else eff_fwd[nid])
                    release_slot(mb, nid, t)
                    continue
                if not (alive[nid] and t < crash[nid]):
                    # crashed mid-compute: work lost; the sender's
                    # timeout recovers — materialize the CHECK record
                    m.wasted_gpu += (eff_bwd[nid] if mb.direction == "bwd"
                                     else eff_fwd[nid])
                    push((mb.deadline, next(seq), CHECK,
                          mb, nid, leg, mb.sent_from))
                    continue
                if mb.direction == "bwd":
                    mb.compute_history.append((nid, eff_bwd[nid]))
                    release_slot(mb, nid, t)
                    mb.pos -= 1
                else:
                    mb.compute_history.append((nid, eff_fwd[nid]))
                    mb.pos += 1
                pos = mb.pos
                nxt = (mb.data_node if (pos <= 0 or pos >= len(mb.path) - 1)
                       else mb.path[pos])
                send(mb, nid, nxt, t)
                if t > end_time:
                    end_time = t
            else:                                  # CHECK
                if leg != mb.leg:
                    continue                       # progressed past this leg
                # no COMPLETE for this leg: the receiver is dead OR too
                # slow (queued behind an over-committed node) — the sender
                # cannot tell the difference and reroutes either way.
                timeouts_ctr += 1
                dead_recv = not (alive[nid] and t < crash[nid])
                if dead_recv:
                    mb.slots.discard(nid)
                elif nid in hung or mb.slow_leg == leg or \
                        mb.dropped_leg == leg:
                    # adversarial stall on an alive receiver
                    if not deadline_defense:
                        continue          # undefended: the mb is stuck
                    if nid in hung or mb.slow_leg == leg:
                        mb.slow_leg = -1
                        self.timeline.record(it, "straggler",
                                             "detection", nid)
                        rep_reports.append(nid)
                        if nid in hung and nid in mb.slots:
                            # free the wedged slot without waking the
                            # queue — anything queued at a hung node
                            # must deadline out on its own
                            mb.slots.discard(nid)
                            busy[nid] -= 1
                        recover(mb, frm, nid, t)
                        if not mb.failed:
                            self.timeline.record(it, "straggler",
                                                 "repair", nid)
                        if t > end_time:
                            end_time = t
                        continue
                    # dropped delivery: bounded retry with linear
                    # backoff on the same leg before rerouting
                    mb.dropped_leg = -1
                    self.timeline.record(it, "flaky_link",
                                         "detection", nid)
                    if mb.retries < self.max_retries:
                        mb.retries += 1
                        retries_ctr += 1
                        send(mb, frm, nid, t + 0.5 * mb.retries)
                        if mb.dropped_leg != mb.leg:
                            self.timeline.record(it, "flaky_link",
                                                 "repair", nid)
                        if t > end_time:
                            end_time = t
                        continue
                recover(mb, frm, nid, t)
                if t > end_time:
                    end_time = t
        m.loop_seconds = time.perf_counter() - loop_t0
        m.events = pops
        m.completed = completed
        m.comm_time = comm_total
        m.queue_depth_peak = qdepth_peak
        m.queue_enqueues = enqueues
        m.timeouts = timeouts_ctr
        m.retries = retries_ctr
        if legb is not None:
            m.bytes_on_wire = wire_bytes
            m.codec_legs = {self._codec_names[k]: codec_hist[k]
                            for k in range(len(codec_hist)) if codec_hist[k]}
        else:
            m.bytes_on_wire = sends * self.profile.activation_bytes

        # ---- planning-overrun guard (warn-and-cap) ---------------------
        # the optimality oracle (GWTFPolicy track_optimality) is a
        # diagnostic riding inside plan(); its wall time must not trip
        # the throttle and change planning behavior under profiling
        plan_core = m.plan_seconds - getattr(self.policy,
                                             "last_oracle_seconds", 0.0)
        factor = self.plan_overrun_factor
        if (factor is not None
                and plan_core > self.plan_overrun_min_seconds
                and plan_core > factor * m.loop_seconds):
            m.plan_overrun = True
            throttle = getattr(self.policy, "throttle_planning", None)
            capped = throttle() if throttle is not None else None
            warnings.warn(
                f"planning overran the event loop: plan_seconds="
                f"{plan_core:.3f} > {factor:g} x loop_seconds="
                f"{m.loop_seconds:.3f}"
                + (f"; policy planning effort capped to {capped}"
                   if capped is not None else
                   "; policy has no throttle_planning() hook"),
                RuntimeWarning, stacklevel=2)

        if (near or far) and pops >= max_events:
            m.truncated = True
            warnings.warn(
                f"simulation iteration truncated: max_events={max_events} "
                f"exhausted with {len(near) + len(far)} events pending "
                f"({completed}/{m.launched} microbatches complete); "
                f"reported duration is a lower bound",
                RuntimeWarning, stacklevel=2)

        for mb in mbs:
            if not mb.done and not mb.failed:
                mb.failed = True
                m.wasted_gpu += sum(c for _, c in mb.compute_history)

        # ---- modelled gradient screen (corrupt contributions) ----------
        # the simulator carries no gradients; it models the runtime's
        # norm/cosine screen as catching every completed contribution
        # whose (final, post-reroute) chain crossed a corrupt node — the
        # harness' detection precision/recall check pins the runtime
        # screen to exactly this on deterministic programs
        if corrupt and self.corrupt_screen:
            cset = frozenset(corrupt)
            for mb in mbs:
                if not mb.done:
                    continue
                for c_nid in sorted(cset.intersection(mb.path)):
                    self.timeline.record(it, "corrupt_gradient",
                                         "detection", c_nid)
                    self.timeline.record(it, "corrupt_gradient",
                                         "repair", c_nid)
                    rep_reports.append(c_nid)

        # ---- aggregation phase (Sec. V-E) ------------------------------
        m.aggregation_time = self._aggregation_time(crash_times)
        m.duration = end_time + m.aggregation_time

        # ---- commit crashes for the next iteration ---------------------
        for nid in crash_times:
            net.kill_node(nid)
            self.policy.on_crash(nid)

        # ---- reputation: decay first (rehabilitation), then charge this
        # iteration's detections, so the next plan prices fresh faults at
        # full strength.  Both are exact no-ops on an all-1.0 network.
        if rep_reports or net.reputation_active():
            net.decay_reputations()
            for r_nid in rep_reports:
                net.report_fault(r_nid)
        return m

    # ------------------------------------------------------------------
    def _aggregation_time(self, crash_times: Dict[int, float]) -> float:
        """BEGIN-AGGREGATION wave + intra-stage weight exchange + CAN-TAKE.

        The worst pairwise weight-exchange cost per stage is the max
        over the off-diagonal of the stage's slice of the cached comm
        matrix — elementwise identical to the pre-refactor O(n^2)
        per-pair ``comm_cost`` loop, but one NumPy reduction per stage.
        """
        total_wave = 0.0
        agg = 0.0
        M = None
        for s in range(self.net.num_stages):
            ids = [n.id for n in self.net.stage_nodes(s)
                   if crash_times.get(n.id) is None]
            k = len(ids)
            if k < 2:
                continue
            if M is None:
                M = self.net.comm_matrix(self.profile.stage_param_bytes)
            sub = M[np.ix_(ids, ids)]
            worst = float(sub[~np.eye(k, dtype=bool)].max())
            agg = max(agg, worst)
            total_wave += 0.05          # BEGIN AGG / CAN TAKE hop latency
        return agg + 2 * total_wave

    # ------------------------------------------------------------------
    def run(self, iterations: int) -> List[IterationMetrics]:
        return [self.run_iteration() for _ in range(iterations)]
