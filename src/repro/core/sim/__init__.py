"""Layered discrete-event simulation engine (paper Sec. VI).

Layers, each its own module:

* `engine` — the fast event core (`SimulationEngine`): typed event
  records on an array-backed calendar, batched cost lookups resolved
  against `FlowNetwork`'s cached Eq. 1 matrices, per-iteration event
  accounting;
* `policies` — the scheduler layer (`RoutingPolicy`): plan paths,
  reroute on forward faults, recover backward faults; GWTF, SWARM and
  fixed-schedule implementations;
* `faults` — the fault layer (`ChurnModel`): Bernoulli coin-flips,
  trace replay, correlated regional outages, and compositions;
* `metrics` — Table II/III columns plus queue-depth / reroute /
  event-accounting series (`IterationMetrics`, `summarize`);
* `facade` — the drop-in `TrainingSimulator` wrapper the rest of the
  repo imports (also re-exported by `repro.core.simulator`);
* `reference` — the pre-refactor monolithic loop, frozen for
  `benchmarks/bench_sim.py` events/sec comparisons.
"""
from repro.core.sim.engine import SimulationEngine
from repro.core.sim.facade import TrainingSimulator
from repro.core.sim.faults import (AdversarialPlan, BernoulliChurn,
                                   ChurnContext, ChurnModel, ComposedChurn,
                                   CorruptGradientChurn, FlakyLinkChurn,
                                   LinkDegradationChurn, RegionalOutageChurn,
                                   StragglerChurn, TraceChurn,
                                   adversarial_plan)
from repro.core.sim.metrics import IterationMetrics, ModelProfile, summarize
from repro.core.sim.policies import (FixedPolicy, GWTFPolicy, RoutingPolicy,
                                     SwarmPolicy, make_policy)
from repro.core.sim.timeline import (FaultRecord, FaultTimeline,
                                     record_injections)

__all__ = [
    "SimulationEngine", "TrainingSimulator",
    "AdversarialPlan", "BernoulliChurn", "ChurnContext", "ChurnModel",
    "ComposedChurn", "CorruptGradientChurn", "FlakyLinkChurn",
    "LinkDegradationChurn", "RegionalOutageChurn", "StragglerChurn",
    "TraceChurn", "adversarial_plan",
    "FaultRecord", "FaultTimeline", "record_injections",
    "IterationMetrics", "ModelProfile", "summarize",
    "FixedPolicy", "GWTFPolicy", "RoutingPolicy", "SwarmPolicy",
    "make_policy",
]
