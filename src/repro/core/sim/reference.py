"""Pre-refactor simulator loop, kept verbatim for benchmarking.

`ReferenceTrainingSimulator` is the monolithic ``TrainingSimulator``
exactly as it stood before the layered `repro.core.sim` engine replaced
it (hard-coded scheduler branches, per-event ``comm_cost`` /
``_compute_time`` method calls, string-typed heapq events, O(n^2)
aggregation loop) — including the SWARM backward-restart slot leak the
refactor fixed.  ``benchmarks/bench_sim.py`` runs it side by side with
the new event core to measure events/sec and to prove the GWTF path
metric-identical (same RNG stream, same float arithmetic).

The only changes from the pre-refactor file: `ModelProfile` /
`IterationMetrics` are imported from `repro.core.sim.metrics` instead
of being redefined, and the event loop stamps ``m.events`` /
``m.loop_seconds`` so events/sec is measured identically in both
implementations.  Do not "improve" this module — its value is being
frozen history.
"""
from __future__ import annotations

import heapq
import itertools
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.flow.decentralized import GWTFProtocol
from repro.core.flow.graph import FlowNetwork, Node
from repro.core.sim.metrics import IterationMetrics, ModelProfile
from repro.core.swarm import SwarmRouter


@dataclass
class _MB:
    """One microbatch's lifecycle."""
    id: int
    data_node: int
    path: List[int]                   # planned chain (GWTF) / realised (SWARM)
    pos: int = 0                      # index into path
    direction: str = "fwd"
    compute_history: List[Tuple[int, float]] = field(default_factory=list)
    slots: set = field(default_factory=set)   # nodes holding memory for us
    leg: int = 0                  # increments on every send; stale events ignored
    retries: int = 0
    done: bool = False
    failed: bool = False


@dataclass
class _NodeState:
    busy: int = 0
    queue: deque = field(default_factory=deque)   # FIFO, O(1) popleft
    crash_time: Optional[float] = None     # this iteration


class ReferenceTrainingSimulator:
    def __init__(self, net: FlowNetwork, *, scheduler: str = "gwtf",
                 profile: Optional[ModelProfile] = None,
                 churn: float = 0.0, timeout: float = 30.0,
                 max_retries: int = 2, fixed_paths=None,
                 rng: Optional[np.random.Generator] = None):
        """scheduler: 'gwtf' | 'swarm' | 'fixed' (preset paths — used for
        the DT-FM optimal-schedule baseline of Table VI)."""
        self.net = net
        self.scheduler = scheduler
        self.profile = profile or ModelProfile(fwd_compute=2.0)
        self.churn = churn
        self.timeout = timeout
        self.max_retries = max_retries
        self.fixed_paths = fixed_paths or []
        self.rng = rng or np.random.default_rng(0)
        self._mb_ids = itertools.count()
        self.protocol: Optional[GWTFProtocol] = None
        self.router: Optional[SwarmRouter] = None
        if scheduler == "gwtf":
            self.protocol = GWTFProtocol(net, rng=self.rng)
            self.protocol.run(max_rounds=100)
        elif scheduler == "swarm":
            self.router = SwarmRouter(net, stochastic=True, rng=self.rng)

    # ------------------------------------------------------------------
    # Churn at iteration boundaries
    # ------------------------------------------------------------------
    def _apply_churn(self) -> Dict[int, float]:
        """Sample crashes (mid-iteration times) and rejoins; returns
        {node_id: crash_time}."""
        crash_times: Dict[int, float] = {}
        est = self._estimate_iteration()
        for n in list(self.net.nodes.values()):
            if n.is_data:
                continue
            if n.alive and self.rng.uniform() < self.churn:
                crash_times[n.id] = float(self.rng.uniform(0.0, est))
            elif not n.alive and self.rng.uniform() < self.churn:
                n.alive = True                     # rejoin, usable this iter
                if self.protocol is not None:
                    self.protocol.add_node(n)
        return crash_times

    def _estimate_iteration(self) -> float:
        S = self.net.num_stages
        costs = [n.compute_cost for n in self.net.alive_nodes() if not n.is_data]
        mean_c = float(np.mean(costs)) if costs else 1.0
        per_hop = mean_c * (1 + self.profile.bwd_mult)
        return max(60.0, S * (per_hop + 10.0))

    # ------------------------------------------------------------------
    def _comm(self, i: int, j: int) -> float:
        return self.net.comm_cost(i, j, self.profile.activation_bytes)

    def _compute_time(self, nid: int, direction: str) -> float:
        """Node.compute_cost is seconds per microbatch forward pass."""
        n = self.net.nodes[nid]
        base = max(0.05, n.compute_cost)
        return base * (self.profile.bwd_mult if direction == "bwd" else 1.0)

    def _alive_at(self, nid: int, t: float, crash_times: Dict[int, float]) -> bool:
        n = self.net.nodes.get(nid)
        if n is None or not n.alive:
            return False
        ct = crash_times.get(nid)
        return ct is None or t < ct

    # ------------------------------------------------------------------
    # Routing / recovery decisions
    # ------------------------------------------------------------------
    def _gwtf_reroute(self, mb: _MB, from_node: int, target_stage: int,
                      t: float, crash_times: Dict[int, float],
                      states: Dict[int, _NodeState]) -> Optional[int]:
        """Flow-algorithm reroute: cheapest alive next-stage node with
        spare capacity (the protocol's Request Flow applied at fault time)."""
        if target_stage >= self.net.num_stages:
            return mb.data_node
        best, best_c = None, None
        for n in self.net.stage_nodes(target_stage):
            if not self._alive_at(n.id, t, crash_times):
                continue
            st = states[n.id]
            load_penalty = max(0, st.busy + len(st.queue) - n.capacity + 1)
            c = self.net.edge_cost(from_node, n.id,
                                   self.profile.activation_bytes)
            c += load_penalty * self._compute_time(n.id, mb.direction)
            if best_c is None or c < best_c:
                best, best_c = n.id, c
        return best

    def _swarm_reroute(self, mb: _MB, from_node: int, target_stage: int,
                       t: float, crash_times: Dict[int, float],
                       exclude: set) -> Optional[int]:
        if target_stage >= self.net.num_stages:
            return mb.data_node
        cands = [n.id for n in self.net.stage_nodes(target_stage)
                 if self._alive_at(n.id, t, crash_times)
                 and n.id not in exclude]
        if not cands:
            return None
        costs = [self._comm(from_node, j) for j in cands]
        return int(cands[int(np.argmin(costs))])

    # ------------------------------------------------------------------
    # One training iteration
    # ------------------------------------------------------------------
    def run_iteration(self) -> IterationMetrics:
        m = IterationMetrics()
        crash_times = self._apply_churn()
        states: Dict[int, _NodeState] = {
            nid: _NodeState(crash_time=crash_times.get(nid))
            for nid in self.net.nodes}

        # ---- routing: build this iteration's paths --------------------
        mbs: List[_MB] = []
        if self.scheduler == "gwtf":
            # nodes already crashed (still dead from previous iterations)
            # were removed; re-run a few repair rounds (Sec. V-A runs in
            # parallel with training).
            self.protocol.reclaim_sink_slots()
            self.protocol.run(max_rounds=30, quiet_rounds=2)
            for chain in self.protocol.complete_flows():
                mbs.append(_MB(next(self._mb_ids), chain[0], list(chain)))
        elif self.scheduler == "fixed":
            for path in self.fixed_paths:
                mbs.append(_MB(next(self._mb_ids), path[0], list(path)))
        else:
            for dn in self.net.data_nodes():
                for _ in range(dn.capacity):
                    path = self.router.route(dn.id)
                    if path is not None:
                        mbs.append(_MB(next(self._mb_ids), dn.id, path))
        m.launched = len(mbs)

        # ---- event loop ------------------------------------------------
        # Memory semantics: a relay node's capacity counts *in-flight*
        # microbatches — the slot is held from forward arrival until the
        # backward pass completes at that node (activations must be kept
        # for the backward).  This is exactly why heterogeneous capacities
        # matter: SWARM routes capacity-blind and serialises on cap-1
        # nodes; GWTF's flows respect capacity by construction.
        seq = itertools.count()
        events: List = []

        def push(t, kind, mb, node, payload=None):
            heapq.heappush(events, (t, next(seq), kind, mb, node, payload))

        def send(mb: _MB, frm: int, to: int, t: float):
            mb.leg += 1
            c = self._comm(frm, to)
            m.comm_time += c
            push(t + c, "arrive", mb, to, (frm, mb.leg))
            # sender expects a COMPLETE within comm+compute+timeout; a slow
            # (overloaded) peer is indistinguishable from a dead one.
            expect = c + self._compute_time(to, mb.direction) + self.timeout
            push(t + expect, "check", mb, to, (frm, mb.leg))

        def release_slot(mb: _MB, nid: int, t: float):
            if nid not in mb.slots:
                return
            mb.slots.discard(nid)
            st = states[nid]
            st.busy -= 1
            while st.queue and self._alive_at(nid, t, crash_times):
                qmb, qleg = st.queue.popleft()
                if qmb.done or qmb.failed or qleg != qmb.leg:
                    continue                       # stale queue entry
                st.busy += 1
                qmb.slots.add(nid)
                push(t + self._compute_time(nid, qmb.direction),
                     "done", qmb, nid, qleg)
                break

        def fail(mb: _MB, t: float):
            mb.failed = True
            m.wasted_gpu += sum(c for _, c in mb.compute_history)
            for nid in list(mb.slots):
                release_slot(mb, nid, t)

        loop_t0 = time.perf_counter()
        for mb in mbs:
            nxt = mb.path[1]
            mb.pos = 1
            send(mb, mb.data_node, nxt, 0.0)

        end_time = 0.0
        max_events = 500_000
        while events and max_events > 0:
            max_events -= 1
            t, _, kind, mb, nid, payload = heapq.heappop(events)
            if mb.done or mb.failed:
                continue
            if kind == "arrive":
                frm, leg = payload
                if leg != mb.leg:
                    continue                       # rerouted while in flight
                if not self._alive_at(nid, t, crash_times):
                    continue                       # sender's check recovers
                if nid == mb.data_node:
                    if mb.direction == "fwd":
                        # loss computed at data node; turn around
                        mb.direction = "bwd"
                        mb.pos = len(mb.path) - 2
                        send(mb, mb.data_node, mb.path[mb.pos], t)
                    else:
                        mb.done = True
                        m.completed += 1
                        end_time = max(end_time, t)
                    continue
                st = states[nid]
                cap = self.net.nodes[nid].capacity
                if mb.direction == "bwd":
                    if nid not in mb.slots and st.busy < cap:
                        st.busy += 1
                        mb.slots.add(nid)
                    push(t + self._compute_time(nid, "bwd"),
                         "done", mb, nid, leg)
                elif nid in mb.slots:
                    push(t + self._compute_time(nid, "fwd"),
                         "done", mb, nid, leg)
                elif st.busy < cap:
                    st.busy += 1
                    mb.slots.add(nid)
                    push(t + self._compute_time(nid, "fwd"),
                         "done", mb, nid, leg)
                else:
                    st.queue.append((mb, leg))     # wait for a free slot
            elif kind == "done":
                leg = payload
                if leg is not None and leg != mb.leg:
                    # we were rerouted away while this node was computing:
                    # its work is wasted, its slot freed.
                    m.wasted_gpu += self._compute_time(nid, mb.direction)
                    release_slot(mb, nid, t)
                    continue
                if not self._alive_at(nid, t, crash_times):
                    # crashed mid-compute: work lost; sender's check recovers
                    m.wasted_gpu += self._compute_time(nid, mb.direction)
                    continue
                mb.compute_history.append(
                    (nid, self._compute_time(nid, mb.direction)))
                if mb.direction == "bwd":
                    release_slot(mb, nid, t)
                    mb.pos -= 1
                else:
                    mb.pos += 1
                nxt = (mb.data_node if (mb.pos <= 0 or mb.pos >= len(mb.path) - 1)
                       else mb.path[mb.pos])
                send(mb, nid, nxt, t)
                end_time = max(end_time, t)
            elif kind == "check":
                frm, leg = payload
                if leg != mb.leg:
                    continue                       # progressed past this leg
                # no COMPLETE for this leg: the receiver is dead OR too
                # slow (queued behind an over-committed node) — the sender
                # cannot tell the difference and reroutes either way.
                if not self._alive_at(nid, t, crash_times):
                    mb.slots.discard(nid)
                self._recover(mb, frm, nid, t, crash_times, states,
                              send, fail, m)
                end_time = max(end_time, t)
        m.loop_seconds = time.perf_counter() - loop_t0
        m.events = 500_000 - max_events

        for mb in mbs:
            if not mb.done and not mb.failed:
                mb.failed = True
                m.wasted_gpu += sum(c for _, c in mb.compute_history)

        # ---- aggregation phase (Sec. V-E) ------------------------------
        m.aggregation_time = self._aggregation_time(crash_times)
        m.duration = end_time + m.aggregation_time

        # ---- commit crashes for the next iteration ---------------------
        for nid in crash_times:
            self.net.kill_node(nid)
            if self.protocol is not None:
                self.protocol.remove_node(nid)
        return m

    # ------------------------------------------------------------------
    def _recover(self, mb: _MB, frm: int, dead: int, t: float,
                 crash_times, states, send, fail, m: IterationMetrics):
        """Sender `frm` noticed `dead` is unresponsive."""
        if mb.retries >= self.max_retries:
            fail(mb, t)
            return
        mb.retries += 1
        if self.scheduler == "fixed":
            fail(mb, t)                # preset schedules cannot reroute
            return
        dead_node = self.net.nodes[dead]
        target_stage = (dead_node.stage if not dead_node.is_data
                        else self.net.num_stages)
        if self.scheduler == "gwtf":
            sub = self._gwtf_reroute(mb, frm, target_stage, t, crash_times,
                                     states)
            if sub is None:
                fail(mb, t)                 # DENY upstream: defer the batch
                return
            if mb.direction == "bwd":
                # pipeline repair (Sec. V-D): the substitute recomputes
                # ONLY this stage's forward from the stored upstream
                # activation, then the backward resumes from the stored
                # gradient — no full-pipeline recompute.
                mb.path[mb.pos] = sub
                recompute = self._compute_time(sub, "fwd")
                send(mb, frm, sub, t + recompute)
            else:
                mb.path[mb.pos] = sub
                send(mb, frm, sub, t)
        else:
            if mb.direction == "bwd":
                # SWARM: full pipeline recomputation from the data node
                m.wasted_gpu += sum(c for _, c in mb.compute_history)
                mb.compute_history.clear()
                for nid2 in list(mb.slots):
                    # slots released while the pipeline restarts
                    st = states[nid2]
                    st.busy -= 1
                    mb.slots.discard(nid2)
                path = self.router.route(mb.data_node)
                if path is None:
                    fail(mb, t)
                    return
                mb.path = path
                mb.direction = "fwd"
                mb.pos = 1
                send(mb, mb.data_node, path[1], t)
            else:
                sub = self._swarm_reroute(mb, frm, target_stage, t,
                                          crash_times, exclude={dead})
                if sub is None:
                    fail(mb, t)
                    return
                mb.path[mb.pos] = sub
                send(mb, frm, sub, t)

    # ------------------------------------------------------------------
    def _aggregation_time(self, crash_times) -> float:
        """BEGIN-AGGREGATION wave + intra-stage weight exchange + CAN-TAKE."""
        total_wave = 0.0
        agg = 0.0
        for s in range(self.net.num_stages):
            nodes = [n for n in self.net.stage_nodes(s)
                     if crash_times.get(n.id) is None]
            if len(nodes) < 2:
                continue
            worst = 0.0
            for a in nodes:
                for b in nodes:
                    if a.id == b.id:
                        continue
                    worst = max(worst, self.net.comm_cost(
                        a.id, b.id, self.profile.stage_param_bytes))
            agg = max(agg, worst)
            total_wave += 0.05          # BEGIN AGG / CAN TAKE hop latency
        return agg + 2 * total_wave

    # ------------------------------------------------------------------
    def run(self, iterations: int) -> List[IterationMetrics]:
        return [self.run_iteration() for _ in range(iterations)]
