"""Compatibility facade: the pre-refactor `TrainingSimulator` API.

Thin wrapper assembling the layered engine (`SimulationEngine` +
`RoutingPolicy` + `ChurnModel`) behind the constructor signature every
existing caller uses (`tests/test_simulator.py`, the crash benchmarks,
`examples/churn_recovery.py`).  Seeded runs reproduce the pre-refactor
implementation's RNG stream and metrics exactly on the GWTF and fixed
paths; SWARM differs only by the backward-restart slot-leak fix.

New capabilities are opt-in keyword arguments:

* ``churn_model=`` — any `repro.core.sim.faults.ChurnModel` (trace
  replay, correlated regional outages, compositions); overrides the
  Bernoulli model implied by ``churn=``;
* ``policy=`` — a pre-built `RoutingPolicy`, overriding ``scheduler=``;
* ``max_events=`` — the per-iteration event budget (exhaustion is now
  reported via `IterationMetrics.truncated` + a ``RuntimeWarning``);
* ``plan_overrun_factor=`` / ``plan_overrun_min_seconds=`` — the
  engine's planning-overrun guard: when ``policy.plan()`` wall time
  exceeds the event-loop wall time by the factor (and the absolute
  minimum), the iteration is flagged (`IterationMetrics.plan_overrun`),
  a ``RuntimeWarning`` fires, and the policy's ``throttle_planning()``
  hook (if any) caps further planning effort.

Conflicting keyword combinations used to be resolved by silently
ignoring one side (``churn=`` dropped when ``churn_model=`` was given,
``scheduler=``/``fixed_paths=`` dropped when ``policy=`` was given) —
a scenario spec that set both would run a *different* scenario than it
described.  They now raise ``ValueError``.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core.flow.graph import FlowNetwork
from repro.core.sim.engine import SimulationEngine
from repro.core.sim.faults import BernoulliChurn, ChurnModel
from repro.core.sim.metrics import IterationMetrics, ModelProfile
from repro.core.sim.policies import (GWTFPolicy, RoutingPolicy, SwarmPolicy,
                                     make_policy)


class TrainingSimulator:
    def __init__(self, net: FlowNetwork, *,
                 scheduler: Optional[str] = None,
                 profile: Optional[ModelProfile] = None,
                 churn: float = 0.0, timeout: float = 30.0,
                 max_retries: int = 2, fixed_paths=None,
                 rng: Optional[np.random.Generator] = None,
                 churn_model: Optional[ChurnModel] = None,
                 policy: Optional[RoutingPolicy] = None,
                 max_events: int = 500_000,
                 plan_overrun_factor: float = 100.0,
                 plan_overrun_min_seconds: float = 0.5,
                 deadline_defense: bool = True,
                 corrupt_screen: bool = True):
        """scheduler: 'gwtf' (default) | 'swarm' | 'fixed' (preset paths
        — used for the DT-FM optimal-schedule baseline of Table VI)."""
        if churn and churn_model is not None:
            raise ValueError(
                f"churn={churn} and churn_model={churn_model!r} both "
                f"given — the Bernoulli rate would be silently ignored; "
                f"pass exactly one (compose with ComposedChurn instead)")
        if policy is not None:
            if scheduler is not None:
                raise ValueError(
                    f"scheduler={scheduler!r} and policy={policy!r} both "
                    f"given — the scheduler name would be silently "
                    f"ignored; pass exactly one")
            if fixed_paths:
                raise ValueError(
                    "fixed_paths given alongside policy= — they would be "
                    "silently ignored; build the FixedPolicy yourself")
        elif fixed_paths and scheduler != "fixed":
            raise ValueError(
                f"fixed_paths given but scheduler={scheduler!r} — preset "
                f"paths are only consumed by scheduler='fixed'")
        scheduler = scheduler or "gwtf"
        self.net = net
        self.profile = profile or ModelProfile(fwd_compute=2.0)
        self.churn = churn
        self.timeout = timeout
        self.max_retries = max_retries
        self.fixed_paths = fixed_paths or []
        self.rng = rng or np.random.default_rng(0)
        if policy is None:
            policy = make_policy(scheduler, net, rng=self.rng,
                                 fixed_paths=self.fixed_paths)
        self.policy = policy
        self.scheduler = getattr(policy, "name", scheduler)
        # legacy attribute surface
        self.protocol = policy.protocol if isinstance(policy, GWTFPolicy) else None
        self.router = policy.router if isinstance(policy, SwarmPolicy) else None
        self.engine = SimulationEngine(
            net, policy, churn_model=churn_model or BernoulliChurn(churn),
            profile=self.profile, timeout=timeout, max_retries=max_retries,
            rng=self.rng, max_events=max_events,
            plan_overrun_factor=plan_overrun_factor,
            plan_overrun_min_seconds=plan_overrun_min_seconds,
            deadline_defense=deadline_defense,
            corrupt_screen=corrupt_screen)

    def run_iteration(self) -> IterationMetrics:
        return self.engine.run_iteration()

    def run(self, iterations: int) -> List[IterationMetrics]:
        return self.engine.run(iterations)
