"""Shared fault timeline: one deterministic record of every fault.

Both execution layers — the discrete-event simulator
(`sim/engine.py`) and the staged runtime (`runtime/recovery.py` via
`runtime/trainer.py`) — consume the same `ChurnModel` stream.  This
module gives them one vocabulary for what that stream *did*: a
`FaultTimeline` is an append-only list of `FaultRecord`s stamped with
the logical iteration index (never wall-clock — the simulator runs on
an event clock, the runtime on a normalized pipeline-flush clock, and
only the iteration index is shared).

Record kinds:

* ``injection`` — the fault model put a fault into the world
  (a crash scheduled, a node entering a straggler/hang window, a node
  marked gradient-corrupting, a flaky-link episode becoming active).
  Injections are recorded by `record_injections` from the model's own
  per-iteration outputs, so the two layers produce *identical*
  injection records by construction.
* ``detection`` — the defense layer noticed the fault (a deadline
  fired on a hung relay, the gradient screen flagged a contribution,
  a delivery failure was observed).
* ``repair`` — the response succeeded (the microbatch was re-sent to
  a substitute, the flagged contribution was excluded from the
  update, the flaky leg was retried to completion).

Cross-layer equality contract (enforced by
`scenarios.harness.check_fault_timeline` on deterministic programs):

* per-iteration **injection** counts match for *every* fault class;
* per-iteration **detection/repair** counts match for the
  iteration-granular adversarial classes (``straggler``,
  ``corrupt_gradient``) whose injection windows cover whole
  iterations — every microbatch routed through an afflicted node is
  affected in both layers, so the counts are a function of the
  (bit-equal) plans, not of event timing;
* ``crash`` and ``flaky_link`` detection/repair counts are recorded
  per layer but not cross-compared: they depend on intra-iteration
  event timing (a microbatch may clear a node before its crash time)
  and on per-leg traversal order, which the two clocks model
  differently.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Mapping, Optional, Tuple

#: fault classes a record may carry
FAULT_CLASSES = ("crash", "straggler", "corrupt_gradient", "flaky_link")

#: record kinds
KINDS = ("injection", "detection", "repair")

#: fault classes whose detection/repair counts are comparable across
#: layers (iteration-granular injection windows; see module docstring)
CROSS_LAYER_FAULTS = ("straggler", "corrupt_gradient")


@dataclass(frozen=True)
class FaultRecord:
    """One stamped fault event.  ``node`` is -1 when the fault is not
    attributable to a single node (e.g. a link-level episode)."""
    iteration: int
    fault: str
    kind: str
    node: int = -1

    def __post_init__(self):
        if self.fault not in FAULT_CLASSES:
            raise ValueError(f"unknown fault class {self.fault!r}; "
                             f"expected one of {FAULT_CLASSES}")
        if self.kind not in KINDS:
            raise ValueError(f"unknown record kind {self.kind!r}; "
                             f"expected one of {KINDS}")


@dataclass
class FaultTimeline:
    """Append-only, deterministic fault record."""
    records: List[FaultRecord] = field(default_factory=list)

    def record(self, iteration: int, fault: str, kind: str,
               node: int = -1) -> None:
        self.records.append(FaultRecord(iteration, fault, kind, node))

    def counts(self, *, kinds: Optional[Iterable[str]] = None,
               faults: Optional[Iterable[str]] = None
               ) -> Dict[Tuple[int, str, str], int]:
        """Per-(iteration, fault, kind) counts, optionally filtered."""
        kinds = set(kinds) if kinds is not None else None
        faults = set(faults) if faults is not None else None
        out: Dict[Tuple[int, str, str], int] = {}
        for r in self.records:
            if kinds is not None and r.kind not in kinds:
                continue
            if faults is not None and r.fault not in faults:
                continue
            key = (r.iteration, r.fault, r.kind)
            out[key] = out.get(key, 0) + 1
        return out

    def comparable_counts(self) -> Dict[Tuple[int, str, str], int]:
        """The subset of counts the cross-layer contract pins: all
        injections, plus detection/repair for `CROSS_LAYER_FAULTS`."""
        out = self.counts(kinds=("injection",))
        out.update(self.counts(kinds=("detection", "repair"),
                               faults=CROSS_LAYER_FAULTS))
        return out

    def __len__(self) -> int:
        return len(self.records)


def record_injections(timeline: FaultTimeline, iteration: int,
                      crashes: Mapping[int, float],
                      plan) -> None:
    """Stamp this iteration's injections from the churn model outputs.

    Called by both the sim engine and the runtime trainer with the
    same ``crashes`` dict (from ``ChurnModel.sample``) and the same
    `AdversarialPlan` (from ``faults.adversarial_plan``), immediately
    after sampling — so the two layers' injection records are
    identical by construction.
    """
    for nid in sorted(crashes):
        timeline.record(iteration, "crash", "injection", nid)
    if plan is None or plan.is_empty():
        return
    for nid in sorted(set(plan.slow) | set(plan.hung)):
        timeline.record(iteration, "straggler", "injection", nid)
    for nid in sorted(plan.corrupt):
        timeline.record(iteration, "corrupt_gradient", "injection", nid)
    for _ in range(plan.flaky_episodes):
        timeline.record(iteration, "flaky_link", "injection", -1)
