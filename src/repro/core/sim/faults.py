"""Fault layer: composable churn models for the simulation engine.

A `ChurnModel` decides, at each iteration boundary, which nodes crash
mid-iteration (and when) and which previously-dead nodes rejoin.  The
engine hands it a `ChurnContext` and receives `{node_id: crash_time}`;
rejoins are applied through `ctx.on_rejoin` so the routing policy can
re-admit the node (e.g. `GWTFProtocol.add_node`).

Models:

* `BernoulliChurn` — the paper's Sec. VI experiment: every alive relay
  independently crashes with probability `p` at a uniform time inside
  the iteration; every dead relay rejoins with probability `p`.  RNG
  draw order is kept identical to the pre-refactor simulator so seeded
  runs reproduce.
* `TraceChurn` — deterministic replay of a recorded (or hand-written)
  churn trace: `(iteration, "crash"|"rejoin", node_id[, when])`
  events, `when` given as a fraction of the estimated iteration span.
* `RegionalOutageChurn` — correlated failures keyed on the paper's 10
  geographic locations (`Node.location`): with probability
  `outage_prob` one region suffers an outage and all (or a `severity`
  fraction of) its alive relays crash at the *same* moment; dead
  relays independently rejoin with `rejoin_prob`.
* `ComposedChurn` — applies several models in sequence (union of
  crashes, earliest crash time wins), e.g. background Bernoulli churn
  plus rare regional outages.
* `LinkDegradationChurn` — deterministic link-quality fault: at a
  scripted iteration the (optionally inter-region-only) bandwidth
  matrix is divided by a factor and restored a fixed number of
  iterations later.  Crashes nobody; the fault propagates through the
  Eq. 1 cost caches instead.

Beyond fail-stop (adversarial models; Lu et al., "Exploring the
Robustness of Decentralized Training"):

* `StragglerChurn` — per-node compute slowdown multipliers and hard
  hangs (a hung node accepts work and never finishes it; only a
  deadline can catch it).  Crashes nobody.
* `CorruptGradientChurn` — Byzantine nodes whose backward results are
  sign-flipped / zeroed / perturbed.  Seeded and deterministic; the
  runtime applies the perturbation, the simulator models its
  detection.
* `FlakyLinkChurn` — per-leg Bernoulli delivery failure with a
  counter-based deterministic coin (`leg_ok`), so both execution
  layers see the same drop for the same logical (microbatch, leg,
  attempt) regardless of event ordering.

These return ``{}`` from ``sample`` (they crash nobody) and instead
publish an `AdversarialPlan` via ``adversarial_plan(iteration)`` —
a per-iteration side channel the engine, the runtime recovery sweep
and the trainer's gradient screen probe with
`adversarial_plan(model, iteration)` (duck-typed, so fail-stop models
and the bit-identical default paths are untouched).  All three are
iteration-granular (a fault window covers whole iterations) and draw
from their *own* seeds, never from ``ChurnContext.rng`` — the shared
policy RNG stream stays identical to the fail-stop runs and the
models qualify as deterministic clauses for the differential harness.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import (Callable, Dict, Iterable, List, Mapping, Optional,
                    Protocol, Sequence, Set, Tuple)

import numpy as np

from repro.core.flow.graph import FlowNetwork, Node


@dataclass
class ChurnContext:
    """What a churn model may observe when sampling one iteration."""
    net: FlowNetwork
    rng: np.random.Generator
    horizon: float                      # estimated iteration span (seconds)
    iteration: int                      # 0-based iteration index
    on_rejoin: Callable[[Node], None]   # notify the routing policy


class ChurnModel(Protocol):
    def sample(self, ctx: ChurnContext) -> Dict[int, float]:
        """Apply rejoins (via ``ctx.on_rejoin``) and return this
        iteration's mid-iteration crashes as {node_id: crash_time}."""
        ...


class BernoulliChurn:
    """Independent per-relay crash/rejoin coin flips (paper Sec. VI).

    Draw order matches the pre-refactor ``TrainingSimulator._apply_churn``
    exactly (one uniform per relay, a second for the crash time), so a
    seeded run through the facade reproduces the seed implementation's
    RNG stream bit-for-bit.
    """

    def __init__(self, p: float):
        self.p = p

    def sample(self, ctx: ChurnContext) -> Dict[int, float]:
        crash_times: Dict[int, float] = {}
        rng, p = ctx.rng, self.p
        for n in list(ctx.net.nodes.values()):
            if n.is_data:
                continue
            if n.alive and rng.uniform() < p:
                crash_times[n.id] = float(rng.uniform(0.0, ctx.horizon))
            elif not n.alive and rng.uniform() < p:
                n.alive = True                     # rejoin, usable this iter
                ctx.on_rejoin(n)
        return crash_times


class TraceChurn:
    """Deterministic replay of a churn trace.

    ``events`` is an iterable of ``(iteration, kind, node_id)`` or
    ``(iteration, kind, node_id, when)`` tuples with ``kind`` in
    {"crash", "rejoin"}; ``when`` is the crash time as a fraction of
    the engine's estimated iteration span (default 0.5).  Events for
    dead nodes ("crash") or alive nodes ("rejoin") are skipped, so a
    trace recorded on one topology replays safely on another.

    ``known_ids`` (when given) validates every event's node id at
    construction: a typo'd id raises ``ValueError`` naming the
    offender immediately instead of the event being silently skipped
    (or a ``KeyError`` surfacing mid-run from a downstream consumer).
    """

    def __init__(self, events: Iterable[Sequence], *,
                 known_ids: Optional[Iterable[int]] = None):
        known = set(known_ids) if known_ids is not None else None
        self._by_iter: Dict[int, List[Tuple[str, int, float]]] = {}
        for ev in events:
            it, kind, nid = int(ev[0]), str(ev[1]), int(ev[2])
            when = float(ev[3]) if len(ev) > 3 else 0.5
            if kind not in ("crash", "rejoin"):
                raise ValueError(f"unknown trace event kind {kind!r}")
            if known is not None and nid not in known:
                raise ValueError(
                    f"trace event {tuple(ev)!r} names unknown node "
                    f"{nid}; known ids are "
                    f"{sorted(known)[:20]}{'...' if len(known) > 20 else ''}")
            self._by_iter.setdefault(it, []).append((kind, nid, when))

    @classmethod
    def regional_blackout(cls, net: FlowNetwork, *, location: int,
                          at_iteration: int, duration: int = 2,
                          when: float = 0.25) -> "TraceChurn":
        """Convenience trace: every relay in ``location`` crashes at
        ``at_iteration`` and rejoins ``duration`` iterations later.

        The location must actually contain relays — a blackout of an
        empty (or misspelled-index) region would silently be a no-op,
        so it raises ``ValueError`` listing the populated locations.
        """
        nids = [n.id for n in net.nodes.values()
                if not n.is_data and n.location == location]
        if not nids:
            present = sorted({n.location for n in net.nodes.values()
                              if not n.is_data and n.location >= 0})
            raise ValueError(
                f"regional_blackout: no relays in location {location}; "
                f"populated locations are {present}")
        events: List[Tuple[int, str, int, float]] = []
        events += [(at_iteration, "crash", nid, when) for nid in nids]
        events += [(at_iteration + duration, "rejoin", nid, 0.0)
                   for nid in nids]
        return cls(events, known_ids=net.nodes.keys())

    def sample(self, ctx: ChurnContext) -> Dict[int, float]:
        crash_times: Dict[int, float] = {}
        for kind, nid, when in self._by_iter.get(ctx.iteration, ()):
            n = ctx.net.nodes.get(nid)
            if n is None or n.is_data:
                continue
            if kind == "crash" and n.alive:
                crash_times[nid] = when * ctx.horizon
            elif kind == "rejoin" and not n.alive:
                n.alive = True
                ctx.on_rejoin(n)
        return crash_times


class RegionalOutageChurn:
    """Correlated regional failures (FusionLLM-style geo outages).

    Each iteration, with probability ``outage_prob`` one geographic
    location (uniform over the locations present among relays) goes
    down: every alive relay there crashes at the *same* uniformly-drawn
    moment (``severity`` < 1 spares each relay independently with
    probability ``1 - severity``).  Dead relays rejoin independently
    with ``rejoin_prob`` per iteration, modelling region recovery.

    Requires ``Node.location`` >= 0 (set by ``geo_distributed_network``);
    relays with unknown location are never hit by outages.
    """

    def __init__(self, outage_prob: float, *, severity: float = 1.0,
                 rejoin_prob: float = 0.5):
        self.outage_prob = outage_prob
        self.severity = severity
        self.rejoin_prob = rejoin_prob

    def sample(self, ctx: ChurnContext) -> Dict[int, float]:
        rng = ctx.rng
        crash_times: Dict[int, float] = {}
        relays = [n for n in ctx.net.nodes.values() if not n.is_data]
        regions = sorted({n.location for n in relays if n.location >= 0})
        if regions and rng.uniform() < self.outage_prob:
            region = regions[int(rng.integers(0, len(regions)))]
            outage_at = float(rng.uniform(0.0, ctx.horizon))
            for n in relays:
                if n.location != region or not n.alive:
                    continue
                if self.severity >= 1.0 or rng.uniform() < self.severity:
                    crash_times[n.id] = outage_at
        if self.rejoin_prob > 0.0:
            for n in relays:
                if not n.alive and rng.uniform() < self.rejoin_prob:
                    n.alive = True
                    ctx.on_rejoin(n)
        return crash_times


class LinkDegradationChurn:
    """Scripted bandwidth degradation (no crashes).

    At ``at_iteration`` every link's bandwidth is divided by ``factor``
    (``inter_region_only=True`` restricts the cut to links whose
    endpoints live in different ``Node.location`` regions — the WAN
    legs of the paper's geo topology); ``duration`` iterations later
    the cut is undone by re-multiplying the degraded entries
    (0 = permanent).  The multiplicative undo composes correctly with
    other concurrent degradations (a snapshot restore would clobber
    them); it is bit-exact for power-of-two factors and within 1 ulp
    otherwise.  The mutation goes
    through ``FlowNetwork.invalidate_costs`` so every consumer of the
    Eq. 1 caches — the GWTF protocol's cost oracle, the engine's
    batched cost tables, the runtime's fault views — sees the change
    on its next query.
    """

    def __init__(self, at_iteration: int, factor: float, *,
                 duration: int = 0, inter_region_only: bool = True):
        if factor <= 0:
            raise ValueError("degradation factor must be positive")
        self.at_iteration = at_iteration
        self.factor = factor
        self.duration = duration
        self.inter_region_only = inter_region_only
        # (size, mask-or-None) of the entries this model degraded; the
        # restore *multiplies them back* rather than restoring a saved
        # matrix, so overlapping degradation windows (e.g. two models in
        # a ComposedChurn) compose and un-compose correctly instead of
        # one model's snapshot clobbering the other's active cut
        self._applied: Optional[Tuple[int, Optional[np.ndarray]]] = None

    def sample(self, ctx: ChurnContext) -> Dict[int, float]:
        net = ctx.net
        if ctx.iteration == self.at_iteration:
            bw = net.bandwidth
            n = bw.shape[0]
            if self.inter_region_only:
                loc = np.full(n, -1, np.int64)
                for nid, node in net.nodes.items():
                    if nid < n:
                        loc[nid] = node.location
                inter = loc[:, None] != loc[None, :]
                bw[inter] /= self.factor
                self._applied = (n, inter)
            else:
                bw /= self.factor
                self._applied = (n, None)
            net.invalidate_costs()
        elif (self.duration and self._applied is not None
              and ctx.iteration == self.at_iteration + self.duration):
            n, mask = self._applied
            # the network may have grown since (joins); undo only the
            # entries the degradation touched
            if mask is None:
                net.bandwidth[:n, :n] *= self.factor
            else:
                sub = net.bandwidth[:n, :n]
                sub[mask] *= self.factor
            self._applied = None
            net.invalidate_costs()
        return {}


# ---------------------------------------------------------------------------
# Adversarial (beyond fail-stop) fault models
# ---------------------------------------------------------------------------

def _check_window(at_iteration: int, duration: int) -> None:
    if at_iteration < 0:
        raise ValueError(f"at_iteration must be >= 0, got {at_iteration}")
    if duration < 0:
        raise ValueError(f"duration must be >= 0 (0 = forever), "
                         f"got {duration}")


def _check_known(ids: Iterable[int],
                 known_ids: Optional[Iterable[int]], what: str) -> None:
    if known_ids is None:
        return
    known = set(known_ids)
    for nid in ids:
        if nid not in known:
            raise ValueError(
                f"{what} names unknown node {nid}; known ids are "
                f"{sorted(known)[:20]}{'...' if len(known) > 20 else ''}")


@dataclass(frozen=True)
class AdversarialPlan:
    """One iteration's adversarial faults, published by a model's
    ``adversarial_plan(iteration)`` side channel.

    * ``slow`` — node id -> compute-time multiplier (> 1 is slower);
    * ``hung`` — nodes that accept work this iteration and never
      finish it (only a deadline catches them);
    * ``corrupt`` — node id -> ``(mode, scale, seed)`` gradient
      corruption spec (mode in {"sign_flip", "zero", "perturb"});
    * ``flaky`` — the `FlakyLinkChurn` models active this iteration;
      a logical leg delivers only if *every* model's ``leg_ok`` coin
      comes up heads.
    """
    slow: Mapping[int, float] = field(default_factory=dict)
    hung: frozenset = frozenset()
    corrupt: Mapping[int, Tuple[str, float, int]] = field(
        default_factory=dict)
    flaky: Tuple["FlakyLinkChurn", ...] = ()

    def is_empty(self) -> bool:
        return not (self.slow or self.hung or self.corrupt or self.flaky)

    @property
    def flaky_episodes(self) -> int:
        return len(self.flaky)

    def slow_factor(self, nid: int) -> float:
        return self.slow.get(nid, 1.0)

    def leg_ok(self, iteration: int, mb_id: int, direction: str,
               position: int, attempt: int) -> bool:
        """Deterministic delivery coin for one logical leg attempt —
        identical across execution layers for the same key."""
        return all(m.leg_ok(iteration, mb_id, direction, position, attempt)
                   for m in self.flaky)

    @staticmethod
    def merge(plans: Sequence[Optional["AdversarialPlan"]]
              ) -> Optional["AdversarialPlan"]:
        live = [p for p in plans if p is not None and not p.is_empty()]
        if not live:
            return None
        if len(live) == 1:
            return live[0]
        slow: Dict[int, float] = {}
        corrupt: Dict[int, Tuple[str, float, int]] = {}
        hung: Set[int] = set()
        flaky: List["FlakyLinkChurn"] = []
        for p in live:
            for nid, f in p.slow.items():
                slow[nid] = slow.get(nid, 1.0) * f   # slowdowns compound
            hung |= p.hung
            for nid, spec in p.corrupt.items():
                corrupt.setdefault(nid, spec)        # first model wins
            flaky.extend(p.flaky)
        return AdversarialPlan(slow=slow, hung=frozenset(hung),
                               corrupt=corrupt, flaky=tuple(flaky))


def adversarial_plan(model, iteration: int) -> Optional[AdversarialPlan]:
    """Probe a churn model's adversarial side channel.  Returns None
    for plain fail-stop models and for iterations outside every fault
    window — the engines fast-path on None and stay bit-identical."""
    probe = getattr(model, "adversarial_plan", None)
    if probe is None:
        return None
    plan = probe(iteration)
    if plan is not None and plan.is_empty():
        return None
    return plan


class _WindowedAdversary:
    """Shared iteration-window plumbing: a fault is active for whole
    iterations ``[at_iteration, at_iteration + duration)`` (duration
    0 = forever).  Iteration granularity is deliberate — it makes the
    affected-microbatch sets a pure function of the (bit-equal) plans,
    so the sim and runtime fault timelines agree exactly."""

    def __init__(self, at_iteration: int, duration: int):
        _check_window(at_iteration, duration)
        self.at_iteration = at_iteration
        self.duration = duration

    def active(self, iteration: int) -> bool:
        if iteration < self.at_iteration:
            return False
        return (self.duration == 0
                or iteration < self.at_iteration + self.duration)

    def sample(self, ctx: ChurnContext) -> Dict[int, float]:
        return {}          # crashes nobody, draws nothing from ctx.rng


class StragglerChurn(_WindowedAdversary):
    """Per-node compute slowdowns and hard hangs.

    ``slowdowns`` maps node id -> multiplier (>= 1) applied to the
    node's forward/backward compute time; ``hangs`` lists nodes that
    accept microbatches and never complete them.  Deadlines are
    stamped from the *healthy* compute estimate, so a hung (or
    pathologically slow) node is caught by the engine/runtime deadline
    defense while mild slowdowns pass undisturbed.
    """

    def __init__(self, slowdowns: Optional[Mapping[int, float]] = None,
                 *, hangs: Iterable[int] = (), at_iteration: int = 0,
                 duration: int = 0,
                 known_ids: Optional[Iterable[int]] = None):
        super().__init__(at_iteration, duration)
        self.slowdowns = {int(k): float(v)
                          for k, v in (slowdowns or {}).items()}
        for nid, f in self.slowdowns.items():
            if f < 1.0:
                raise ValueError(f"slowdown factor for node {nid} must "
                                 f"be >= 1, got {f}")
        self.hangs = frozenset(int(n) for n in hangs)
        _check_known(list(self.slowdowns) + list(self.hangs), known_ids,
                     "StragglerChurn")

    def adversarial_plan(self, iteration: int) -> Optional[AdversarialPlan]:
        if not self.active(iteration):
            return None
        return AdversarialPlan(slow=dict(self.slowdowns), hung=self.hangs)


class CorruptGradientChurn(_WindowedAdversary):
    """Byzantine nodes whose backward results are corrupted.

    ``mode``: "sign_flip" (gradient negated), "zero" (gradient
    dropped to zero), or "perturb" (seeded Gaussian noise of relative
    magnitude ``scale`` added).  The perturbation is applied by the
    runtime trainer to every contribution whose chain crosses a
    corrupt node; the simulator — which carries no gradients — models
    the *detection* of the same contributions, so the two layers'
    fault timelines agree.
    """

    MODES = ("sign_flip", "zero", "perturb")

    def __init__(self, nodes: Iterable[int], *, mode: str = "sign_flip",
                 scale: float = 1.0, seed: int = 0, at_iteration: int = 0,
                 duration: int = 0,
                 known_ids: Optional[Iterable[int]] = None):
        super().__init__(at_iteration, duration)
        if mode not in self.MODES:
            raise ValueError(f"unknown corruption mode {mode!r}; "
                             f"expected one of {self.MODES}")
        if scale <= 0:
            raise ValueError(f"corruption scale must be positive, "
                             f"got {scale}")
        self.nodes = frozenset(int(n) for n in nodes)
        if not self.nodes:
            raise ValueError("CorruptGradientChurn needs >= 1 node")
        self.mode = mode
        self.scale = float(scale)
        self.seed = int(seed)
        _check_known(self.nodes, known_ids, "CorruptGradientChurn")

    def adversarial_plan(self, iteration: int) -> Optional[AdversarialPlan]:
        if not self.active(iteration):
            return None
        spec = (self.mode, self.scale, self.seed)
        return AdversarialPlan(corrupt={nid: spec for nid in self.nodes})


class FlakyLinkChurn(_WindowedAdversary):
    """Per-leg Bernoulli delivery failure.

    Each logical leg attempt — keyed by (iteration, microbatch id,
    direction, chain position, attempt index) — independently fails
    with probability ``p``.  The coin is *counter-based*: a fresh
    generator is seeded from the key, so the decision for a given leg
    is independent of how many other legs either execution layer
    evaluated before it, and both layers see the same drops.
    """

    def __init__(self, p: float, *, seed: int = 0, at_iteration: int = 0,
                 duration: int = 0):
        super().__init__(at_iteration, duration)
        if not 0.0 <= p <= 1.0:
            raise ValueError(f"delivery-failure probability must be in "
                             f"[0, 1], got {p}")
        self.p = float(p)
        self.seed = int(seed)

    def leg_ok(self, iteration: int, mb_id: int, direction: str,
               position: int, attempt: int) -> bool:
        if not self.active(iteration) or self.p <= 0.0:
            return True
        d = 0 if direction == "fwd" else 1
        coin = np.random.default_rng(
            [self.seed, iteration, mb_id, d, position, attempt])
        return float(coin.uniform()) >= self.p

    def adversarial_plan(self, iteration: int) -> Optional[AdversarialPlan]:
        if not self.active(iteration):
            return None
        return AdversarialPlan(flaky=(self,))


class ComposedChurn:
    """Union of several churn models, applied in order.

    Crash sets are merged with the earliest crash time winning; rejoins
    take effect immediately, so a later model sees (and may re-crash)
    nodes an earlier model just revived — matching how independent
    fault processes would interleave in the wild.

    Adversarial side channels compose too: slowdowns compound
    multiplicatively, hang/corrupt sets union, flaky links require
    every member's delivery coin to pass.
    """

    def __init__(self, models: Sequence[ChurnModel]):
        self.models = list(models)

    def sample(self, ctx: ChurnContext) -> Dict[int, float]:
        crash_times: Dict[int, float] = {}
        for model in self.models:
            for nid, t in model.sample(ctx).items():
                if nid not in crash_times or t < crash_times[nid]:
                    crash_times[nid] = t
        return crash_times

    def adversarial_plan(self, iteration: int) -> Optional[AdversarialPlan]:
        return AdversarialPlan.merge(
            [adversarial_plan(m, iteration) for m in self.models])
