"""Fault layer: composable churn models for the simulation engine.

A `ChurnModel` decides, at each iteration boundary, which nodes crash
mid-iteration (and when) and which previously-dead nodes rejoin.  The
engine hands it a `ChurnContext` and receives `{node_id: crash_time}`;
rejoins are applied through `ctx.on_rejoin` so the routing policy can
re-admit the node (e.g. `GWTFProtocol.add_node`).

Models:

* `BernoulliChurn` — the paper's Sec. VI experiment: every alive relay
  independently crashes with probability `p` at a uniform time inside
  the iteration; every dead relay rejoins with probability `p`.  RNG
  draw order is kept identical to the pre-refactor simulator so seeded
  runs reproduce.
* `TraceChurn` — deterministic replay of a recorded (or hand-written)
  churn trace: `(iteration, "crash"|"rejoin", node_id[, when])`
  events, `when` given as a fraction of the estimated iteration span.
* `RegionalOutageChurn` — correlated failures keyed on the paper's 10
  geographic locations (`Node.location`): with probability
  `outage_prob` one region suffers an outage and all (or a `severity`
  fraction of) its alive relays crash at the *same* moment; dead
  relays independently rejoin with `rejoin_prob`.
* `ComposedChurn` — applies several models in sequence (union of
  crashes, earliest crash time wins), e.g. background Bernoulli churn
  plus rare regional outages.
* `LinkDegradationChurn` — deterministic link-quality fault: at a
  scripted iteration the (optionally inter-region-only) bandwidth
  matrix is divided by a factor and restored a fixed number of
  iterations later.  Crashes nobody; the fault propagates through the
  Eq. 1 cost caches instead.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import (Callable, Dict, Iterable, List, Optional, Protocol,
                    Sequence, Tuple)

import numpy as np

from repro.core.flow.graph import FlowNetwork, Node


@dataclass
class ChurnContext:
    """What a churn model may observe when sampling one iteration."""
    net: FlowNetwork
    rng: np.random.Generator
    horizon: float                      # estimated iteration span (seconds)
    iteration: int                      # 0-based iteration index
    on_rejoin: Callable[[Node], None]   # notify the routing policy


class ChurnModel(Protocol):
    def sample(self, ctx: ChurnContext) -> Dict[int, float]:
        """Apply rejoins (via ``ctx.on_rejoin``) and return this
        iteration's mid-iteration crashes as {node_id: crash_time}."""
        ...


class BernoulliChurn:
    """Independent per-relay crash/rejoin coin flips (paper Sec. VI).

    Draw order matches the pre-refactor ``TrainingSimulator._apply_churn``
    exactly (one uniform per relay, a second for the crash time), so a
    seeded run through the facade reproduces the seed implementation's
    RNG stream bit-for-bit.
    """

    def __init__(self, p: float):
        self.p = p

    def sample(self, ctx: ChurnContext) -> Dict[int, float]:
        crash_times: Dict[int, float] = {}
        rng, p = ctx.rng, self.p
        for n in list(ctx.net.nodes.values()):
            if n.is_data:
                continue
            if n.alive and rng.uniform() < p:
                crash_times[n.id] = float(rng.uniform(0.0, ctx.horizon))
            elif not n.alive and rng.uniform() < p:
                n.alive = True                     # rejoin, usable this iter
                ctx.on_rejoin(n)
        return crash_times


class TraceChurn:
    """Deterministic replay of a churn trace.

    ``events`` is an iterable of ``(iteration, kind, node_id)`` or
    ``(iteration, kind, node_id, when)`` tuples with ``kind`` in
    {"crash", "rejoin"}; ``when`` is the crash time as a fraction of
    the engine's estimated iteration span (default 0.5).  Events for
    dead nodes ("crash") or alive nodes ("rejoin") are skipped, so a
    trace recorded on one topology replays safely on another.
    """

    def __init__(self, events: Iterable[Sequence]):
        self._by_iter: Dict[int, List[Tuple[str, int, float]]] = {}
        for ev in events:
            it, kind, nid = int(ev[0]), str(ev[1]), int(ev[2])
            when = float(ev[3]) if len(ev) > 3 else 0.5
            if kind not in ("crash", "rejoin"):
                raise ValueError(f"unknown trace event kind {kind!r}")
            self._by_iter.setdefault(it, []).append((kind, nid, when))

    @classmethod
    def regional_blackout(cls, net: FlowNetwork, *, location: int,
                          at_iteration: int, duration: int = 2,
                          when: float = 0.25) -> "TraceChurn":
        """Convenience trace: every relay in ``location`` crashes at
        ``at_iteration`` and rejoins ``duration`` iterations later."""
        nids = [n.id for n in net.nodes.values()
                if not n.is_data and n.location == location]
        events: List[Tuple[int, str, int, float]] = []
        events += [(at_iteration, "crash", nid, when) for nid in nids]
        events += [(at_iteration + duration, "rejoin", nid, 0.0)
                   for nid in nids]
        return cls(events)

    def sample(self, ctx: ChurnContext) -> Dict[int, float]:
        crash_times: Dict[int, float] = {}
        for kind, nid, when in self._by_iter.get(ctx.iteration, ()):
            n = ctx.net.nodes.get(nid)
            if n is None or n.is_data:
                continue
            if kind == "crash" and n.alive:
                crash_times[nid] = when * ctx.horizon
            elif kind == "rejoin" and not n.alive:
                n.alive = True
                ctx.on_rejoin(n)
        return crash_times


class RegionalOutageChurn:
    """Correlated regional failures (FusionLLM-style geo outages).

    Each iteration, with probability ``outage_prob`` one geographic
    location (uniform over the locations present among relays) goes
    down: every alive relay there crashes at the *same* uniformly-drawn
    moment (``severity`` < 1 spares each relay independently with
    probability ``1 - severity``).  Dead relays rejoin independently
    with ``rejoin_prob`` per iteration, modelling region recovery.

    Requires ``Node.location`` >= 0 (set by ``geo_distributed_network``);
    relays with unknown location are never hit by outages.
    """

    def __init__(self, outage_prob: float, *, severity: float = 1.0,
                 rejoin_prob: float = 0.5):
        self.outage_prob = outage_prob
        self.severity = severity
        self.rejoin_prob = rejoin_prob

    def sample(self, ctx: ChurnContext) -> Dict[int, float]:
        rng = ctx.rng
        crash_times: Dict[int, float] = {}
        relays = [n for n in ctx.net.nodes.values() if not n.is_data]
        regions = sorted({n.location for n in relays if n.location >= 0})
        if regions and rng.uniform() < self.outage_prob:
            region = regions[int(rng.integers(0, len(regions)))]
            outage_at = float(rng.uniform(0.0, ctx.horizon))
            for n in relays:
                if n.location != region or not n.alive:
                    continue
                if self.severity >= 1.0 or rng.uniform() < self.severity:
                    crash_times[n.id] = outage_at
        if self.rejoin_prob > 0.0:
            for n in relays:
                if not n.alive and rng.uniform() < self.rejoin_prob:
                    n.alive = True
                    ctx.on_rejoin(n)
        return crash_times


class LinkDegradationChurn:
    """Scripted bandwidth degradation (no crashes).

    At ``at_iteration`` every link's bandwidth is divided by ``factor``
    (``inter_region_only=True`` restricts the cut to links whose
    endpoints live in different ``Node.location`` regions — the WAN
    legs of the paper's geo topology); ``duration`` iterations later
    the cut is undone by re-multiplying the degraded entries
    (0 = permanent).  The multiplicative undo composes correctly with
    other concurrent degradations (a snapshot restore would clobber
    them); it is bit-exact for power-of-two factors and within 1 ulp
    otherwise.  The mutation goes
    through ``FlowNetwork.invalidate_costs`` so every consumer of the
    Eq. 1 caches — the GWTF protocol's cost oracle, the engine's
    batched cost tables, the runtime's fault views — sees the change
    on its next query.
    """

    def __init__(self, at_iteration: int, factor: float, *,
                 duration: int = 0, inter_region_only: bool = True):
        if factor <= 0:
            raise ValueError("degradation factor must be positive")
        self.at_iteration = at_iteration
        self.factor = factor
        self.duration = duration
        self.inter_region_only = inter_region_only
        # (size, mask-or-None) of the entries this model degraded; the
        # restore *multiplies them back* rather than restoring a saved
        # matrix, so overlapping degradation windows (e.g. two models in
        # a ComposedChurn) compose and un-compose correctly instead of
        # one model's snapshot clobbering the other's active cut
        self._applied: Optional[Tuple[int, Optional[np.ndarray]]] = None

    def sample(self, ctx: ChurnContext) -> Dict[int, float]:
        net = ctx.net
        if ctx.iteration == self.at_iteration:
            bw = net.bandwidth
            n = bw.shape[0]
            if self.inter_region_only:
                loc = np.full(n, -1, np.int64)
                for nid, node in net.nodes.items():
                    if nid < n:
                        loc[nid] = node.location
                inter = loc[:, None] != loc[None, :]
                bw[inter] /= self.factor
                self._applied = (n, inter)
            else:
                bw /= self.factor
                self._applied = (n, None)
            net.invalidate_costs()
        elif (self.duration and self._applied is not None
              and ctx.iteration == self.at_iteration + self.duration):
            n, mask = self._applied
            # the network may have grown since (joins); undo only the
            # entries the degradation touched
            if mask is None:
                net.bandwidth[:n, :n] *= self.factor
            else:
                sub = net.bandwidth[:n, :n]
                sub[mask] *= self.factor
            self._applied = None
            net.invalidate_costs()
        return {}


class ComposedChurn:
    """Union of several churn models, applied in order.

    Crash sets are merged with the earliest crash time winning; rejoins
    take effect immediately, so a later model sees (and may re-crash)
    nodes an earlier model just revived — matching how independent
    fault processes would interleave in the wild.
    """

    def __init__(self, models: Sequence[ChurnModel]):
        self.models = list(models)

    def sample(self, ctx: ChurnContext) -> Dict[int, float]:
        crash_times: Dict[int, float] = {}
        for model in self.models:
            for nid, t in model.sample(ctx).items():
                if nid not in crash_times or t < crash_times[nid]:
                    crash_times[nid] = t
        return crash_times
