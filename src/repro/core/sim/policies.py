"""Scheduler layer: pluggable routing/recovery policies for the engine.

A `RoutingPolicy` answers the three questions the event core asks:

* `plan()` — which microbatch paths run this iteration;
* `recover(view, mb, frm, dead, t)` — a sender timed out on `dead`:
  what now?  Returns one of the `Decision` shapes below; the engine
  applies the bookkeeping (slot release, wasted-GPU accounting, resend)
  so every policy shares identical, well-tested fault mechanics;
* membership hooks `on_rejoin` / `on_crash` — keep any internal state
  (e.g. the GWTF protocol's flow graph) in sync with churn.

Decisions (plain tuples, matched on the first element):

* `("fail",)` — give up on the microbatch (accounted as wasted GPU);
* `("substitute", node_id, extra_delay)` — splice `node_id` into the
  current path position and resend after `extra_delay` seconds (GWTF's
  backward *pipeline repair* pays one stage-forward recompute here);
* `("restart", path_or_None)` — SWARM's full-pipeline recomputation:
  drop all progress and start over on `path` (fail if None).

Implementations extract the pre-refactor `TrainingSimulator` if/elif
branches verbatim: `GWTFPolicy` (flow-based, `GWTFProtocol` behind the
interface), `SwarmPolicy` (greedy stochastic `SwarmRouter`), and
`FixedPolicy` (preset schedules — the DT-FM baseline of Table VI; it
cannot reroute).
"""
from __future__ import annotations

from typing import Callable, List, Optional, Protocol, Sequence, Tuple

import numpy as np

from repro.core.flow.decentralized import GWTFProtocol
from repro.core.flow.graph import FlowNetwork, Node
from repro.core.swarm import SwarmRouter

Decision = Tuple  # ("fail",) | ("substitute", nid, delay) | ("restart", path)


class FaultView:
    """Read-only window onto the engine's iteration state, handed to
    policies at fault time.

    Exposes the engine's batched per-iteration tables directly (plain
    lists — the fault path scans whole candidate stages, so indexing
    must not pay per-call overhead): node `nid` is alive at time `t`
    iff ``alive[nid] and t < crash[nid]``; its current load is
    ``busy[nid] + len(queues[nid])``; transfer/edge costs from `i` to
    `j` are ``comm_rows[i][j]`` / ``edge_rows[i][j]`` at
    ``activation_bytes``; per-direction compute times are
    ``fwd_t[nid]`` / ``bwd_t[nid]``.  ``stage_nodes(s)`` returns the
    stage's alive-membership list, cached for the iteration (liveness
    within the running iteration is `alive`/`crash`, not membership).
    """
    __slots__ = ("net", "activation_bytes", "alive", "crash", "busy",
                 "queues", "fwd_t", "bwd_t", "comm_rows", "edge_rows",
                 "stage_nodes")

    net: FlowNetwork
    activation_bytes: float
    alive: List[bool]
    crash: List[float]
    busy: List[int]
    queues: list
    fwd_t: List[float]
    bwd_t: List[float]
    comm_rows: List[List[float]]
    edge_rows: List[List[float]]
    stage_nodes: Callable[[int], list]


class RoutingPolicy(Protocol):
    name: str

    def plan(self) -> List[Sequence[int]]:
        """Paths (data_node, stage_0, ..., stage_{S-1}, data_node) to
        launch this iteration."""
        ...

    def recover(self, view: FaultView, mb, frm: int, dead: int,
                t: float) -> Decision:
        ...

    def on_rejoin(self, node: Node) -> None:
        ...

    def on_crash(self, nid: int) -> None:
        ...


def _target_stage(net: FlowNetwork, dead: int) -> int:
    dead_node = net.nodes[dead]
    return dead_node.stage if not dead_node.is_data else net.num_stages


class GWTFPolicy:
    """Flow-based scheduling (paper Sec. V) behind the policy interface.

    Forward fault: Request Flow applied at fault time — cheapest alive
    next-stage node with spare capacity.  Backward fault: *pipeline
    repair* (Sec. V-D) — a substitute recomputes only the dead stage's
    forward from the stored upstream activation before the backward
    resumes; no full-pipeline recompute.

    ``track_optimality=True`` runs the dial `MinCostFlow` oracle next
    to every plan and publishes ``last_cost_ratio`` — (cost of the
    planned flows) / (the oracle's optimal cost for the *same number of
    flows* on the same alive network) — which the engine copies into
    ``IterationMetrics.cost_ratio_vs_optimal``.  Float (geo) cost
    matrices are quantized to integers for the dial core
    (``oracle_quantum``), so the reported ratio carries a bounded
    quantization error of at most one quantum per edge.

    ``throttle_planning()`` is the engine's planning-overrun cap: each
    call halves ``repair_rounds`` (floor 2) so a planner whose wall
    time dwarfs the event loop degrades gracefully instead of
    superlinearly.
    """
    name = "gwtf"

    def __init__(self, net: FlowNetwork, *,
                 rng: Optional[np.random.Generator] = None,
                 warmup_rounds: int = 100, repair_rounds: int = 30,
                 repair_quiet_rounds: int = 2,
                 track_optimality: bool = False,
                 oracle_quantum: float = 1e-3):
        self.net = net
        self.repair_rounds = repair_rounds
        self.repair_quiet_rounds = repair_quiet_rounds
        self.track_optimality = track_optimality
        self.oracle_quantum = oracle_quantum
        self.last_cost_ratio: Optional[float] = None
        self.last_oracle_seconds: float = 0.0
        self.protocol = GWTFProtocol(net, rng=rng)
        self.protocol.run(max_rounds=warmup_rounds)

    def plan(self) -> List[Sequence[int]]:
        # Nodes still dead from previous iterations were removed; run a
        # few repair rounds (Sec. V-A runs in parallel with training).
        self.protocol.reclaim_sink_slots()
        self.protocol.run(max_rounds=self.repair_rounds,
                          quiet_rounds=self.repair_quiet_rounds)
        flows = self.protocol.complete_flows()
        if self.track_optimality:
            self._update_cost_ratio(flows)
        return flows

    def throttle_planning(self) -> int:
        """Engine overrun cap: halve the per-iteration repair budget."""
        self.repair_rounds = max(2, self.repair_rounds // 2)
        return self.repair_rounds

    def _update_cost_ratio(self, flows: List[Sequence[int]]):
        """Dial-oracle optimality gap of this iteration's plan.

        The oracle is restricted to the planned flow *volume* (so a
        partially-repaired plan is compared against the optimal routing
        of the same number of flows, not blamed for flows it could not
        launch), and the cost matrix is quantized to ``oracle_quantum``
        integer steps to keep the O(V + C) dial core applicable to
        float geo costs.  Consumes no protocol RNG.
        """
        import time as _time
        from repro.core.flow.mincost import solve_training_flow
        self.last_cost_ratio = None
        if not flows:
            return
        t0 = _time.perf_counter()
        CM = self.net.cost_matrix()
        q = self.oracle_quantum
        CMq = np.round(CM / q)
        planned = sum(sum(CMq[a][b] for a, b in zip(f, f[1:]))
                      for f in flows)
        try:
            plan_opt = solve_training_flow(
                self.net, cost_matrix=CMq, max_flow=float(len(flows)),
                method="dial")
        except ValueError:
            self.last_oracle_seconds = _time.perf_counter() - t0
            return                      # non-finite costs: oracle N/A
        if plan_opt.cost > 0 and plan_opt.flow >= len(flows):
            self.last_cost_ratio = float(planned) / plan_opt.cost
        self.last_oracle_seconds = _time.perf_counter() - t0

    def _reroute(self, view: FaultView, mb, frm: int, target_stage: int,
                 t: float) -> Optional[int]:
        if target_stage >= self.net.num_stages:
            return mb.data_node
        alive, crash = view.alive, view.crash
        busy, queues = view.busy, view.queues
        erow = view.edge_rows[frm]
        ct = view.bwd_t if mb.direction == "bwd" else view.fwd_t
        best, best_c = None, None
        for n in view.stage_nodes(target_stage):
            j = n.id
            if not (alive[j] and t < crash[j]):
                continue
            load_penalty = max(0, busy[j] + len(queues[j]) - n.capacity + 1)
            c = erow[j]
            c += load_penalty * ct[j]
            if best_c is None or c < best_c:
                best, best_c = j, c
        return best

    def recover(self, view: FaultView, mb, frm: int, dead: int,
                t: float) -> Decision:
        sub = self._reroute(view, mb, frm, _target_stage(self.net, dead), t)
        if sub is None:
            return ("fail",)               # DENY upstream: defer the batch
        delay = view.fwd_t[sub] if mb.direction == "bwd" else 0.0
        return ("substitute", sub, delay)

    def on_rejoin(self, node: Node) -> None:
        self.protocol.add_node(node)

    def on_crash(self, nid: int) -> None:
        self.protocol.remove_node(nid)


class SwarmPolicy:
    """SWARM baseline: greedy stochastic wiring, capacity-blind.

    Forward fault: timeout + resend to a different next-stage node.
    Backward fault: the whole pipeline for that microbatch restarts
    from the data node (the paper's key inefficiency claim).
    """
    name = "swarm"

    def __init__(self, net: FlowNetwork, *,
                 rng: Optional[np.random.Generator] = None):
        self.net = net
        self.router = SwarmRouter(net, stochastic=True, rng=rng)

    def plan(self) -> List[Sequence[int]]:
        paths: List[Sequence[int]] = []
        # one routing context for the whole wave: membership cannot
        # change while a plan is built, so the per-stage candidate
        # tables and the cost matrix are derived once, not per hop
        ctx = self.router.route_context()
        for dn in self.net.data_nodes():
            for _ in range(dn.capacity):
                path = self.router.route(dn.id, ctx=ctx)
                if path is not None:
                    paths.append(path)
        return paths

    def _reroute(self, view: FaultView, mb, frm: int, target_stage: int,
                 t: float, exclude: set) -> Optional[int]:
        if target_stage >= self.net.num_stages:
            return mb.data_node
        alive, crash = view.alive, view.crash
        crow = view.comm_rows[frm]
        # first strict minimum in stage order == np.argmin over the
        # candidate list (first occurrence wins) in the reference loop
        best, best_c = None, None
        for n in view.stage_nodes(target_stage):
            j = n.id
            if not (alive[j] and t < crash[j]) or j in exclude:
                continue
            c = crow[j]
            if best_c is None or c < best_c:
                best, best_c = j, c
        return best

    def recover(self, view: FaultView, mb, frm: int, dead: int,
                t: float) -> Decision:
        if mb.direction == "bwd":
            return ("restart", self.router.route(mb.data_node))
        sub = self._reroute(view, mb, frm, _target_stage(self.net, dead), t,
                            exclude={dead})
        return ("fail",) if sub is None else ("substitute", sub, 0.0)

    def on_rejoin(self, node: Node) -> None:
        pass

    def on_crash(self, nid: int) -> None:
        pass


class FixedPolicy:
    """Preset schedules (DT-FM optimal baseline, Table VI): the same
    paths every iteration, no rerouting — any timed-out leg fails the
    microbatch."""
    name = "fixed"

    def __init__(self, net: FlowNetwork, paths: Sequence[Sequence[int]]):
        self.net = net
        self.paths = [list(p) for p in (paths or [])]

    def plan(self) -> List[Sequence[int]]:
        return [list(p) for p in self.paths]

    def recover(self, view: FaultView, mb, frm: int, dead: int,
                t: float) -> Decision:
        return ("fail",)

    def on_rejoin(self, node: Node) -> None:
        pass

    def on_crash(self, nid: int) -> None:
        pass


def make_policy(scheduler: str, net: FlowNetwork, *,
                rng: Optional[np.random.Generator] = None,
                fixed_paths=None) -> RoutingPolicy:
    """The pre-refactor `scheduler=` string, resolved to a policy."""
    if scheduler == "gwtf":
        return GWTFPolicy(net, rng=rng)
    if scheduler == "swarm":
        return SwarmPolicy(net, rng=rng)
    if scheduler == "fixed":
        return FixedPolicy(net, fixed_paths or [])
    raise ValueError(f"unknown scheduler {scheduler!r} "
                     f"(expected 'gwtf' | 'swarm' | 'fixed')")
