"""Discrete-event simulator of decentralized training (paper Sec. VI).

Compatibility shim: the monolithic simulator that used to live here was
refactored into the layered engine package `repro.core.sim` —

* `repro.core.sim.engine`   — fast event core (`SimulationEngine`);
* `repro.core.sim.policies` — pluggable schedulers (`RoutingPolicy`:
  GWTF / SWARM / fixed);
* `repro.core.sim.faults`   — composable churn models (Bernoulli,
  trace replay, correlated regional outages);
* `repro.core.sim.metrics`  — Table II/III columns + engine series;
* `repro.core.sim.facade`   — the `TrainingSimulator` wrapper re-exported
  below, drop-in for the pre-refactor class (seeded GWTF/fixed runs are
  RNG-stream and metric identical; SWARM differs only by the
  backward-restart slot-leak fix).

Import from `repro.core.sim` in new code; this module stays for the
existing callers (tests, benchmarks, examples).
"""
from repro.core.sim import (BernoulliChurn, ComposedChurn,
                            CorruptGradientChurn, FaultTimeline,
                            FlakyLinkChurn, IterationMetrics,
                            LinkDegradationChurn, ModelProfile,
                            RegionalOutageChurn, SimulationEngine,
                            StragglerChurn, TraceChurn, TrainingSimulator,
                            summarize)

__all__ = [
    "TrainingSimulator", "SimulationEngine", "ModelProfile",
    "IterationMetrics", "BernoulliChurn", "TraceChurn",
    "RegionalOutageChurn", "ComposedChurn", "LinkDegradationChurn",
    "StragglerChurn", "CorruptGradientChurn", "FlakyLinkChurn",
    "FaultTimeline", "summarize",
]
