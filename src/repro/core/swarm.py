"""SWARM parallelism baseline (Ryabinin et al., ICML'23) — as characterised
in the GWTF paper:

* stochastic greedy wiring: each node independently forwards a microbatch
  to the closest (lowest comm-cost) *responsive* node of the next stage —
  no flow construction, no capacity planning;
* assumes homogeneous memory: nodes are considered available regardless of
  their real capacity, so heterogeneous nodes over-commit and queue;
* forward-pass crash: timeout + resend to a different next-stage node;
* backward-pass crash: the WHOLE pipeline for that microbatch is
  recomputed from the data node (the paper's key inefficiency claim).
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core.flow.graph import FlowNetwork


class SwarmRouter:
    """Greedy next-stage selection with optional stochastic tie-breaking."""

    def __init__(self, net: FlowNetwork, *,
                 cost_matrix: Optional[np.ndarray] = None,
                 stochastic: bool = False,
                 rng: Optional[np.random.Generator] = None):
        self.net = net
        self.cost_matrix = cost_matrix
        self.stochastic = stochastic
        self.rng = rng or np.random.default_rng(0)

    def d(self, i: int, j: int) -> float:
        if self.cost_matrix is not None:
            return float(self.cost_matrix[i, j])
        return self.net.edge_cost(i, j)

    def next_hop(self, current: int, next_stage: int, data_node: int,
                 exclude: Optional[set] = None) -> Optional[int]:
        """Greedy: closest alive node of the next stage (or the data node
        when the pipeline is done).  ``exclude`` = peers already timed out."""
        exclude = exclude or set()
        if next_stage >= self.net.num_stages:
            return data_node if self.net.nodes[data_node].alive else None
        cands = [n.id for n in self.net.stage_nodes(next_stage)
                 if n.id not in exclude]
        if not cands:
            return None
        costs = np.array([self.d(current, j) for j in cands])
        if self.stochastic:
            # SWARM prioritises faster peers stochastically
            w = 1.0 / np.maximum(costs, 1e-9)
            w = w / w.sum()
            return int(self.rng.choice(cands, p=w))
        return int(cands[int(np.argmin(costs))])

    def route(self, data_node: int) -> Optional[List[int]]:
        """A full greedy path for one microbatch (no capacity checks)."""
        path = [data_node]
        cur = data_node
        for s in range(self.net.num_stages):
            nxt = self.next_hop(cur, s, data_node)
            if nxt is None:
                return None
            path.append(nxt)
            cur = nxt
        path.append(data_node)
        return path

    def route_with_capacity(self, data_node: int, used: dict
                            ) -> Optional[List[int]]:
        """Greedy path that only uses nodes with remaining capacity
        (``used`` is a shared node_id -> consumed-slots dict).  This is
        the *feasible* SWARM baseline of Fig. 7 — a schedule that
        over-commits capacity is not executable."""
        path = [data_node]
        cur = data_node
        for s in range(self.net.num_stages):
            full = {nid for nid, u in used.items()
                    if u >= self.net.nodes[nid].capacity}
            nxt = self.next_hop(cur, s, data_node, exclude=full)
            if nxt is None:
                return None
            path.append(nxt)
            used[nxt] = used.get(nxt, 0) + 1
            cur = nxt
        path.append(data_node)
        return path
