"""SWARM parallelism baseline (Ryabinin et al., ICML'23) — as characterised
in the GWTF paper:

* stochastic greedy wiring: each node independently forwards a microbatch
  to the closest (lowest comm-cost) *responsive* node of the next stage —
  no flow construction, no capacity planning;
* assumes homogeneous memory: nodes are considered available regardless of
  their real capacity, so heterogeneous nodes over-commit and queue;
* forward-pass crash: timeout + resend to a different next-stage node;
* backward-pass crash: the WHOLE pipeline for that microbatch is
  recomputed from the data node (the paper's key inefficiency claim).
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core.flow.graph import FlowNetwork


class SwarmRouter:
    """Greedy next-stage selection with optional stochastic tie-breaking.

    The per-hop cost scan is batched: candidate costs come from one row
    gather of the cached dense Eq. 1 matrix instead of a per-pair
    ``d()`` call per candidate, and a *routing context* (per-stage alive
    membership snapshot + the matrix) can be shared across every path
    of a planning wave — membership cannot change while a plan is being
    built, so ``SwarmPolicy.plan`` derives it once instead of re-scanning
    the node table per hop.  Results (and the RNG stream of stochastic
    tie-breaking) are identical to the scalar scan's.
    """

    def __init__(self, net: FlowNetwork, *,
                 cost_matrix: Optional[np.ndarray] = None,
                 stochastic: bool = False,
                 rng: Optional[np.random.Generator] = None):
        self.net = net
        self.cost_matrix = cost_matrix
        self.stochastic = stochastic
        self.rng = rng or np.random.default_rng(0)
        self._cm: Optional[np.ndarray] = None

    def d(self, i: int, j: int) -> float:
        if self.cost_matrix is not None:
            return float(self.cost_matrix[i, j])
        return self.net.edge_cost(i, j)

    def _matrix(self) -> np.ndarray:
        if self.cost_matrix is not None:
            if self._cm is None:
                self._cm = np.asarray(self.cost_matrix, np.float64)
            return self._cm
        return self.net.cost_matrix()    # cached by the network

    def route_context(self) -> tuple:
        """Snapshot (cost matrix, per-stage alive candidate ids) for one
        planning wave."""
        return (self._matrix(),
                [[n.id for n in self.net.stage_nodes(s)]
                 for s in range(self.net.num_stages)])

    def next_hop(self, current: int, next_stage: int, data_node: int,
                 exclude: Optional[set] = None,
                 ctx: Optional[tuple] = None) -> Optional[int]:
        """Greedy: closest alive node of the next stage (or the data node
        when the pipeline is done).  ``exclude`` = peers already timed out."""
        if next_stage >= self.net.num_stages:
            return data_node if self.net.nodes[data_node].alive else None
        if ctx is not None:
            cands = ctx[1][next_stage]
            cm = ctx[0]
        else:
            cands = [n.id for n in self.net.stage_nodes(next_stage)]
            cm = self._matrix()
        if exclude:
            cands = [j for j in cands if j not in exclude]
        if not cands:
            return None
        costs = cm[current][cands]
        if self.stochastic:
            # SWARM prioritises faster peers stochastically
            w = 1.0 / np.maximum(costs, 1e-9)
            w = w / w.sum()
            return int(self.rng.choice(cands, p=w))
        return int(cands[int(np.argmin(costs))])

    def route(self, data_node: int,
              ctx: Optional[tuple] = None) -> Optional[List[int]]:
        """A full greedy path for one microbatch (no capacity checks)."""
        if ctx is None:
            ctx = self.route_context()
        path = [data_node]
        cur = data_node
        for s in range(self.net.num_stages):
            nxt = self.next_hop(cur, s, data_node, ctx=ctx)
            if nxt is None:
                return None
            path.append(nxt)
            cur = nxt
        path.append(data_node)
        return path

    def route_with_capacity(self, data_node: int, used: dict,
                            ctx: Optional[tuple] = None
                            ) -> Optional[List[int]]:
        """Greedy path that only uses nodes with remaining capacity
        (``used`` is a shared node_id -> consumed-slots dict).  This is
        the *feasible* SWARM baseline of Fig. 7 — a schedule that
        over-commits capacity is not executable."""
        if ctx is None:
            ctx = self.route_context()
        path = [data_node]
        cur = data_node
        for s in range(self.net.num_stages):
            full = {nid for nid, u in used.items()
                    if u >= self.net.nodes[nid].capacity}
            nxt = self.next_hop(cur, s, data_node, exclude=full, ctx=ctx)
            if nxt is None:
                return None
            path.append(nxt)
            used[nxt] = used.get(nxt, 0) + 1
            cur = nxt
        path.append(data_node)
        return path
