"""Training loop over the staged runtime: aggregation, AdamW, checkpoints.

`RuntimeTrainer` wires the layers together into the paper's iteration
(Sec. V-E):

1. the fault layer samples crashes/rejoins (`ChurnModel` through the
   same `ChurnContext` the simulator uses; rejoining nodes bootstrap by
   downloading their stage snapshot via ``checkpoint.store.restore_stage``
   when a checkpoint directory is configured);
2. the routing policy plans this iteration's complete-flow chains and
   microbatches are assigned to them;
3. `RecoveryManager` resolves every mid-iteration crash against the
   policy (stage-local substitute, requeue onto another chain, or
   drop);
4. the numeric pass executes the completed microbatches through
   `StageCompute` in **depth-first dispatch chunks**: each chunk of up
   to `dispatch_chunk` stacked microbatches runs embed → fused
   per-stage forwards (capturing VJP residuals in the
   `ActivationStore`) → loss head → per-stage backwards consuming the
   stored residuals — so the backward never recomputes the forward and
   a stage's residuals are freed as soon as its chunk's backward used
   them (peak residency ~ one chunk per stage).  Each recorded crash
   additionally dispatches the dead replica's lost work (via
   `RecoveryManager.replay_lost`, from stored residuals where
   available), so recovery cost is real wall time, not bookkeeping.
   ``remat=True`` switches the backward to the rematerialising oracle
   path (same compiled programs, composed — bit-identical gradients,
   no residual storage); ``activation_codec="int8"`` quantises the
   store at a bounded fidelity cost;
5. per-stage gradients are averaged over completed microbatches and
   applied with a jitted AdamW update (identical on every replica, so
   replicas stay bit-identical), and stage snapshots are written to
   ``checkpoint.store`` every ``checkpoint_every`` iterations.

`CentralizedTrainer` (the Fig. 6 baseline) lives here too and runs the
*same* chunked pass (`_chunk_pass`) over the same cached kernels, so
at churn 0 the decentralized trainer executes bit-for-bit the
identical float program; the ``repro.core.executor`` facade re-exports
both.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store as ckpt
from repro.core.flow.graph import FlowNetwork, Node
from repro.core.runtime import cache
from repro.core.runtime.activations import ActivationStore, make_codec
from repro.core.runtime.recovery import Job, RecoveryManager, Resolution
from repro.core.runtime.stages import StageCompute
from repro.core.sim.faults import (BernoulliChurn, ChurnContext, ChurnModel,
                                   adversarial_plan)
from repro.core.sim.policies import GWTFPolicy, RoutingPolicy
from repro.core.sim.timeline import FaultTimeline, record_injections
from repro.optim.adamw import AdamW

# Depth-first dispatch chunking: stack at most this many microbatches
# per stage dispatch, shrinking toward 1 when a single microbatch's
# boundary activation exceeds the byte target.  Tuned on the 1-core CI
# host where small chunks keep residuals cache-hot between a stage's
# forward and its backward; multi-core hosts may prefer larger chunks
# via the ``dispatch_chunk`` kwarg.  Both trainers share this rule —
# chunking changes gradient-accumulation association, so bit-identity
# requires identical chunk boundaries.
_CHUNK_TARGET_BYTES = 256 * 1024
_CHUNK_MAX_MB = 4


def auto_chunk(n_mb: int, per: int, seq: int, d_model: int,
               itemsize: int = 4) -> int:
    """Microbatches per dispatch chunk (deterministic, shared by both
    trainers)."""
    mb_bytes = max(1, per * seq * d_model * itemsize)
    return max(1, min(_CHUNK_MAX_MB, n_mb,
                      _CHUNK_TARGET_BYTES // mb_bytes))


class _WireLink:
    """Per-boundary wire codecs for inter-stage chunk transfers.

    ``send(s, x)`` encodes + decodes the boundary activation leaving
    stage ``s`` with the codec the planner chose for that boundary's
    link (encode → wire → decode; the receiving stage computes on the
    decoded tensor, so compression fidelity costs are *real* in the
    loss, not simulated).  Cotangents stay exact: crash replay consumes
    stored residuals, and compressing the backward would double-charge
    the fidelity budget the planner priced for one crossing.
    ``bytes`` accumulates the encoded (on-wire) payload size.
    """

    def __init__(self, names: List[str]):
        self.names = list(names)
        self._codecs = [make_codec(n) for n in self.names]
        self.bytes = 0

    def send(self, boundary: int, x):
        codec = self._codecs[boundary]
        enc = codec.encode(x)
        self.bytes += int(codec.nbytes(enc))
        return codec.decode(enc)


def _chunk_pass(stages: StageCompute, store: ActivationStore,
                stage_params: List[Any], head_params, toks, labels,
                ids: Tuple[int, ...], per: int, *, remat: bool,
                grad_stage: List[Any],
                replay: Optional[Callable] = None,
                wire: Optional[_WireLink] = None) -> Tuple[float, Any]:
    """One depth-first chunk: embed → per-stage forward (fused residual
    capture unless ``remat``) → loss head → per-stage backward from
    stored residuals (or remat oracle) → embedding pull-back.

    Shared verbatim by `RuntimeTrainer` and `CentralizedTrainer`: at
    churn 0 (``replay=None``) both execute exactly this program, which
    is what makes the bit-identity invariant hold by construction.
    ``wire`` (when set) compresses each inter-stage boundary transfer
    with that boundary's planner-chosen codec — callers pass ``None``
    (not a no-op wire) for fp32 so the bit-identity path stays
    untouched.  Accumulates per-stage gradients into ``grad_stage`` in
    place; returns ``(loss_sum, g_head)`` with the embedding share
    included.
    """
    S = len(stage_params)
    x = stages.embed(head_params, toks)
    for s in range(S):
        store.put(s, ids, x)
        if remat:
            x = stages.forward(s, stage_params[s], x)
        else:
            x, resid = stages.forward_fused(s, stage_params[s], x)
            store.put_residuals(s, ids, resid)
        if replay is not None:
            replay(s, "fwd", ids)
        if wire is not None and s < S - 1:
            x = wire.send(s, x)
    B = len(ids)
    seq, D = x.shape[1], x.shape[-1]
    h = x.reshape(B, per, seq, D)
    losses, g_head, g_hidden = stages.head_loss(head_params, h, labels)
    g = g_hidden.reshape(B * per, seq, D)
    for s in reversed(range(S)):
        if replay is not None:
            replay(s, "bwd", ids, g, per)
        if remat:
            xin = store.stacked(s, ids)
            dp, dx = stages.backward(s, stage_params[s], xin, g)
        else:
            dp, dx = stages.backward_from_residuals(
                s, store.residuals(s, ids), g)
        grad_stage[s] = (dp if grad_stage[s] is None else
                        jax.tree.map(jnp.add, grad_stage[s], dp))
        g = dx
        store.drop(s, ids)
    g_emb = stages.embed_backward(head_params, toks, g)
    return float(jnp.sum(losses)), jax.tree.map(jnp.add, g_head, g_emb)


@dataclass
class IterationResult:
    loss: float
    completed: int
    launched: int
    dropped: int
    rerouted: int = 0             # crash repairs that saved the microbatch
    requeued: int = 0             # subset of rerouted: moved to another chain
    fwd_recomputes: int = 0       # stage-local forward recomputes (Sec. V-D)
    bwd_replays: int = 0          # stage-local VJP replays (Sec. V-D)
    store_peak_bytes: int = 0     # high-water resident activation+residual
                                  # bytes (encoded) during the numeric pass
    wire_bytes: int = 0           # encoded bytes sent over inter-stage
                                  # boundaries (0 when the wire is fp32)
    wire_codecs: Tuple[str, ...] = ()   # applied codec per stage boundary
                                  # (empty when the wire is fp32/off)
    deadline_requeues: int = 0    # subset of rerouted: re-dispatches
                                  # fired by the sender's deadline on a
                                  # hung/straggling (alive) relay
    grads_flagged: int = 0        # contributions the gradient screen
                                  # excluded from this update (the jobs
                                  # still count as completed)


class RuntimeTrainer:
    """GWTF training with real JAX compute over the staged runtime."""

    def __init__(self, cfg, net: FlowNetwork, *,
                 churn: float = 0.0, lr: float = 1e-3, seed: int = 0,
                 rng: Optional[np.random.Generator] = None,
                 policy: Optional[RoutingPolicy] = None,
                 churn_model: Optional[ChurnModel] = None,
                 batch_microbatches: bool = True,
                 max_retries: int = 2,
                 timeout: float = 30.0,
                 deadline_defense: bool = True,
                 grad_screen: Optional[bool] = None,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 0,
                 record_microbatch_grads: bool = False,
                 remat: bool = False,
                 activation_codec: str = "fp",
                 wire_codec: Optional[str] = None,
                 dispatch_chunk: Optional[int] = None,
                 donate: Optional[bool] = None):
        self.cfg = cfg
        self.net = net
        self.rng = rng or np.random.default_rng(seed)
        self.policy = policy or GWTFPolicy(net, rng=self.rng)
        self.churn_model = churn_model or BernoulliChurn(churn)
        self.batch_microbatches = batch_microbatches
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        self.record_microbatch_grads = record_microbatch_grads
        self.remat = remat
        # wire_codec: None/"fp"/"fp32" leaves boundary transfers exact;
        # "planner" applies, per stage boundary, the codec the network's
        # menu chose for that boundary's planned links; any codec name
        # ("bf16"/"int8"/"top-k") forces it on every boundary.
        self.wire_codec = wire_codec
        self.dispatch_chunk = dispatch_chunk

        # defenses against beyond-fail-stop faults: the sender-side
        # deadline (hung/straggling relays are requeued, mirroring the
        # sim engine) and the gradient screen (norm/cosine outlier test
        # over per-microbatch contributions before aggregation).
        # grad_screen=None auto-enables the screen exactly when the
        # churn model injects corrupt gradients; False is the
        # undefended baseline the adversarial benchmarks compare.
        self.grad_screen = grad_screen
        self.timeline = FaultTimeline()

        self.stages = StageCompute(cfg, net.num_stages, donate=donate)
        self.store = ActivationStore(codec=activation_codec)
        self.recovery = RecoveryManager(net, self.policy,
                                        max_retries=max_retries,
                                        timeout=timeout,
                                        deadline_defense=deadline_defense)

        S = net.num_stages
        # identical replicas per stage (paper: joining nodes download the
        # stage weights) -> ONE canonical copy per stage; replicas share
        # it because aggregation keeps them identical.  Initial trees
        # come from the process-wide cache (immutable, replaced on
        # update, so sharing across trainers cannot leak state).
        stage_p, head_p = cache.initial_params(cfg, S, seed)
        self.stage_params = list(stage_p)
        self.head_params = {d.id: head_p for d in net.data_nodes()}
        self.opt = AdamW(lr=lr)
        self.stage_opt = [self.opt.init(p) for p in self.stage_params]
        self.head_opt = {d: self.opt.init(p)
                         for d, p in self.head_params.items()}
        self._upd = jax.jit(lambda g, s, p: self.opt.update(g, s, p))

        self.losses: List[float] = []
        self.step = 0
        self.joins_bootstrapped = 0
        self.last_microbatch_grads: List[Tuple[int, Any, Any]] = []
        # introspection for tests/examples: the most recent iteration's
        # planned chains, crash resolution, and store high-water mark
        self.last_chains: List[List[int]] = []
        self.last_resolution: Optional[Resolution] = None
        self.last_store_peak_bytes = 0
        self.last_wire_codecs: List[str] = []
        self.last_wire_bytes = 0

    # ------------------------------------------------------------------
    @property
    def protocol(self):
        """The GWTF protocol behind the routing policy, when there is
        one (pre-refactor compat accessor; ``None`` for policies that
        are not flow-based)."""
        return getattr(self.policy, "protocol", None)

    # ------------------------------------------------------------------
    # Fault-layer hooks
    # ------------------------------------------------------------------
    def _on_rejoin(self, node: Node) -> None:
        """Sec. V-E join path: the rejoining replica downloads its
        stage's latest snapshot before re-entering the flow graph.
        The restored tree is discarded afterwards because replicas
        share one canonical copy (the aggregation invariant keeps them
        bit-identical); the download itself — and its validation
        against the live stage structure — is the exercised path."""
        if (self.checkpoint_dir and node.stage >= 0
                and os.path.exists(os.path.join(
                    self.checkpoint_dir, f"stage_{node.stage:03d}.npz"))):
            ckpt.restore_stage(self.checkpoint_dir, node.stage,
                               {"params": self.stage_params[node.stage],
                                "opt": self.stage_opt[node.stage]})
            self.joins_bootstrapped += 1
        self.policy.on_rejoin(node)

    # ------------------------------------------------------------------
    # Checkpoint plumbing
    # ------------------------------------------------------------------
    def save_checkpoint(self, dirpath: Optional[str] = None) -> str:
        """Per-stage snapshots (params + AdamW state) plus the data-node
        heads; the unit a joining node downloads (paper Sec. V-E)."""
        d = dirpath or self.checkpoint_dir
        if not d:
            raise ValueError("no checkpoint directory configured")
        for s, (p, o) in enumerate(zip(self.stage_params, self.stage_opt)):
            ckpt.save_stage(d, s, {"params": p, "opt": o}, step=self.step)
        for dn, p in self.head_params.items():
            ckpt.save(os.path.join(d, f"head_{dn:03d}.npz"),
                      {"params": p, "opt": self.head_opt[dn]},
                      step=self.step)
        return d

    def restore_checkpoint(self, dirpath: Optional[str] = None) -> int:
        """Resume every stage + head from snapshots; returns the step."""
        d = dirpath or self.checkpoint_dir
        if not d:
            raise ValueError("no checkpoint directory configured")
        step = 0
        for s in range(self.net.num_stages):
            tree, step = ckpt.restore_stage(
                d, s, {"params": self.stage_params[s],
                       "opt": self.stage_opt[s]})
            self.stage_params[s] = tree["params"]
            self.stage_opt[s] = tree["opt"]
        for dn in self.head_params:
            tree, step = ckpt.restore(
                os.path.join(d, f"head_{dn:03d}.npz"),
                {"params": self.head_params[dn], "opt": self.head_opt[dn]})
            self.head_params[dn] = tree["params"]
            self.head_opt[dn] = tree["opt"]
        self.step = step
        return step

    # ------------------------------------------------------------------
    # Wire codec (planner-chosen per-boundary compression)
    # ------------------------------------------------------------------
    def _make_wire(self, chains: List[List[int]]) -> Optional[_WireLink]:
        """Resolve this iteration's per-boundary wire codecs.

        ``"planner"`` mode reads the network's codec-choice matrix at
        the hop each planned chain crosses between stages ``s`` and
        ``s+1`` and applies the modal choice per boundary (chunks stack
        microbatches from several chains, so one codec per boundary;
        ties resolve to the earlier menu entry).  Returns ``None`` when
        every boundary resolves to fp32 — the exact path must not even
        construct a wire, so bit-identity survives by construction.
        """
        spec = self.wire_codec
        if spec is None or spec in ("fp", "fp32"):
            return None
        S = self.net.num_stages
        if S < 2:
            return None
        if spec != "planner":
            return _WireLink([spec] * (S - 1))
        menu = self.net.wire_codec_names()
        if len(menu) <= 1:
            return None
        choice = self.net.wire_codec_matrix()
        names = []
        for s in range(S - 1):
            votes: Dict[int, int] = {}
            for chain in chains:
                k = int(choice[chain[s + 1], chain[s + 2]])
                votes[k] = votes.get(k, 0) + 1
            best = (min(votes, key=lambda k: (-votes[k], k))
                    if votes else 0)
            names.append(menu[best])
        if all(n == "fp32" for n in names):
            return None
        return _WireLink(names)

    # ------------------------------------------------------------------
    # One training iteration
    # ------------------------------------------------------------------
    def iteration(self, batches_per_data_node: Dict[int, List[dict]]
                  ) -> IterationResult:
        horizon = 1.0                    # normalized pipeline-flush clock
        it = self.step
        crash_times = self.churn_model.sample(ChurnContext(
            net=self.net, rng=self.rng, horizon=horizon,
            iteration=it, on_rejoin=self._on_rejoin))
        # adversarial side channel — None for plain fail-stop models,
        # keeping every defended branch below inert.  Injections are
        # recorded from the same model outputs the simulator records
        # from, which is what makes the two layers' timelines
        # injection-count identical by construction.
        adv = adversarial_plan(self.churn_model, it)
        record_injections(self.timeline, it, crash_times, adv)

        chains = [list(c) for c in self.policy.plan()]
        jobs: List[Job] = []
        per_dn: Dict[int, int] = {}
        for chain in chains:
            dn = chain[0]
            avail = batches_per_data_node.get(dn, [])
            k = per_dn.get(dn, 0)
            if k < len(avail):
                jobs.append(Job(index=len(jobs), data_node=dn,
                                mb=avail[k], chain=list(chain)))
                per_dn[dn] = k + 1
        launched = len(jobs)

        res = self.recovery.resolve(jobs, chains, crash_times, horizon,
                                    adv=adv, timeline=self.timeline,
                                    iteration=it)
        self.last_chains = chains
        self.last_resolution = res

        # corrupt-gradient injection: per completed job, the stages
        # whose relay the adversarial plan corrupts (on the job's
        # *final* chain, after any reroutes)
        corrupt = adv.corrupt if adv is not None else {}
        corrupt_stages: Dict[int, Dict[int, Tuple]] = {}
        if corrupt:
            S = self.net.num_stages
            for job in res.completed:
                hit = {s: corrupt[job.chain[s + 1]] + (job.chain[s + 1],)
                       for s in range(S) if job.chain[s + 1] in corrupt}
                if hit:
                    corrupt_stages[job.index] = hit
        self._corrupt_stages = corrupt_stages
        self._screen = (self.grad_screen if self.grad_screen is not None
                        else bool(corrupt))
        self._grads_flagged = 0

        wire = self._make_wire(chains)
        self.last_wire_codecs = list(wire.names) if wire is not None else []
        mean_loss = self._execute(res, wire)
        self.last_wire_bytes = wire.bytes if wire is not None else 0

        # ---- commit crashes for the next iteration --------------------
        for nid in crash_times:
            self.net.kill_node(nid)
            self.policy.on_crash(nid)

        # ---- reputation: decay first (rehabilitation), then charge
        # this iteration's detections (fresh faults carry the full
        # quarantine penalty into the next plan).  Same ordering as the
        # sim engine; both no-op bit-identically on clean runs.
        if res.rep_reports or self.net.reputation_active():
            self.net.decay_reputations()
            for r_nid in res.rep_reports:
                self.net.report_fault(r_nid)

        self.step += 1
        if (self.checkpoint_dir and self.checkpoint_every
                and self.step % self.checkpoint_every == 0):
            self.save_checkpoint()

        self.losses.append(mean_loss)
        return IterationResult(
            loss=mean_loss, completed=len(res.completed), launched=launched,
            dropped=res.dropped, rerouted=res.rerouted,
            requeued=res.requeued, fwd_recomputes=res.fwd_recomputes,
            bwd_replays=res.bwd_replays,
            store_peak_bytes=self.last_store_peak_bytes,
            wire_bytes=self.last_wire_bytes,
            wire_codecs=tuple(self.last_wire_codecs),
            deadline_requeues=res.deadline_requeues,
            grads_flagged=self._grads_flagged)

    # ------------------------------------------------------------------
    # Numeric pass
    # ------------------------------------------------------------------
    def _execute(self, res: Resolution,
                 wire: Optional[_WireLink] = None) -> float:
        """Run the completed microbatches through the staged compute and
        apply the aggregated update; dispatch each recorded crash's
        lost work so recovery cost is real."""
        done = res.completed
        self.store.clear()
        self.store.reset_peak()
        self.last_store_peak_bytes = 0
        if not done:
            return 0.0
        self.last_microbatch_grads = []
        # corrupt gradients (or an explicitly requested screen) force
        # the per-microbatch path: the perturbation is per-job and the
        # screen needs per-job contributions before aggregation
        adversarial = (bool(getattr(self, "_corrupt_stages", None))
                       or getattr(self, "_screen", False))
        if self.batch_microbatches and not adversarial:
            total = self._execute_batched(done, res, wire)
        else:
            total = self._execute_per_microbatch(done, res, wire)
        self.last_store_peak_bytes = self.store.peak_bytes
        self.store.clear()
        return total / len(done)

    def _group_by_dn(self, done: List[Job]) -> Dict[int, List[int]]:
        by_dn: Dict[int, List[int]] = {}
        for k, job in enumerate(done):
            by_dn.setdefault(job.data_node, []).append(k)
        return by_dn

    def _chunk_size(self, n_mb: int, per: int, seq: int) -> int:
        if self.dispatch_chunk is not None:
            return max(1, min(self.dispatch_chunk, n_mb))
        itemsize = jnp.dtype(self.cfg.param_dtype).itemsize
        return auto_chunk(n_mb, per, seq, self.cfg.d_model, itemsize)

    def _execute_batched(self, done: List[Job], res: Resolution,
                         wire: Optional[_WireLink] = None) -> float:
        by_dn = self._group_by_dn(done)
        per = np.asarray(done[0].mb["tokens"]).shape[0]
        seq = np.asarray(done[0].mb["tokens"]).shape[1]
        S = self.net.num_stages
        total = 0.0
        grad_stage: List[Any] = [None] * S
        g_head_by_dn: Dict[int, Any] = {}

        def replay(s, direction, ids, cotangent=None, p=0):
            self.recovery.replay_lost(
                self.stages, self.store, self.stage_params, res,
                s, direction, ids=ids, cotangent=cotangent, per=p,
                remat=self.remat)

        for dn, idxs in by_dn.items():
            C = self._chunk_size(len(idxs), per, seq)
            head_p = self.head_params[dn]
            g_head = None
            for lo in range(0, len(idxs), C):
                jobs = [done[k] for k in idxs[lo:lo + C]]
                ids = tuple(j.index for j in jobs)
                toks = jnp.asarray(np.concatenate(
                    [np.asarray(j.mb["tokens"]) for j in jobs]))
                labels = jnp.asarray(np.stack(
                    [np.asarray(j.mb["labels"]) for j in jobs]))
                loss_sum, gh = _chunk_pass(
                    self.stages, self.store, self.stage_params, head_p,
                    toks, labels, ids, per, remat=self.remat,
                    grad_stage=grad_stage, replay=replay, wire=wire)
                total += loss_sum
                g_head = (gh if g_head is None else
                          jax.tree.map(jnp.add, g_head, gh))
            g_head_by_dn[dn] = (g_head, len(idxs))
        self._apply_update(grad_stage, g_head_by_dn, len(done))
        return total

    # -- corrupt-gradient adversary + screen ---------------------------
    def _perturb_tree(self, tree, mode: str, scale: float, seed: int,
                      job: int, stage: int):
        """Apply one corrupt node's backward perturbation to a gradient
        tree.  Deterministic: the noise stream is keyed on
        (seed, iteration, job, stage), so seeded adversarial runs
        reproduce bit-for-bit."""
        if mode == "sign_flip":
            return jax.tree.map(jnp.negative, tree)
        if mode == "zero":
            return jax.tree.map(jnp.zeros_like, tree)
        rng = np.random.default_rng([seed, self.step, job, stage])
        return jax.tree.map(
            lambda a: a + scale * jnp.asarray(
                rng.standard_normal(a.shape), dtype=a.dtype), tree)

    @staticmethod
    def _flatten_grads(tree) -> np.ndarray:
        leaves = [np.asarray(x, dtype=np.float64).ravel()
                  for x in jax.tree.leaves(tree)]
        return (np.concatenate(leaves) if leaves
                else np.zeros(1, dtype=np.float64))

    def _screen_contribs(self, contribs) -> set:
        """The cheap gradient screen: flag per-microbatch contributions
        whose per-stage gradient is a norm outlier (>8x or <1/8 the
        median) or anti-correlated with the other contributions at the
        same stage (cosine < -0.1 vs the leave-one-out mean).  A
        sign-flipped backward is ~-1 cosine at (and below) the corrupt
        stage; a zeroed one fails the norm floor; large perturbations
        fail the norm ceiling.  Returns flagged indices into
        ``contribs``.

        The reference norm is the *lower* median (element ``(k-1)//2``
        of the sorted norms), not the interpolated one: with exactly
        half the contributions inflated, the interpolated median
        averages an honest and a poisoned norm and both tests go
        blind, while the lower median stays an honest value for any
        contamination strictly below half."""
        S = self.net.num_stages
        k = len(contribs)
        flagged: set = set()
        for s in range(S):
            vecs = [self._flatten_grads(gs[s]) for _, _, gs in contribs]
            norms = np.array([float(np.linalg.norm(v)) for v in vecs])
            med = float(np.sort(norms)[(k - 1) // 2])
            if med > 0.0:
                for i in range(k):
                    if norms[i] > 8.0 * med or norms[i] < med / 8.0:
                        flagged.add(i)
            if k >= 3:
                total = np.sum(vecs, axis=0)
                for i in range(k):
                    others = total - vecs[i]
                    no = float(np.linalg.norm(others))
                    if norms[i] > 0.0 and no > 0.0:
                        cos = float(np.dot(vecs[i], others)
                                    / (norms[i] * no))
                        if cos < -0.1:
                            flagged.add(i)
        return flagged

    def _execute_per_microbatch(self, done: List[Job], res: Resolution,
                                wire: Optional[_WireLink] = None) -> float:
        """Unbatched path: every microbatch runs its own per-stage
        dispatches and gradients are accumulated with ``jnp.add`` —
        the dispatch order (and float association) of the centralized
        baseline, used by the numerical-equivalence tests.

        When the churn model injects corrupt gradients this path also
        hosts the adversary and its defense: each corrupt relay on a
        job's final chain perturbs that stage's backward outputs
        (``dp``/``dx`` — the poison propagates to earlier stages
        through the cotangent, as it would in a real pipeline), and the
        gradient screen then excludes flagged contributions *before*
        the AdamW aggregation (``grads_flagged``; flagged jobs still
        count as completed — delivery succeeded, trust didn't)."""
        S = self.net.num_stages
        corrupt_stages = getattr(self, "_corrupt_stages", None) or {}
        screening = getattr(self, "_screen", False)
        collect = bool(corrupt_stages) or screening
        contribs: List[Tuple[Job, Any, List[Any]]] = []
        total = 0.0
        grad_stage: List[Any] = [None] * S
        g_head_by_dn: Dict[int, Any] = {}
        # crash events per (job, stage, direction): each costs one real
        # lost-work dispatch, issued inline where the inputs are in hand
        lost: Dict[Tuple[int, int, str], int] = {}
        for ev in res.events:
            key = (ev.job, ev.stage, ev.direction)
            lost[key] = lost.get(key, 0) + 1
        for job in done:
            toks = jnp.asarray(job.mb["tokens"])
            labels = jnp.asarray(job.mb["labels"])[None]
            ids = (job.index,)
            x = self.stages.embed(self.head_params[job.data_node], toks)
            for s in range(S):
                self.store.put(s, ids, x)
                for _ in range(lost.get((job.index, s, "fwd"), 0)):
                    self.stages.forward(s, self.stage_params[s], x)
                if self.remat:
                    x = self.stages.forward(s, self.stage_params[s], x)
                else:
                    x, resid = self.stages.forward_fused(
                        s, self.stage_params[s], x)
                    self.store.put_residuals(s, ids, resid)
                if wire is not None and s < S - 1:
                    x = wire.send(s, x)
            losses, g_head, g_hidden = self.stages.head_loss(
                self.head_params[job.data_node], x[None], labels)
            total += float(losses[0])
            g = g_hidden[0]
            g_stages: List[Any] = [None] * S
            for s in reversed(range(S)):
                for _ in range(lost.get((job.index, s, "bwd"), 0)):
                    # copied cotangent: the backward dispatch donates
                    # its cotangent buffer on donating backends and g
                    # is reused by the real dispatch below
                    if not self.remat and self.store.has_residuals(s, ids):
                        self.stages.backward_from_residuals(
                            s, self.store.residuals(s, ids), jnp.copy(g))
                    else:
                        self.stages.backward(
                            s, self.stage_params[s],
                            self.store.get(s, job.index), jnp.copy(g))
                if self.remat:
                    dp, dx = self.stages.backward(
                        s, self.stage_params[s],
                        self.store.get(s, job.index), g)
                else:
                    dp, dx = self.stages.backward_from_residuals(
                        s, self.store.residuals(s, ids), g)
                hit = corrupt_stages.get(job.index)
                if hit is not None and s in hit:
                    # the corrupt relay at this stage perturbs the
                    # backward results it computed; the poisoned
                    # cotangent dx flows into every earlier stage
                    mode, scale, c_seed, _nid = hit[s]
                    dp = self._perturb_tree(dp, mode, scale, c_seed,
                                            job.index, s)
                    dx = self._perturb_tree(dx, mode, scale, c_seed,
                                            job.index, s)
                g_stages[s] = dp
                g = dx
                self.store.drop(s, ids)
            g_emb = self.stages.embed_backward(
                self.head_params[job.data_node], toks, g)
            g_head = jax.tree.map(jnp.add, g_head, g_emb)
            if self.record_microbatch_grads:
                self.last_microbatch_grads.append(
                    (job.index, g_head, list(g_stages)))
            if collect:
                # defer aggregation until the screen has seen every
                # contribution (same jnp.add chain in the same job
                # order afterwards, so an empty flag set aggregates
                # bit-identically to the inline path)
                contribs.append((job, g_head, g_stages))
                continue
            for s in range(S):
                grad_stage[s] = (g_stages[s] if grad_stage[s] is None else
                                 jax.tree.map(jnp.add, grad_stage[s],
                                              g_stages[s]))
            dn = job.data_node
            if dn in g_head_by_dn:
                acc, n = g_head_by_dn[dn]
                g_head_by_dn[dn] = (jax.tree.map(jnp.add, acc, g_head), n + 1)
            else:
                g_head_by_dn[dn] = (g_head, 1)
        if collect:
            flagged = self._screen_contribs(contribs) if screening else set()
            self._grads_flagged = len(flagged)
            for i in sorted(flagged):
                f_job = contribs[i][0]
                hit = corrupt_stages.get(f_job.index)
                if not hit:
                    continue   # false positive: excluded, but nobody
                    # is accused (no timeline record, no rep report)
                for s in sorted(hit):
                    c_nid = hit[s][3]
                    self.timeline.record(self.step, "corrupt_gradient",
                                         "detection", c_nid)
                    self.timeline.record(self.step, "corrupt_gradient",
                                         "repair", c_nid)
                    res.rep_reports.append(c_nid)
            kept = [i for i in range(len(contribs)) if i not in flagged]
            for i in kept:
                k_job, g_head, g_stages = contribs[i]
                for s in range(S):
                    grad_stage[s] = (
                        g_stages[s] if grad_stage[s] is None else
                        jax.tree.map(jnp.add, grad_stage[s], g_stages[s]))
                dn = k_job.data_node
                if dn in g_head_by_dn:
                    acc, n = g_head_by_dn[dn]
                    g_head_by_dn[dn] = (
                        jax.tree.map(jnp.add, acc, g_head), n + 1)
                else:
                    g_head_by_dn[dn] = (g_head, 1)
            if kept:
                self._apply_update(grad_stage, g_head_by_dn, len(kept))
            return total
        self._apply_update(grad_stage, g_head_by_dn, len(done))
        return total

    def _apply_update(self, grad_stage, g_head_by_dn, n_completed: int):
        for s in range(self.net.num_stages):
            if grad_stage[s] is None:
                continue
            gs = jax.tree.map(lambda a: a / n_completed, grad_stage[s])
            self.stage_params[s], self.stage_opt[s] = self._upd(
                gs, self.stage_opt[s], self.stage_params[s])
        for dn, (gh, n) in g_head_by_dn.items():
            if gh is None:
                continue
            g = jax.tree.map(lambda a: a / n, gh)
            self.head_params[dn], self.head_opt[dn] = self._upd(
                g, self.head_opt[dn], self.head_params[dn])


class CentralizedTrainer:
    """Baseline: same model, same data, no decentralization (Fig. 6).

    Runs the *same* chunked pass (`_chunk_pass`) over the same cached
    staged kernels (`StageCompute`) and the same jitted AdamW update as
    the decentralized runtime, in the same dispatch order.  At churn 0
    the decentralized trainer therefore executes bit-for-bit the
    identical float program — which is the paper's convergence claim
    stated as an executable invariant (the pre-refactor whole-model-jit
    formulation could only guarantee this by being one monolithic
    program; the staged formulation preserves it by construction).
    """

    def __init__(self, cfg, num_stages: int, *, lr: float = 1e-3,
                 seed: int = 0, remat: bool = False,
                 activation_codec: str = "fp",
                 wire_codec: Optional[str] = None,
                 dispatch_chunk: Optional[int] = None,
                 donate: Optional[bool] = None):
        self.cfg = cfg
        self.num_stages = num_stages
        self.remat = remat
        # fixed per-boundary wire codec (no planner here); None/fp32
        # keeps the exact program the bit-identity invariant pins
        self.wire_codec = (None if wire_codec in (None, "fp", "fp32")
                           else wire_codec)
        self.dispatch_chunk = dispatch_chunk
        stage_p, head_p = cache.initial_params(cfg, num_stages, seed)
        self.stage_params = list(stage_p)
        self.head_params = head_p
        self.opt = AdamW(lr=lr)
        self.stage_opt = [self.opt.init(p) for p in self.stage_params]
        self.head_opt = self.opt.init(self.head_params)
        self.stages = StageCompute(cfg, num_stages, donate=donate)
        self.store = ActivationStore(codec=activation_codec)
        self._upd = jax.jit(lambda g, s, p: self.opt.update(g, s, p))
        self.losses: List[float] = []
        self.last_store_peak_bytes = 0
        self.last_wire_bytes = 0

    def _chunk_size(self, n_mb: int, per: int, seq: int) -> int:
        if self.dispatch_chunk is not None:
            return max(1, min(self.dispatch_chunk, n_mb))
        itemsize = jnp.dtype(self.cfg.param_dtype).itemsize
        return auto_chunk(n_mb, per, seq, self.cfg.d_model, itemsize)

    def iteration(self, microbatches: List[dict]) -> float:
        S = self.num_stages
        B = len(microbatches)
        per = np.asarray(microbatches[0]["tokens"]).shape[0]
        seq = np.asarray(microbatches[0]["tokens"]).shape[1]
        self.store.clear()
        self.store.reset_peak()
        wire = (_WireLink([self.wire_codec] * (S - 1))
                if self.wire_codec and S > 1 else None)
        total = 0.0
        grad_stage: List[Any] = [None] * S
        g_head = None
        C = self._chunk_size(B, per, seq)
        for lo in range(0, B, C):
            part = microbatches[lo:lo + C]
            ids = tuple(range(lo, lo + len(part)))
            toks = jnp.asarray(np.concatenate(
                [np.asarray(mb["tokens"]) for mb in part]))
            labels = jnp.asarray(np.stack(
                [np.asarray(mb["labels"]) for mb in part]))
            loss_sum, gh = _chunk_pass(
                self.stages, self.store, self.stage_params,
                self.head_params, toks, labels, ids, per,
                remat=self.remat, grad_stage=grad_stage, wire=wire)
            total += loss_sum
            g_head = gh if g_head is None else jax.tree.map(jnp.add,
                                                            g_head, gh)
        for s in range(S):
            gs = jax.tree.map(lambda a: a / B, grad_stage[s])
            self.stage_params[s], self.stage_opt[s] = self._upd(
                gs, self.stage_opt[s], self.stage_params[s])
        gh = jax.tree.map(lambda a: a / B, g_head)
        self.head_params, self.head_opt = self._upd(
            gh, self.head_opt, self.head_params)
        self.last_store_peak_bytes = self.store.peak_bytes
        self.last_wire_bytes = wire.bytes if wire is not None else 0
        mean = float(total) / B
        self.losses.append(mean)
        return mean
