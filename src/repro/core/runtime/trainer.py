"""Training loop over the staged runtime: aggregation, AdamW, checkpoints.

`RuntimeTrainer` wires the layers together into the paper's iteration
(Sec. V-E):

1. the fault layer samples crashes/rejoins (`ChurnModel` through the
   same `ChurnContext` the simulator uses; rejoining nodes bootstrap by
   downloading their stage snapshot via ``checkpoint.store.restore_stage``
   when a checkpoint directory is configured);
2. the routing policy plans this iteration's complete-flow chains and
   microbatches are assigned to them;
3. `RecoveryManager` resolves every mid-iteration crash against the
   policy (stage-local substitute, requeue onto another chain, or
   drop);
4. the numeric pass executes the completed microbatches through
   `StageCompute`: stacked per-stage forwards (one dispatch per stage
   for the whole batch), the per-data-node loss head, then stacked
   per-stage VJPs read back from the `ActivationStore`; each recorded
   crash additionally dispatches the dead replica's lost work, so
   recovery cost is real wall time, not bookkeeping;
5. per-stage gradients are averaged over completed microbatches and
   applied with a jitted AdamW update (identical on every replica, so
   replicas stay bit-identical), and stage snapshots are written to
   ``checkpoint.store`` every ``checkpoint_every`` iterations.

`CentralizedTrainer` (the Fig. 6 baseline) lives here too; the
``repro.core.executor`` facade re-exports both.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store as ckpt
from repro.core.flow.graph import FlowNetwork, Node
from repro.core.runtime.activations import ActivationStore
from repro.core.runtime.recovery import Job, RecoveryManager, Resolution
from repro.core.runtime.stages import (StageCompute, init_head_params,
                                       init_stage_params)
from repro.core.sim.faults import BernoulliChurn, ChurnContext, ChurnModel
from repro.core.sim.policies import GWTFPolicy, RoutingPolicy
from repro.optim.adamw import AdamW


@dataclass
class IterationResult:
    loss: float
    completed: int
    launched: int
    dropped: int
    rerouted: int = 0             # crash repairs that saved the microbatch
    requeued: int = 0             # subset of rerouted: moved to another chain
    fwd_recomputes: int = 0       # stage-local forward recomputes (Sec. V-D)
    bwd_replays: int = 0          # stage-local VJP replays (Sec. V-D)


class RuntimeTrainer:
    """GWTF training with real JAX compute over the staged runtime."""

    def __init__(self, cfg, net: FlowNetwork, *,
                 churn: float = 0.0, lr: float = 1e-3, seed: int = 0,
                 rng: Optional[np.random.Generator] = None,
                 policy: Optional[RoutingPolicy] = None,
                 churn_model: Optional[ChurnModel] = None,
                 batch_microbatches: bool = True,
                 max_retries: int = 2,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 0,
                 record_microbatch_grads: bool = False):
        self.cfg = cfg
        self.net = net
        self.rng = rng or np.random.default_rng(seed)
        self.policy = policy or GWTFPolicy(net, rng=self.rng)
        self.churn_model = churn_model or BernoulliChurn(churn)
        self.batch_microbatches = batch_microbatches
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        self.record_microbatch_grads = record_microbatch_grads

        self.stages = StageCompute(cfg, net.num_stages)
        self.store = ActivationStore()
        self.recovery = RecoveryManager(net, self.policy,
                                        max_retries=max_retries)

        key = jax.random.PRNGKey(seed)
        S = net.num_stages
        # identical replicas per stage (paper: joining nodes download the
        # stage weights) -> ONE canonical copy per stage; replicas share
        # it because aggregation keeps them identical.
        self.stage_params = [init_stage_params(cfg, s, S, key)
                             for s in range(S)]
        self.head_params = {d.id: init_head_params(
            cfg, jax.random.fold_in(key, 999)) for d in net.data_nodes()}
        self.opt = AdamW(lr=lr)
        self.stage_opt = [self.opt.init(p) for p in self.stage_params]
        self.head_opt = {d: self.opt.init(p)
                         for d, p in self.head_params.items()}
        self._upd = jax.jit(lambda g, s, p: self.opt.update(g, s, p))

        self.losses: List[float] = []
        self.step = 0
        self.joins_bootstrapped = 0
        self.last_microbatch_grads: List[Tuple[int, Any, Any]] = []
        # introspection for tests/examples: the most recent iteration's
        # planned chains and crash resolution
        self.last_chains: List[List[int]] = []
        self.last_resolution: Optional[Resolution] = None

    # ------------------------------------------------------------------
    @property
    def protocol(self):
        """The GWTF protocol behind the routing policy, when there is
        one (pre-refactor compat accessor; ``None`` for policies that
        are not flow-based)."""
        return getattr(self.policy, "protocol", None)

    # ------------------------------------------------------------------
    # Fault-layer hooks
    # ------------------------------------------------------------------
    def _on_rejoin(self, node: Node) -> None:
        """Sec. V-E join path: the rejoining replica downloads its
        stage's latest snapshot before re-entering the flow graph.
        The restored tree is discarded afterwards because replicas
        share one canonical copy (the aggregation invariant keeps them
        bit-identical); the download itself — and its validation
        against the live stage structure — is the exercised path."""
        if (self.checkpoint_dir and node.stage >= 0
                and os.path.exists(os.path.join(
                    self.checkpoint_dir, f"stage_{node.stage:03d}.npz"))):
            ckpt.restore_stage(self.checkpoint_dir, node.stage,
                               {"params": self.stage_params[node.stage],
                                "opt": self.stage_opt[node.stage]})
            self.joins_bootstrapped += 1
        self.policy.on_rejoin(node)

    # ------------------------------------------------------------------
    # Checkpoint plumbing
    # ------------------------------------------------------------------
    def save_checkpoint(self, dirpath: Optional[str] = None) -> str:
        """Per-stage snapshots (params + AdamW state) plus the data-node
        heads; the unit a joining node downloads (paper Sec. V-E)."""
        d = dirpath or self.checkpoint_dir
        if not d:
            raise ValueError("no checkpoint directory configured")
        for s, (p, o) in enumerate(zip(self.stage_params, self.stage_opt)):
            ckpt.save_stage(d, s, {"params": p, "opt": o}, step=self.step)
        for dn, p in self.head_params.items():
            ckpt.save(os.path.join(d, f"head_{dn:03d}.npz"),
                      {"params": p, "opt": self.head_opt[dn]},
                      step=self.step)
        return d

    def restore_checkpoint(self, dirpath: Optional[str] = None) -> int:
        """Resume every stage + head from snapshots; returns the step."""
        d = dirpath or self.checkpoint_dir
        if not d:
            raise ValueError("no checkpoint directory configured")
        step = 0
        for s in range(self.net.num_stages):
            tree, step = ckpt.restore_stage(
                d, s, {"params": self.stage_params[s],
                       "opt": self.stage_opt[s]})
            self.stage_params[s] = tree["params"]
            self.stage_opt[s] = tree["opt"]
        for dn in self.head_params:
            tree, step = ckpt.restore(
                os.path.join(d, f"head_{dn:03d}.npz"),
                {"params": self.head_params[dn], "opt": self.head_opt[dn]})
            self.head_params[dn] = tree["params"]
            self.head_opt[dn] = tree["opt"]
        self.step = step
        return step

    # ------------------------------------------------------------------
    # One training iteration
    # ------------------------------------------------------------------
    def iteration(self, batches_per_data_node: Dict[int, List[dict]]
                  ) -> IterationResult:
        horizon = 1.0                    # normalized pipeline-flush clock
        crash_times = self.churn_model.sample(ChurnContext(
            net=self.net, rng=self.rng, horizon=horizon,
            iteration=self.step, on_rejoin=self._on_rejoin))

        chains = [list(c) for c in self.policy.plan()]
        jobs: List[Job] = []
        per_dn: Dict[int, int] = {}
        for chain in chains:
            dn = chain[0]
            avail = batches_per_data_node.get(dn, [])
            k = per_dn.get(dn, 0)
            if k < len(avail):
                jobs.append(Job(index=len(jobs), data_node=dn,
                                mb=avail[k], chain=list(chain)))
                per_dn[dn] = k + 1
        launched = len(jobs)

        res = self.recovery.resolve(jobs, chains, crash_times, horizon)
        self.last_chains = chains
        self.last_resolution = res
        mean_loss = self._execute(res)

        # ---- commit crashes for the next iteration --------------------
        for nid in crash_times:
            self.net.kill_node(nid)
            self.policy.on_crash(nid)

        self.step += 1
        if (self.checkpoint_dir and self.checkpoint_every
                and self.step % self.checkpoint_every == 0):
            self.save_checkpoint()

        self.losses.append(mean_loss)
        return IterationResult(
            loss=mean_loss, completed=len(res.completed), launched=launched,
            dropped=res.dropped, rerouted=res.rerouted,
            requeued=res.requeued, fwd_recomputes=res.fwd_recomputes,
            bwd_replays=res.bwd_replays)

    # ------------------------------------------------------------------
    # Numeric pass
    # ------------------------------------------------------------------
    def _execute(self, res: Resolution) -> float:
        """Run the completed microbatches through the staged compute and
        apply the aggregated update; dispatch each recorded crash's
        lost work so recovery cost is real."""
        done = res.completed
        if not done:
            return 0.0
        self.store.clear()
        self.last_microbatch_grads = []
        if self.batch_microbatches:
            total = self._execute_batched(done, res)
        else:
            total = self._execute_per_microbatch(done, res)
        self.store.clear()
        return total / len(done)

    def _group_by_dn(self, done: List[Job]) -> Dict[int, List[int]]:
        by_dn: Dict[int, List[int]] = {}
        for k, job in enumerate(done):
            by_dn.setdefault(job.data_node, []).append(k)
        return by_dn

    def _execute_batched(self, done: List[Job], res: Resolution) -> float:
        S = self.net.num_stages
        by_dn = self._group_by_dn(done)
        ids = tuple(j.index for j in done)
        per = np.asarray(done[0].mb["tokens"]).shape[0]

        # ---- forward: one stacked dispatch per stage ------------------
        toks_by_dn: Dict[int, Any] = {}
        single_dn = len(by_dn) == 1
        if single_dn:
            dn0 = next(iter(by_dn))
            toks_by_dn[dn0] = jnp.asarray(np.concatenate(
                [np.asarray(j.mb["tokens"]) for j in done]))
            x = self.stages.embed(self.head_params[dn0], toks_by_dn[dn0])
        else:
            parts: List[Any] = [None] * len(done)
            for dn, idxs in by_dn.items():
                toks = jnp.asarray(np.concatenate(
                    [np.asarray(done[k].mb["tokens"]) for k in idxs]))
                toks_by_dn[dn] = toks
                h = self.stages.embed(self.head_params[dn], toks)
                for row, k in enumerate(idxs):
                    parts[k] = h[row * per:(row + 1) * per]
            x = (parts[0] if len(parts) == 1
                 else jnp.concatenate(parts, axis=0))
        for s in range(S):
            self.store.put(s, ids, x)
            x = self.stages.forward(s, self.stage_params[s], x)
            self._replay_lost(res, s, "fwd")

        # ---- loss head per data node ----------------------------------
        D = x.shape[-1]
        seq = x.shape[1]
        total = 0.0
        g_head_by_dn: Dict[int, Any] = {}
        if single_dn:
            B = len(done)
            h = x.reshape(B, per, seq, D)
            labels = jnp.asarray(np.stack(
                [np.asarray(j.mb["labels"]) for j in done]))
            losses, g_head, g_hidden = self.stages.head_loss(
                self.head_params[dn0], h, labels)
            total += float(jnp.sum(losses))
            g_head_by_dn[dn0] = (g_head, B)
            g = g_hidden.reshape(B * per, seq, D)
        else:
            g_parts: List[Any] = [None] * len(done)
            for dn, idxs in by_dn.items():
                B = len(idxs)
                h = jnp.concatenate([x[k * per:(k + 1) * per] for k in idxs],
                                    axis=0).reshape(B, per, seq, D)
                labels = jnp.asarray(np.stack(
                    [np.asarray(done[k].mb["labels"]) for k in idxs]))
                losses, g_head, g_hidden = self.stages.head_loss(
                    self.head_params[dn], h, labels)
                total += float(jnp.sum(losses))
                g_head_by_dn[dn] = (g_head, B)
                for row, k in enumerate(idxs):
                    g_parts[k] = g_hidden[row]
            g = (g_parts[0] if len(g_parts) == 1
                 else jnp.concatenate(g_parts, axis=0))

        # ---- backward: one stacked VJP per stage ----------------------
        grad_stage: List[Any] = [None] * S
        for s in reversed(range(S)):
            self._replay_lost(res, s, "bwd", cotangent=g, ids=ids, per=per)
            xin = self.store.stacked(s, ids)
            dp, dx = self.stages.backward(s, self.stage_params[s], xin, g)
            grad_stage[s] = dp
            g = dx
            self.store.drop_stage(s)

        # ---- embedding pull-back (the token-lookup share of the head
        # gradient: the loss head's VJP alone misses it) ----------------
        for dn, idxs in by_dn.items():
            gslice = (g if single_dn else jnp.concatenate(
                [g[k * per:(k + 1) * per] for k in idxs], axis=0))
            g_emb = self.stages.embed_backward(self.head_params[dn],
                                               toks_by_dn[dn], gslice)
            gh, n = g_head_by_dn[dn]
            g_head_by_dn[dn] = (jax.tree.map(jnp.add, gh, g_emb), n)

        self._apply_update(grad_stage, g_head_by_dn, len(done))
        return total

    def _execute_per_microbatch(self, done: List[Job],
                                res: Resolution) -> float:
        """Unbatched path: every microbatch runs its own per-stage
        dispatches and gradients are accumulated with ``jnp.add`` —
        the dispatch order (and float association) of the centralized
        baseline, used by the numerical-equivalence tests."""
        S = self.net.num_stages
        total = 0.0
        grad_stage: List[Any] = [None] * S
        g_head_by_dn: Dict[int, Any] = {}
        # crash events per (job, stage, direction): each costs one real
        # lost-work dispatch, issued inline where the inputs are in hand
        lost: Dict[Tuple[int, int, str], int] = {}
        for ev in res.events:
            key = (ev.job, ev.stage, ev.direction)
            lost[key] = lost.get(key, 0) + 1
        for job in done:
            toks = jnp.asarray(job.mb["tokens"])
            labels = jnp.asarray(job.mb["labels"])[None]
            x = self.stages.embed(self.head_params[job.data_node], toks)
            for s in range(S):
                self.store.put(s, (job.index,), x)
                for _ in range(lost.get((job.index, s, "fwd"), 0)):
                    self.stages.forward(s, self.stage_params[s], x)
                x = self.stages.forward(s, self.stage_params[s], x)
            losses, g_head, g_hidden = self.stages.head_loss(
                self.head_params[job.data_node], x[None], labels)
            total += float(losses[0])
            g = g_hidden[0]
            g_stages: List[Any] = [None] * S
            for s in reversed(range(S)):
                xin = self.store.get(s, job.index)
                for _ in range(lost.get((job.index, s, "bwd"), 0)):
                    # copied cotangent: the backward dispatch donates
                    # its cotangent buffer on GPU/TPU and g is reused
                    # by the real dispatch below
                    self.stages.backward(s, self.stage_params[s], xin,
                                         jnp.copy(g))
                dp, dx = self.stages.backward(s, self.stage_params[s],
                                              xin, g)
                g_stages[s] = dp
                g = dx
            g_emb = self.stages.embed_backward(
                self.head_params[job.data_node], toks, g)
            g_head = jax.tree.map(jnp.add, g_head, g_emb)
            if self.record_microbatch_grads:
                self.last_microbatch_grads.append(
                    (job.index, g_head, list(g_stages)))
            for s in range(S):
                grad_stage[s] = (g_stages[s] if grad_stage[s] is None else
                                 jax.tree.map(jnp.add, grad_stage[s],
                                              g_stages[s]))
            dn = job.data_node
            if dn in g_head_by_dn:
                acc, n = g_head_by_dn[dn]
                g_head_by_dn[dn] = (jax.tree.map(jnp.add, acc, g_head), n + 1)
            else:
                g_head_by_dn[dn] = (g_head, 1)
        self._apply_update(grad_stage, g_head_by_dn, len(done))
        return total

    def _replay_lost(self, res: Resolution, s: int, direction: str,
                     cotangent=None, ids=None, per: int = 0) -> None:
        """Dispatch the dead replica's lost work for each crash recorded
        at stage ``s``: a forward crash costs one wasted stage forward,
        a backward crash one wasted VJP replay.  Results are discarded
        — the substitute's (identical) computation lives in the batch —
        but the wall time and the dispatch counters are real, which is
        what the recovery benchmarks and tests measure."""
        for ev in res.events:
            if ev.stage != s or ev.direction != direction:
                continue
            try:
                xin = self.store.get(s, ev.job)
            except KeyError:
                continue    # microbatch dropped before reaching the batch
            if direction == "fwd":
                self.stages.forward(s, self.stage_params[s], xin)
            elif cotangent is not None and ids is not None and ev.job in ids:
                k = ids.index(ev.job)
                gslice = cotangent[k * per:(k + 1) * per]
                self.stages.backward(s, self.stage_params[s], xin, gslice)

    def _apply_update(self, grad_stage, g_head_by_dn, n_completed: int):
        for s in range(self.net.num_stages):
            if grad_stage[s] is None:
                continue
            gs = jax.tree.map(lambda a: a / n_completed, grad_stage[s])
            self.stage_params[s], self.stage_opt[s] = self._upd(
                gs, self.stage_opt[s], self.stage_params[s])
        for dn, (gh, n) in g_head_by_dn.items():
            g = jax.tree.map(lambda a: a / n, gh)
            self.head_params[dn], self.head_opt[dn] = self._upd(
                g, self.head_opt[dn], self.head_params[dn])


class CentralizedTrainer:
    """Baseline: same model, same data, no decentralization (Fig. 6).

    Runs on the *same* staged kernels (`StageCompute`) and the same
    jitted AdamW update as the decentralized runtime, in the same
    dispatch order.  At churn 0 the decentralized trainer therefore
    executes bit-for-bit the identical float program — which is the
    paper's convergence claim stated as an executable invariant (the
    pre-refactor whole-model-jit formulation could only guarantee this
    by being one monolithic program; the staged formulation preserves
    it by construction).
    """

    def __init__(self, cfg, num_stages: int, *, lr: float = 1e-3,
                 seed: int = 0):
        self.cfg = cfg
        self.num_stages = num_stages
        key = jax.random.PRNGKey(seed)
        self.stage_params = [init_stage_params(cfg, s, num_stages, key)
                             for s in range(num_stages)]
        self.head_params = init_head_params(cfg, jax.random.fold_in(key, 999))
        self.opt = AdamW(lr=lr)
        self.stage_opt = [self.opt.init(p) for p in self.stage_params]
        self.head_opt = self.opt.init(self.head_params)
        self.stages = StageCompute(cfg, num_stages)
        self.store = ActivationStore()
        self._upd = jax.jit(lambda g, s, p: self.opt.update(g, s, p))
        self.losses: List[float] = []

    def iteration(self, microbatches: List[dict]) -> float:
        S = self.num_stages
        B = len(microbatches)
        per = np.asarray(microbatches[0]["tokens"]).shape[0]
        ids = tuple(range(B))
        self.store.clear()
        toks = jnp.asarray(np.concatenate(
            [np.asarray(mb["tokens"]) for mb in microbatches]))
        x = self.stages.embed(self.head_params, toks)
        for s in range(S):
            self.store.put(s, ids, x)
            x = self.stages.forward(s, self.stage_params[s], x)
        seq, D = x.shape[1], x.shape[-1]
        h = x.reshape(B, per, seq, D)
        labels = jnp.asarray(np.stack(
            [np.asarray(mb["labels"]) for mb in microbatches]))
        losses, g_head, g_hidden = self.stages.head_loss(
            self.head_params, h, labels)
        g = g_hidden.reshape(B * per, seq, D)
        grad_stage: List[Any] = [None] * S
        for s in reversed(range(S)):
            xin = self.store.stacked(s, ids)
            dp, dx = self.stages.backward(s, self.stage_params[s], xin, g)
            grad_stage[s] = dp
            g = dx
            self.store.drop_stage(s)
        g_emb = self.stages.embed_backward(self.head_params, toks, g)
        g_head = jax.tree.map(jnp.add, g_head, g_emb)
        for s in range(S):
            gs = jax.tree.map(lambda a: a / B, grad_stage[s])
            self.stage_params[s], self.stage_opt[s] = self._upd(
                gs, self.stage_opt[s], self.stage_params[s])
        gh = jax.tree.map(lambda a: a / B, g_head)
        self.head_params, self.head_opt = self._upd(
            gh, self.head_opt, self.head_params)
        mean = float(jnp.sum(losses)) / B
        self.losses.append(mean)
        return mean
