"""Per-(microbatch, stage) activation + residual store — the recovery
and fused-backward substrate.

The paper's stage-local repair (Sec. V-D) hinges on one invariant: the
input activation of every stage is retained until that stage's backward
completes.  A forward crash then reroutes and recomputes *only* the
crashed stage from the stored input; a backward crash replays that
stage's VJP on a substitute replica.

Since the fused dispatch rework, the store holds two things per stage:

* **boundary activations** (stage ``s``'s entry is the *input* of
  stage ``s``; stage 0's entry is the embedding output) — what a
  substitute replica 'downloads' to recompute a crashed forward, and
  what the remat oracle path reads back for its backward;
* **VJP residuals** (the ``jax.tree_util.Partial`` captured by
  ``StageCompute.forward_fused``) — what the default backward and the
  residual-based crash replay consume, so backward never re-runs the
  forward.

Keeping residuals costs memory; the opt-in :class:`Int8Codec`
(per-tensor symmetric int8 + fp32 scale, the FusionLLM-style
compression lever) shrinks both boundary activations and residuals
~4x at a bounded fidelity cost (``|x - dq(q(x))| <= scale/2``
elementwise).  :class:`Bf16Codec` (half the bytes, <= 2**-8 relative
error) and :class:`TopKCodec` (sparse value+index pairs, dropped
magnitudes bounded by the smallest kept one) complete the menu the
flow planner prices per link (``flow.graph.WIRE_CODECS``); the same
codecs double as *wire* codecs on inter-stage boundary transfers
(``trainer.py``).  ``peak_bytes`` tracks the high-water resident size
so benchmarks can surface the memory/recompute/fidelity trade.

The batched runtime stores one stacked array per (stage, chunk) (the
rows of all microbatches of a dispatch chunk, one ``put``); the
per-microbatch view needed by recovery (`get`) slices rows out of the
stack, and the backward sweep reads the stack back (`stacked`),
gathering rows when some microbatches failed mid-backward.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Codecs
# ---------------------------------------------------------------------------

def _leaf_nbytes(x) -> int:
    nb = getattr(x, "nbytes", None)
    return int(nb) if nb is not None else int(np.asarray(x).nbytes)


class _Quantized:
    """One int8-encoded tensor: values, per-tensor fp32 scale, original
    dtype.  Rows can be sliced before dequantisation (the scale is
    per-tensor, so any row subset dequantises with the same scale)."""
    __slots__ = ("q", "scale", "dtype")

    def __init__(self, q, scale, dtype):
        self.q = q
        self.scale = scale
        self.dtype = dtype

    @property
    def nbytes(self) -> int:
        return _leaf_nbytes(self.q) + _leaf_nbytes(self.scale)


class NullCodec:
    """Identity codec: full-precision store, zero-copy (the default —
    bit-identity with `CentralizedTrainer` depends on it)."""
    name = "fp"

    def encode(self, x):
        return x

    def decode(self, enc):
        return enc

    @staticmethod
    def nbytes(enc) -> int:
        return _leaf_nbytes(enc)


class Int8Codec:
    """Per-tensor symmetric int8 quantisation with an fp32 scale.

    ``scale = amax(|x|) / 127``; ``q = clip(round(x / scale), -127,
    127)``; ``dq = q * scale``.  Round-to-nearest bounds the elementwise
    error by ``scale / 2``.  Non-float leaves (token ids, integer
    residuals) pass through unquantised.
    """
    name = "int8"

    def encode(self, x):
        if not jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating):
            return x
        x = jnp.asarray(x)
        amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
        scale = jnp.where(amax > 0, amax / 127.0, jnp.float32(1.0))
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                     -127, 127).astype(jnp.int8)
        return _Quantized(q, scale, x.dtype)

    def decode(self, enc):
        if not isinstance(enc, _Quantized):
            return enc
        return (enc.q.astype(jnp.float32) * enc.scale).astype(enc.dtype)

    @staticmethod
    def nbytes(enc) -> int:
        if isinstance(enc, _Quantized):
            return enc.nbytes
        return _leaf_nbytes(enc)


class _Bf16:
    """One bf16-encoded tensor + its original dtype (so decode restores
    the exact dtype the compute graph expects)."""
    __slots__ = ("h", "dtype")

    def __init__(self, h, dtype):
        self.h = h
        self.dtype = dtype

    @property
    def nbytes(self) -> int:
        return _leaf_nbytes(self.h)


class Bf16Codec:
    """Truncate to bfloat16 on the wire / in the store.

    Round-to-nearest into an 8-bit significand bounds the elementwise
    relative error by ``2**-8`` (half an ulp of eps = 2**-7):
    ``|x - dq(q(x))| <= 2**-8 * |x|`` for normal values.  Non-float
    leaves pass through.
    """
    name = "bf16"

    def encode(self, x):
        if not jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating):
            return x
        x = jnp.asarray(x)
        return _Bf16(x.astype(jnp.bfloat16), x.dtype)

    def decode(self, enc):
        if not isinstance(enc, _Bf16):
            return enc
        return enc.h.astype(enc.dtype)

    @staticmethod
    def nbytes(enc) -> int:
        if isinstance(enc, _Bf16):
            return enc.nbytes
        return _leaf_nbytes(enc)


class _Sparse:
    """One top-k-encoded tensor: kept values, flat int32 indices, and
    enough metadata to scatter back into a dense zero tensor."""
    __slots__ = ("vals", "idx", "shape", "dtype", "size")

    def __init__(self, vals, idx, shape, dtype, size):
        self.vals = vals
        self.idx = idx
        self.shape = shape
        self.dtype = dtype
        self.size = size

    @property
    def nbytes(self) -> int:
        return _leaf_nbytes(self.vals) + _leaf_nbytes(self.idx)


class TopKCodec:
    """Magnitude top-k sparsification: keep the ``k_frac`` largest-|x|
    entries as (value, flat index) pairs, decode scatters them into
    zeros.

    Error bound: kept entries round-trip exactly, dropped entries are
    zeroed, and every dropped magnitude is <= the smallest kept
    magnitude — so ``|x - dq(q(x))| <= min(|kept values|)``
    elementwise.  ``nbytes`` is monotone in k (more kept pairs, more
    bytes).  Non-float leaves pass through.
    """
    name = "topk"

    def __init__(self, k_frac: float = 1.0 / 16.0):
        if not 0.0 < k_frac <= 1.0:
            raise ValueError(f"k_frac must be in (0, 1], got {k_frac}")
        self.k_frac = float(k_frac)

    def encode(self, x):
        if not jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating):
            return x
        x = jnp.asarray(x)
        flat = x.ravel()
        n = int(flat.size)
        k = max(1, int(round(self.k_frac * n)))
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        idx = idx.astype(jnp.int32)
        return _Sparse(flat[idx], idx, x.shape, x.dtype, n)

    def decode(self, enc):
        if not isinstance(enc, _Sparse):
            return enc
        dense = jnp.zeros(enc.size, dtype=enc.dtype).at[enc.idx].set(
            enc.vals)
        return dense.reshape(enc.shape)

    @staticmethod
    def nbytes(enc) -> int:
        if isinstance(enc, _Sparse):
            return enc.nbytes
        return _leaf_nbytes(enc)


CODECS = {"fp": NullCodec, "int8": Int8Codec, "bf16": Bf16Codec,
          "topk": TopKCodec}

# Planner-side wire-codec names (flow.graph.WIRE_CODECS) map onto the
# runtime codec registry, so a flow-layer codec choice can be applied
# to real tensors without translation at every call site.
CODEC_ALIASES = {"fp32": "fp", "top-k": "topk"}


def make_codec(spec: Union[str, None, NullCodec, Int8Codec]):
    if spec is None:
        return NullCodec()
    if isinstance(spec, str):
        name = CODEC_ALIASES.get(spec, spec)
        try:
            return CODECS[name]()
        except KeyError:
            raise ValueError(
                f"unknown activation codec {spec!r} (choose from "
                f"{sorted(CODECS) + sorted(CODEC_ALIASES)})") from None
    return spec


# ---------------------------------------------------------------------------
# Store
# ---------------------------------------------------------------------------

class ActivationStore:
    """Boundary activations + VJP residuals for the in-flight
    iteration, optionally quantised by ``codec``."""

    def __init__(self, codec: Union[str, None, NullCodec, Int8Codec] = None):
        self.codec = make_codec(codec)
        # stage -> list of (mb_ids tuple, encoded stacked array) chunks
        self._chunks: Dict[int, List[Tuple[tuple, Any]]] = {}
        # stage -> list of (mb_ids tuple, [encoded leaves], treedef)
        self._residuals: Dict[int, List[Tuple[tuple, list, Any]]] = {}
        self.puts = 0
        self.hits = 0
        self.misses = 0
        self.peak_bytes = 0

    # ------------------------------------------------------------------
    def put(self, stage: int, mb_ids: Sequence[int], x) -> None:
        """Store the stacked input of ``stage`` for ``mb_ids`` (rows of
        ``x`` split evenly, in order)."""
        self._chunks.setdefault(stage, []).append(
            (tuple(mb_ids), self.codec.encode(x)))
        self.puts += 1
        self._note_peak()

    def get(self, stage: int, mb_id: int):
        """The stored input rows of ``stage`` for one microbatch — what
        a substitute replica 'downloads' to recompute or replay."""
        for ids, enc in self._chunks.get(stage, ()):
            if mb_id in ids:
                x = self.codec.decode(enc)
                per = x.shape[0] // len(ids)
                k = ids.index(mb_id)
                self.hits += 1
                return x[k * per:(k + 1) * per]
        self.misses += 1
        raise KeyError(f"no stored activation for (mb={mb_id}, "
                       f"stage={stage})")

    def stacked(self, stage: int, mb_ids: Sequence[int]):
        """The stacked input of ``stage`` for exactly ``mb_ids``.

        Fast path: a single chunk holding exactly these ids (the
        healthy batched iteration) is returned as-is; otherwise rows
        are gathered per microbatch.
        """
        want = tuple(mb_ids)
        for ids, enc in self._chunks.get(stage, ()):
            if ids == want:
                self.hits += 1
                return self.codec.decode(enc)
        return jnp.concatenate([self.get(stage, i) for i in want], axis=0)

    # ------------------------------------------------------------------
    # Residuals (fused backward / residual-based crash replay)
    # ------------------------------------------------------------------
    def put_residuals(self, stage: int, mb_ids: Sequence[int],
                      residuals) -> None:
        """Store the VJP residual pytree of ``stage`` for the chunk
        ``mb_ids`` (leaf-wise encoded)."""
        leaves, treedef = jax.tree_util.tree_flatten(residuals)
        enc = [self.codec.encode(leaf) for leaf in leaves]
        self._residuals.setdefault(stage, []).append(
            (tuple(mb_ids), enc, treedef))
        self.puts += 1
        self._note_peak()

    def residuals(self, stage: int, mb_ids: Sequence[int]):
        """The decoded residual pytree for exactly the chunk
        ``mb_ids`` (residual leaves mix batch-shaped and param-shaped
        tensors, so unlike boundaries they are chunk-granular)."""
        want = tuple(mb_ids)
        for ids, enc, treedef in self._residuals.get(stage, ()):
            if ids == want:
                self.hits += 1
                return jax.tree_util.tree_unflatten(
                    treedef, [self.codec.decode(e) for e in enc])
        self.misses += 1
        raise KeyError(f"no stored residuals for (mbs={want}, "
                       f"stage={stage})")

    def has_residuals(self, stage: int, mb_ids: Sequence[int]) -> bool:
        want = tuple(mb_ids)
        return any(ids == want for ids, _, _ in
                   self._residuals.get(stage, ()))

    # ------------------------------------------------------------------
    def drop(self, stage: int, mb_ids: Sequence[int]) -> None:
        """Release one chunk's boundary + residuals once its backward
        completed (depth-first chunking keeps residency to ~one chunk
        per stage)."""
        want = tuple(mb_ids)
        chunks = self._chunks.get(stage)
        if chunks is not None:
            chunks[:] = [c for c in chunks if c[0] != want]
            if not chunks:
                del self._chunks[stage]
        resid = self._residuals.get(stage)
        if resid is not None:
            resid[:] = [r for r in resid if r[0] != want]
            if not resid:
                del self._residuals[stage]

    def drop_stage(self, stage: int) -> None:
        """Release a stage's activations + residuals entirely."""
        self._chunks.pop(stage, None)
        self._residuals.pop(stage, None)

    def clear(self) -> None:
        self._chunks.clear()
        self._residuals.clear()

    def reset_peak(self) -> None:
        self.peak_bytes = 0

    def nbytes(self) -> int:
        """Resident encoded bytes (boundaries + residuals)."""
        total = sum(self.codec.nbytes(enc)
                    for chunks in self._chunks.values()
                    for _, enc in chunks)
        total += sum(self.codec.nbytes(e)
                     for chunks in self._residuals.values()
                     for _, enc, _ in chunks for e in enc)
        return int(total)

    def _note_peak(self) -> None:
        n = self.nbytes()
        if n > self.peak_bytes:
            self.peak_bytes = n

    def __len__(self) -> int:
        return (sum(len(c) for c in self._chunks.values())
                + sum(len(c) for c in self._residuals.values()))
