"""Per-(microbatch, stage) activation store — the recovery substrate.

The paper's stage-local repair (Sec. V-D) hinges on one invariant: the
input activation of every stage is retained until that stage's backward
completes.  A forward crash then reroutes and recomputes *only* the
crashed stage from the stored input; a backward crash replays that
stage's VJP on a substitute replica from the same stored input.

`ActivationStore` keys boundary activations by pipeline stage.  The
batched runtime stores one stacked array per stage (the rows of all
in-flight microbatches, one ``put``); the per-microbatch view needed by
recovery (`get`) slices rows out of the stack, and the backward sweep
reads the stack back (`stacked`), gathering rows when some microbatches
failed mid-backward.  Stage ``s``'s entry is the *input* of stage
``s``; stage 0's entry is the embedding output.
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


class ActivationStore:
    """Boundary activations for the in-flight iteration."""

    def __init__(self):
        # stage -> list of (mb_ids tuple, stacked array) chunks
        self._chunks: Dict[int, List[Tuple[tuple, Any]]] = {}
        self.puts = 0
        self.hits = 0
        self.misses = 0

    # ------------------------------------------------------------------
    def put(self, stage: int, mb_ids: Sequence[int], x) -> None:
        """Store the stacked input of ``stage`` for ``mb_ids`` (rows of
        ``x`` split evenly, in order)."""
        self._chunks.setdefault(stage, []).append((tuple(mb_ids), x))
        self.puts += 1

    def get(self, stage: int, mb_id: int):
        """The stored input rows of ``stage`` for one microbatch — what
        a substitute replica 'downloads' to recompute or replay."""
        for ids, x in self._chunks.get(stage, ()):
            if mb_id in ids:
                per = x.shape[0] // len(ids)
                k = ids.index(mb_id)
                self.hits += 1
                return x[k * per:(k + 1) * per]
        self.misses += 1
        raise KeyError(f"no stored activation for (mb={mb_id}, "
                       f"stage={stage})")

    def stacked(self, stage: int, mb_ids: Sequence[int]):
        """The stacked input of ``stage`` for exactly ``mb_ids``.

        Fast path: a single chunk holding exactly these ids (the
        healthy batched iteration) is returned as-is; otherwise rows
        are gathered per microbatch.
        """
        want = tuple(mb_ids)
        for ids, x in self._chunks.get(stage, ()):
            if ids == want:
                self.hits += 1
                return x
        return jnp.concatenate([self.get(stage, i) for i in want], axis=0)

    # ------------------------------------------------------------------
    def drop_stage(self, stage: int) -> None:
        """Release a stage's activations once its backward completed."""
        self._chunks.pop(stage, None)

    def clear(self) -> None:
        self._chunks.clear()

    def nbytes(self) -> int:
        return int(sum(np.asarray(x).nbytes
                       for chunks in self._chunks.values()
                       for _, x in chunks))

    def __len__(self) -> int:
        return sum(len(c) for c in self._chunks.values())
