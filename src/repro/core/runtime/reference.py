"""FROZEN pre-refactor executor — the per-microbatch full-jit reference.

This is the monolithic ``DecentralizedTrainer`` exactly as it stood
before the staged runtime existed: one ``jax.value_and_grad`` over the
*entire* model per microbatch, hand-rolled Bernoulli churn with an
``integers(0, 2)`` crash budget, silent drops when no live same-stage
substitute exists, no activation store, no checkpointing.

Do not modify this file except to track upstream API renames — it is
the baseline ``benchmarks/bench_exec.py`` measures the staged runtime
against (microbatches/sec and recovery cost), mirroring how
``sim/reference.py`` freezes the pre-refactor event loop.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flow.decentralized import GWTFProtocol
from repro.core.flow.graph import FlowNetwork
from repro.core.runtime.stages import (embed_fn, init_head_params,
                                       init_stage_params, loss_fn,
                                       stage_forward)
from repro.optim.adamw import AdamW


@dataclass
class ReferenceIterationResult:
    loss: float
    completed: int
    launched: int
    dropped: int


class ReferenceDecentralizedTrainer:
    """The seed's GWTF trainer: whole-model jit per microbatch."""

    def __init__(self, cfg, net: FlowNetwork, *,
                 churn: float = 0.0, lr: float = 1e-3,
                 seed: int = 0,
                 rng: Optional[np.random.Generator] = None):
        self.cfg = cfg
        self.net = net
        self.churn = churn
        self.rng = rng or np.random.default_rng(seed)
        self.protocol = GWTFProtocol(net, rng=self.rng)
        self.protocol.run(max_rounds=100)
        key = jax.random.PRNGKey(seed)
        S = net.num_stages
        self.stage_params = [init_stage_params(cfg, s, S, key)
                             for s in range(S)]
        self.head_params = {d.id: init_head_params(cfg, jax.random.fold_in(key, 999))
                            for d in net.data_nodes()}
        self.opt = AdamW(lr=lr)
        self.stage_opt = [self.opt.init(p) for p in self.stage_params]
        self.head_opt = {d: self.opt.init(p)
                         for d, p in self.head_params.items()}
        self._jit_cache: Dict[str, Any] = {}
        self.losses: List[float] = []

    # ------------------------------------------------------------------
    def iteration(self, batches_per_data_node: Dict[int, List[dict]]
                  ) -> ReferenceIterationResult:
        """One training iteration: route, fwd, bwd, aggregate, update."""
        cfg, S = self.cfg, self.net.num_stages
        # --- churn: pick crashing relays for this iteration -------------
        crashed = set()
        for n in self.net.nodes.values():
            if n.is_data:
                continue
            if n.alive and self.rng.uniform() < self.churn:
                crashed.add(n.id)
            elif not n.alive and self.rng.uniform() < self.churn:
                n.alive = True
                self.protocol.add_node(n)
        # --- routing -----------------------------------------------------
        self.protocol.reclaim_sink_slots()
        self.protocol.run(max_rounds=30, quiet_rounds=2)
        flows = self.protocol.complete_flows()
        mb_queue: List[Tuple[int, dict, List[int]]] = []
        per_dn_counts: Dict[int, int] = {d.id: 0 for d in self.net.data_nodes()}
        for chain in flows:
            dn = chain[0]
            avail = batches_per_data_node.get(dn, [])
            k = per_dn_counts[dn]
            if k < len(avail):
                mb_queue.append((dn, avail[k], chain))
                per_dn_counts[dn] += 1
        launched = len(mb_queue)
        crash_budget = {nid: self.rng.integers(0, 2) for nid in crashed}

        # --- forward + backward per microbatch ---------------------------
        grad_stage = [None] * S
        grad_head: Dict[int, Any] = {}
        counts = [0] * S
        head_counts: Dict[int, int] = {}
        total_loss, completed, dropped = 0.0, 0, 0

        for dn, mb, chain in mb_queue:
            relays = list(chain[1:-1])
            ok = True
            for idx, nid in enumerate(relays):
                if nid in crashed and crash_budget[nid] <= 0:
                    sub = self._substitute(nid, crashed)
                    if sub is None:
                        ok = False
                        break
                    relays[idx] = sub
                elif nid in crashed:
                    crash_budget[nid] -= 1
            if not ok:
                dropped += 1
                continue
            loss, g_head, g_stages = self._train_microbatch(dn, mb, relays)
            total_loss += loss
            completed += 1
            for s, g in enumerate(g_stages):
                grad_stage[s] = g if grad_stage[s] is None else jax.tree.map(
                    jnp.add, grad_stage[s], g)
                counts[s] += 1
            if dn in grad_head:
                grad_head[dn] = jax.tree.map(jnp.add, grad_head[dn], g_head)
                head_counts[dn] += 1
            else:
                grad_head[dn] = g_head
                head_counts[dn] = 1

        # --- aggregation + update (Sec. V-E) ------------------------------
        for s in range(S):
            if grad_stage[s] is None:
                continue
            g = jax.tree.map(lambda x: x / counts[s], grad_stage[s])
            self.stage_params[s], self.stage_opt[s] = self.opt.update(
                g, self.stage_opt[s], self.stage_params[s])
        for dn, g in grad_head.items():
            g = jax.tree.map(lambda x: x / head_counts[dn], g)
            self.head_params[dn], self.head_opt[dn] = self.opt.update(
                g, self.head_opt[dn], self.head_params[dn])

        # --- commit crashes ------------------------------------------------
        for nid in crashed:
            self.net.nodes[nid].alive = False
            self.protocol.remove_node(nid)

        mean_loss = total_loss / max(1, completed)
        self.losses.append(mean_loss)
        return ReferenceIterationResult(loss=mean_loss, completed=completed,
                                        launched=launched, dropped=dropped)

    # ------------------------------------------------------------------
    def _substitute(self, dead: int, crashed: set) -> Optional[int]:
        stage = self.net.nodes[dead].stage
        cands = [n.id for n in self.net.stage_nodes(stage)
                 if n.id not in crashed and n.id != dead]
        return cands[0] if cands else None

    def _train_microbatch(self, dn: int, mb: dict, relays: List[int]):
        """Full fwd+bwd for one microbatch along its (repaired) path."""
        cfg, S = self.cfg, self.net.num_stages
        key = "trainmb"
        if key not in self._jit_cache:
            def full(head_p, stage_ps, tokens, labels):
                x = embed_fn(head_p, tokens)
                for s in range(S):
                    x = stage_forward(stage_ps[s], x, cfg)
                return loss_fn(head_p, x, labels, cfg)
            self._jit_cache[key] = jax.jit(jax.value_and_grad(
                full, argnums=(0, 1)))
        tokens = jnp.asarray(mb["tokens"])
        labels = jnp.asarray(mb["labels"])
        loss, (g_head, g_stages) = self._jit_cache[key](
            self.head_params[dn], self.stage_params, tokens, labels)
        return float(loss), g_head, list(g_stages)
