"""Layered real-compute runtime for GWTF training (paper Sec. V).

The runtime splits the old monolithic executor into the same layered
shape as :mod:`repro.core.sim`:

* :mod:`repro.core.runtime.stages` — per-stage fused
  forward+residual and residual-consuming backward dispatches (true
  pipeline-stage semantics, no backward-time forward recompute; the
  rematerialising pair is kept as the in-engine equality oracle),
  with same-stage microbatch stacking so B microbatches cost one
  dispatch per stage;
* :mod:`repro.core.runtime.activations` — the per-(chunk, stage)
  boundary-activation + VJP-residual store (optionally int8+scale
  quantised) that makes the paper's stage-local recovery real;
* :mod:`repro.core.runtime.cache` — process-wide memoised stage
  kernels and initial parameters, shared by trainers, tests, and the
  scenario harness;
* :mod:`repro.core.runtime.recovery` — crash injection and repair
  driven by the shared :class:`~repro.core.sim.faults.ChurnModel` and
  :class:`~repro.core.sim.policies.RoutingPolicy`/``FaultView``
  layers, including requeue-instead-of-drop;
* :mod:`repro.core.runtime.trainer` — gradient aggregation, AdamW
  updates, periodic per-stage checkpoints and joining-node bootstrap
  via :func:`repro.checkpoint.store.restore_stage`;
* :mod:`repro.core.runtime.reference` — the frozen pre-refactor
  per-microbatch full-jit executor, kept for benchmarking
  (``benchmarks/bench_exec.py``).

``repro.core.executor`` re-exports the drop-in trainer facades.
"""
from repro.core.runtime.activations import ActivationStore
from repro.core.runtime.recovery import RecoveryManager, Resolution
from repro.core.runtime.stages import StageCompute
from repro.core.runtime.trainer import (CentralizedTrainer, IterationResult,
                                        RuntimeTrainer)

__all__ = [
    "ActivationStore",
    "CentralizedTrainer",
    "IterationResult",
    "RecoveryManager",
    "Resolution",
    "RuntimeTrainer",
    "StageCompute",
]
