"""Per-stage compute: forward and VJP backward as separate jitted calls.

The pre-refactor executor jitted the *entire* model end-to-end per
microbatch (``jax.value_and_grad`` over all stages at once), which has
no pipeline-stage structure: a crash anywhere forced rerunning the
whole graph, and B microbatches cost B full-model dispatches.

`StageCompute` lowers each pipeline stage to two jitted primitives:

* ``forward(s, params, x)`` — the stage's transformer blocks;
* ``backward(s, params, x, g)`` — the stage's VJP, *rematerialised
  from the stored input activation*: ``jax.vjp`` recomputes the
  stage forward under the hood and pulls the cotangent ``g`` back to
  ``(dparams, dx)``.  This is exactly the paper's Sec. V-D repair
  primitive: any replica holding the stage weights and the upstream
  activation can (re)produce the stage's backward.

Microbatches of the same stage are stacked along the batch axis, so B
microbatches cost one dispatch per stage instead of B full-model
dispatches.  Cotangents are donated to the backward dispatch on
backends that support buffer donation (stored activations are *not*
donated — recovery may replay them).

Dispatch counters (``fwd_calls``/``bwd_calls`` per stage) are the
ground truth for the recovery tests: a backward crash must add exactly
one stage-level dispatch, not a full-pipeline recompute.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.transformer import _apply_block, _init_block


# ---------------------------------------------------------------------------
# Stage modules (moved verbatim from the pre-refactor executor)
# ---------------------------------------------------------------------------

def stage_bounds(cfg: ModelConfig, stage: int, num_stages: int):
    per = cfg.num_layers // num_stages
    extra = cfg.num_layers - per * num_stages
    lo = stage * per + min(stage, extra)
    hi = lo + per + (1 if stage < extra else 0)
    return lo, hi


def init_stage_params(cfg: ModelConfig, stage: int, num_stages: int, key):
    """Blocks [lo, hi) of the model as one stage (stacked for scan)."""
    lo, hi = stage_bounds(cfg, stage, num_stages)
    keys = jax.random.split(jax.random.fold_in(key, stage), hi - lo)
    dtype = jnp.dtype(cfg.param_dtype)
    return jax.vmap(lambda kk: _init_block(kk, cfg, dtype))(keys)


def stage_forward(stage_params, x, cfg: ModelConfig):
    positions = jnp.arange(x.shape[1])

    def body(carry, bp):
        h, _aux, _ = _apply_block(bp, carry, cfg, positions=positions,
                                  window=None, cache=None, write_index=None,
                                  kv_valid=None, moe_impl="dense",
                                  use_kernel=False)
        return h, None

    out, _ = jax.lax.scan(body, x, stage_params)
    return out


def init_head_params(cfg: ModelConfig, key):
    """Data-node module: embedding + final norm + LM head."""
    return {"embed": L.init_embed(key, cfg, jnp.dtype(cfg.param_dtype)),
            "final_norm": L.init_norm(cfg)}


def embed_fn(head_params, tokens):
    return L.embed_tokens(head_params["embed"], tokens)


def loss_fn(head_params, hidden, labels, cfg: ModelConfig):
    h = L.apply_norm(head_params["final_norm"], hidden, cfg)
    return L.chunked_xent_loss(head_params["embed"], h, labels, cfg)


def _donate_supported() -> bool:
    return jax.default_backend() in ("gpu", "tpu")


class StageCompute:
    """Jitted per-stage primitives + dispatch accounting.

    One jitted callable serves every stage (jax retraces per parameter
    shape); counters are tracked per stage at the call sites so
    recovery tests can pin exactly which stage recomputed.
    """

    def __init__(self, cfg: ModelConfig, num_stages: int):
        self.cfg = cfg
        self.num_stages = num_stages
        self.fwd_calls: List[int] = [0] * num_stages
        self.bwd_calls: List[int] = [0] * num_stages
        self.embed_calls = 0
        self.embed_bwd_calls = 0
        self.head_calls = 0

        self._fwd = jax.jit(lambda p, x: stage_forward(p, x, cfg))

        def bwd_impl(p, x, g):
            _, vjp = jax.vjp(lambda pp, xx: stage_forward(pp, xx, cfg), p, x)
            dp, dx = vjp(g)
            return dp, dx

        donate = (2,) if _donate_supported() else ()
        self._bwd = jax.jit(bwd_impl, donate_argnums=donate)
        self._embed = jax.jit(embed_fn)

        def embed_bwd_impl(head_p, tokens, g):
            """Pull the stage-0 input cotangent back through the token
            embedding: the data node's share of the head gradient."""
            _, vjp = jax.vjp(lambda hp: embed_fn(hp, tokens), head_p)
            (dhp,) = vjp(g)
            return dhp

        self._embed_bwd = jax.jit(embed_bwd_impl, donate_argnums=donate)

        def head_impl(head_p, hidden, labels):
            """hidden: (B, mb, S, D); labels: (B, mb, S).

            Per-microbatch losses (each the mean over its own tokens,
            matching the centralized per-microbatch loss), with one VJP
            giving the head gradient summed over the B microbatches and
            the per-microbatch hidden cotangents.
            """
            def f(hp, h):
                losses = jax.vmap(
                    lambda hh, ll: loss_fn(hp, hh, ll, cfg))(h, labels)
                return jnp.sum(losses), losses

            _, vjp, losses = jax.vjp(f, head_p, hidden, has_aux=True)
            g_head, g_hidden = vjp(jnp.float32(1.0))
            return losses, g_head, g_hidden

        self._head = jax.jit(head_impl)

    # ------------------------------------------------------------------
    def embed(self, head_params, tokens):
        self.embed_calls += 1
        return self._embed(head_params, tokens)

    def embed_backward(self, head_params, tokens, g):
        """Head-gradient contribution of the embedding lookup (the
        cotangent leaving stage 0's VJP)."""
        self.embed_bwd_calls += 1
        return self._embed_bwd(head_params, tokens, g)

    def forward(self, stage: int, params, x):
        """One dispatch of stage ``stage`` over a stacked batch."""
        self.fwd_calls[stage] += 1
        return self._fwd(params, x)

    def backward(self, stage: int, params, x, g) -> Tuple[Any, Any]:
        """Replay stage ``stage``'s VJP from its stored input ``x``."""
        self.bwd_calls[stage] += 1
        return self._bwd(params, x, g)

    def head_loss(self, head_params, hidden, labels):
        self.head_calls += 1
        return self._head(head_params, hidden, labels)

    # ------------------------------------------------------------------
    @property
    def stage_dispatches(self) -> int:
        """Total stage-level dispatches (each backward remats one
        forward, so this is the unit the recovery tests count in)."""
        return sum(self.fwd_calls) + sum(self.bwd_calls)

    def snapshot(self) -> Dict[str, Any]:
        return dict(fwd=list(self.fwd_calls), bwd=list(self.bwd_calls),
                    embed=self.embed_calls, embed_bwd=self.embed_bwd_calls,
                    head=self.head_calls)
