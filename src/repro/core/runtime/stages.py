"""Per-stage compute: fused forward+residual dispatch, VJP backward.

The pre-refactor executor jitted the *entire* model end-to-end per
microbatch (``jax.value_and_grad`` over all stages at once), which has
no pipeline-stage structure: a crash anywhere forced rerunning the
whole graph, and B microbatches cost B full-model dispatches.

`StageCompute` lowers each pipeline stage to jitted primitives:

* ``forward_fused(s, params, x)`` — ONE dispatch that runs the stage's
  transformer blocks *and* captures the VJP residuals: ``jax.vjp``
  inside jit returns ``(out, vjp_fn)`` where ``vjp_fn`` is a
  ``jax.tree_util.Partial`` whose leaves are the residual arrays.  The
  primal output is bit-identical to the plain forward.
* ``backward_from_residuals(s, residuals, g)`` — pulls the cotangent
  ``g`` back through the stored residuals to ``(dparams, dx)``
  *without recomputing the forward*.  This is the default backward.
* ``forward(s, params, x)`` / ``backward(s, params, x, g)`` — the
  rematerialising pair kept as the in-engine equality oracle:
  ``backward`` re-runs the *same* compiled residual-capturing forward
  program and then the *same* compiled VJP program, so its result is
  bit-identical to the fused path by construction (program
  composition, not a separately compiled ``jax.vjp`` graph).  It is
  also the paper's Sec. V-D repair primitive: any replica holding the
  stage weights and the upstream activation can (re)produce the
  stage's backward.

Microbatches of the same stage are stacked along the batch axis, so B
microbatches cost one dispatch per stage instead of B full-model
dispatches.  Cotangents are donated to the backward dispatch on
backends that support buffer donation (stored activations and
residuals are *not* donated — recovery may replay them).

Dispatch counters (``fwd_calls``/``bwd_calls`` per stage) are the
ground truth for the recovery tests: a backward crash must add exactly
one stage-level dispatch, not a full-pipeline recompute.  A remat
backward additionally bumps ``remat_recomputes`` for the hidden
forward it re-runs; the fused path never does.

One set of jitted kernels serves every ``(ModelConfig, donate)`` pair
process-wide (``stage_kernels`` is ``lru_cache``d), so tests, the
scenario harness's runtime leg, and fuzz share compiled programs
instead of recompiling per trainer instance.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.transformer import _apply_block, _init_block


# ---------------------------------------------------------------------------
# Stage modules (moved verbatim from the pre-refactor executor)
# ---------------------------------------------------------------------------

def stage_bounds(cfg: ModelConfig, stage: int, num_stages: int):
    per = cfg.num_layers // num_stages
    extra = cfg.num_layers - per * num_stages
    lo = stage * per + min(stage, extra)
    hi = lo + per + (1 if stage < extra else 0)
    return lo, hi


def init_stage_params(cfg: ModelConfig, stage: int, num_stages: int, key):
    """Blocks [lo, hi) of the model as one stage (stacked for scan)."""
    lo, hi = stage_bounds(cfg, stage, num_stages)
    keys = jax.random.split(jax.random.fold_in(key, stage), hi - lo)
    dtype = jnp.dtype(cfg.param_dtype)
    return jax.vmap(lambda kk: _init_block(kk, cfg, dtype))(keys)


def stage_forward(stage_params, x, cfg: ModelConfig):
    positions = jnp.arange(x.shape[1])

    def body(carry, bp):
        h, _aux, _ = _apply_block(bp, carry, cfg, positions=positions,
                                  window=None, cache=None, write_index=None,
                                  kv_valid=None, moe_impl="dense",
                                  use_kernel=False)
        return h, None

    out, _ = jax.lax.scan(body, x, stage_params)
    return out


def init_head_params(cfg: ModelConfig, key):
    """Data-node module: embedding + final norm + LM head."""
    return {"embed": L.init_embed(key, cfg, jnp.dtype(cfg.param_dtype)),
            "final_norm": L.init_norm(cfg)}


def embed_fn(head_params, tokens):
    return L.embed_tokens(head_params["embed"], tokens)


def loss_fn(head_params, hidden, labels, cfg: ModelConfig):
    h = L.apply_norm(head_params["final_norm"], hidden, cfg)
    return L.chunked_xent_loss(head_params["embed"], h, labels, cfg)


def _donate_supported(backend: Optional[str] = None) -> bool:
    """Whether the (given or default) backend honours buffer donation.

    CPU silently ignores donation, so the flag is only *useful* on
    accelerators — but both code paths must stay correct everywhere;
    ``StageCompute(donate=...)`` can force either branch for tests.
    """
    b = backend if backend is not None else jax.default_backend()
    return b in ("gpu", "cuda", "rocm", "tpu")


class StageKernels(NamedTuple):
    """The jitted primitives for one ``(ModelConfig, donate)`` pair."""
    fwd: Any          # (p, x) -> out
    fwd_res: Any      # (p, x) -> (out, vjp Partial)   [residual capture]
    bwd_res: Any      # (vjp, g) -> (dp, dx)           [consumes residuals]
    embed: Any
    embed_bwd: Any
    head: Any


@lru_cache(maxsize=None)
def stage_kernels(cfg: ModelConfig, donate: bool) -> StageKernels:
    """Build (once per process) the jitted kernels for ``cfg``.

    jax retraces per parameter shape, so one kernel set serves every
    stage and every stage count; the cache key is the hashable frozen
    ``ModelConfig`` plus the donation flag.
    """
    fwd = jax.jit(lambda p, x: stage_forward(p, x, cfg))

    def fwd_res_impl(p, x):
        # jax.vjp inside jit: the returned closure is a
        # jax.tree_util.Partial whose leaves are the residual arrays —
        # it round-trips the jit boundary as a pytree and can be fed
        # to bwd_res (possibly quantized in between).
        out, vjp = jax.vjp(lambda pp, xx: stage_forward(pp, xx, cfg), p, x)
        return out, vjp

    fwd_res = jax.jit(fwd_res_impl)

    def bwd_res_impl(vjp, g):
        dp, dx = vjp(g)
        return dp, dx

    g_donate = (1,) if donate else ()
    bwd_res = jax.jit(bwd_res_impl, donate_argnums=g_donate)
    embed = jax.jit(embed_fn)

    def embed_bwd_impl(head_p, tokens, g):
        """Pull the stage-0 input cotangent back through the token
        embedding: the data node's share of the head gradient."""
        _, vjp = jax.vjp(lambda hp: embed_fn(hp, tokens), head_p)
        (dhp,) = vjp(g)
        return dhp

    embed_bwd = jax.jit(embed_bwd_impl,
                        donate_argnums=(2,) if donate else ())

    def head_impl(head_p, hidden, labels):
        """hidden: (B, mb, S, D); labels: (B, mb, S).

        Per-microbatch losses (each the mean over its own tokens,
        matching the centralized per-microbatch loss), with one VJP
        giving the head gradient summed over the B microbatches and
        the per-microbatch hidden cotangents.
        """
        def f(hp, h):
            losses = jax.vmap(
                lambda hh, ll: loss_fn(hp, hh, ll, cfg))(h, labels)
            return jnp.sum(losses), losses

        _, vjp, losses = jax.vjp(f, head_p, hidden, has_aux=True)
        g_head, g_hidden = vjp(jnp.float32(1.0))
        return losses, g_head, g_hidden

    head = jax.jit(head_impl)
    return StageKernels(fwd, fwd_res, bwd_res, embed, embed_bwd, head)


class StageCompute:
    """Per-stage primitives + dispatch accounting.

    Kernels are shared process-wide via :func:`stage_kernels`; counters
    are per instance and tracked at the call sites so recovery tests
    can pin exactly which stage recomputed and session-cached kernels
    cannot leak dispatch state across trainers or tests.
    """

    def __init__(self, cfg: ModelConfig, num_stages: int, *,
                 donate: Optional[bool] = None):
        self.cfg = cfg
        self.num_stages = num_stages
        self.donate = _donate_supported() if donate is None else donate
        self.fwd_calls: List[int] = [0] * num_stages
        self.bwd_calls: List[int] = [0] * num_stages
        self.remat_recomputes: List[int] = [0] * num_stages
        self.embed_calls = 0
        self.embed_bwd_calls = 0
        self.head_calls = 0
        self._k = stage_kernels(cfg, self.donate)

    # ------------------------------------------------------------------
    def embed(self, head_params, tokens):
        self.embed_calls += 1
        return self._k.embed(head_params, tokens)

    def embed_backward(self, head_params, tokens, g):
        """Head-gradient contribution of the embedding lookup (the
        cotangent leaving stage 0's VJP)."""
        self.embed_bwd_calls += 1
        return self._k.embed_bwd(head_params, tokens, g)

    def forward(self, stage: int, params, x):
        """One plain dispatch of stage ``stage`` over a stacked batch
        (no residual capture — the remat path and forward repairs)."""
        self.fwd_calls[stage] += 1
        return self._k.fwd(params, x)

    def forward_fused(self, stage: int, params, x) -> Tuple[Any, Any]:
        """One fused dispatch: ``(output, residuals)``.  The output is
        bit-identical to :meth:`forward`; the residuals (a
        ``jax.tree_util.Partial``) feed :meth:`backward_from_residuals`
        so the backward never re-runs the forward."""
        self.fwd_calls[stage] += 1
        return self._k.fwd_res(params, x)

    def backward_from_residuals(self, stage: int, residuals, g
                                ) -> Tuple[Any, Any]:
        """Stage ``stage``'s VJP from stored residuals: zero forward
        recompute.  ``g`` is donated when ``self.donate``."""
        self.bwd_calls[stage] += 1
        return self._k.bwd_res(residuals, g)

    def backward(self, stage: int, params, x, g) -> Tuple[Any, Any]:
        """Rematerialising backward: replay stage ``stage``'s VJP from
        its stored input ``x``.

        Composed from the *same* compiled programs as the fused path
        (residual-capturing forward, then residual-consuming VJP), so
        fused and remat gradients are bit-identical — the in-engine
        equality oracle.  Counts one logical backward dispatch plus
        one ``remat_recomputes`` for the hidden forward.
        """
        self.bwd_calls[stage] += 1
        self.remat_recomputes[stage] += 1
        _, vjp = self._k.fwd_res(params, x)
        return self._k.bwd_res(vjp, g)

    def head_loss(self, head_params, hidden, labels):
        self.head_calls += 1
        return self._k.head(head_params, hidden, labels)

    # ------------------------------------------------------------------
    @property
    def stage_dispatches(self) -> int:
        """Total logical stage-level dispatches (one per forward, one
        per backward — the unit the recovery tests count in; remat's
        hidden forward recompute is reported separately)."""
        return sum(self.fwd_calls) + sum(self.bwd_calls)

    @property
    def remat_recompute_count(self) -> int:
        """Forward recomputes hidden inside remat backwards — 0 on the
        fused path by construction."""
        return sum(self.remat_recomputes)

    def snapshot(self) -> Dict[str, Any]:
        return dict(fwd=list(self.fwd_calls), bwd=list(self.bwd_calls),
                    remat=list(self.remat_recomputes),
                    embed=self.embed_calls, embed_bwd=self.embed_bwd_calls,
                    head=self.head_calls)
