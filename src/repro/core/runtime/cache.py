"""Process-wide caches for the staged runtime's expensive setup.

Two things dominate runtime-test and scenario-fuzz wall time on CPU:
XLA compilation of the stage kernels and model-parameter init.  Both
are pure functions of hashable inputs (the frozen ``ModelConfig``,
stage count, seed), so they are memoised here and shared by every
trainer, test, and harness leg in the process:

* :func:`kernels` — the jitted stage primitives, keyed on
  ``(ModelConfig, donate)`` (delegates to the ``lru_cache`` in
  :mod:`repro.core.runtime.stages`);
* :func:`initial_params` — per-stage parameter pytrees + the data-node
  head, keyed on ``(ModelConfig, num_stages, seed)``.  JAX arrays are
  immutable and trainers replace (never mutate) their parameter trees
  on update, so sharing the initial trees cannot leak training state
  across cache hits — ``tests/test_fused_runtime.py`` pins that.

``StageCompute`` instances are intentionally NOT cached: their
dispatch counters are per-trainer ground truth for the recovery tests.
Construction is cheap once the kernels behind them are cached.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Tuple

import jax

from repro.models.config import ModelConfig
from repro.core.runtime.stages import (init_head_params, init_stage_params,
                                       stage_kernels)


def kernels(cfg: ModelConfig, donate: bool):
    """The shared jitted kernel set for ``cfg`` (compiled lazily per
    input shape, once per process)."""
    return stage_kernels(cfg, donate)


@lru_cache(maxsize=None)
def initial_params(cfg: ModelConfig, num_stages: int, seed: int = 0
                   ) -> Tuple[tuple, dict]:
    """Seeded initial parameters: ``(stage_param_trees, head_params)``.

    Key derivation matches the historical trainer init exactly
    (``PRNGKey(seed)`` folded per stage; head at ``fold_in(key, 999)``)
    so cached and uncached trainers are bit-identical.
    """
    key = jax.random.PRNGKey(seed)
    stage_p = tuple(init_stage_params(cfg, s, num_stages, key)
                    for s in range(num_stages))
    head_p = init_head_params(cfg, jax.random.fold_in(key, 999))
    return stage_p, head_p


def cache_info() -> dict:
    return {"kernels": stage_kernels.cache_info()._asdict(),
            "initial_params": initial_params.cache_info()._asdict()}


def clear() -> None:
    stage_kernels.cache_clear()
    initial_params.cache_clear()
