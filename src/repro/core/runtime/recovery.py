"""Crash injection and stage-local repair for the real-compute runtime.

The pre-refactor executor hand-rolled Bernoulli churn (one uniform per
node plus an ad-hoc ``integers(0, 2)`` "crash budget") and faked the
crash by renaming the relay before a monolithic full-model dispatch.
This module drives the runtime's faults through the *same* layers the
event simulator uses:

* crashes/rejoins are sampled by a :class:`repro.core.sim.faults.ChurnModel`
  (the trainer builds the ``ChurnContext``), so every churn scenario the
  simulator supports — Bernoulli, trace replay, correlated regional
  outages, compositions — runs against real compute unchanged;
* repair decisions come from a :class:`repro.core.sim.policies.RoutingPolicy`
  via the same ``recover(view, mb, frm, dead, t)`` entry point, against
  a :class:`~repro.core.sim.policies.FaultView` built over the real
  network.

Timing model
------------
The runtime executes a synchronous pipeline flush: stage-major forward
(stage 0 for every microbatch, then stage 1, ...), the loss at the data
node, then stage-major backward.  That sweep *is* the iteration's
timeline: visiting stage ``s`` forward happens at normalized time
``(s+1)/(2S)``, stage ``s`` backward at ``(2S-s)/(2S)``.  A churn
model's crash times (sampled against ``horizon=1.0``) place each crash
at a point in that sweep, so a relay serves every visit before its
crash moment and fails every visit after it — mid-iteration faults
with both forward- and backward-phase crashes, derived from the same
crash-time vocabulary the simulator uses.  Each repair advances the
microbatch by a small discovery penalty (the sender's timeout), so a
repaired microbatch can be hit again later in the sweep.

Repair semantics (paper Sec. V-D, now real)
-------------------------------------------
* forward crash at stage ``s``: the policy reroutes to a same-stage
  substitute, which recomputes *only* stage ``s`` from the stored
  input activation (``fwd_recomputes``);
* backward crash at stage ``s``: the substitute replays that stage's
  VJP (``bwd_replays``) — never a full-pipeline recompute.  Since the
  fused dispatch rework the replay consumes the *stored (possibly
  quantized) VJP residuals* of the chunk directly, so repair costs
  zero forward recomputes; the remat oracle path falls back to
  replaying from the stored input activation;
* policy says ``("fail",)`` (no live same-stage candidate, retries
  exhausted, or a no-reroute policy like ``FixedPolicy``): instead of
  silently dropping the microbatch, the manager requeues it onto
  another planned complete-flow chain from the same data node whose
  remaining relays are still expected alive (``requeued``, reported as
  part of ``rerouted``).  Only when no such chain exists is the
  microbatch dropped.

Beyond fail-stop: the deadline defense
--------------------------------------
When the churn model publishes an :class:`~repro.core.sim.faults.AdversarialPlan`
(hung nodes, deadline-catchable stragglers), ``resolve`` mirrors the
simulator's deadline-triggered re-dispatch: a visit to a hung relay —
or to a straggler slow enough that the healthy-estimate deadline is
guaranteed to fire (``leg_time * (factor - 1) > timeout``, the same
predicate the sim engine applies) — is detected at the sender's
timeout, recorded on the shared :class:`~repro.core.sim.timeline.FaultTimeline`,
and re-dispatched through the same substitute/requeue machinery as a
crash (counted in ``Resolution.deadline_requeues``).  The policy's
view marks hung/catchable nodes crashed-at-0 (exactly like the sim
engine) so recovery never substitutes onto one.  With
``deadline_defense=False`` a hung relay wedges its microbatch for the
whole iteration (dropped), and a slow one is simply waited out — the
undefended baseline the adversarial benchmarks compare against.
Detected nodes are reported in ``Resolution.rep_reports`` for the
trainer's reputation update (quarantine).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.core.flow.graph import FlowNetwork
from repro.core.sim.faults import AdversarialPlan
from repro.core.sim.policies import FaultView, RoutingPolicy
from repro.core.sim.timeline import FaultTimeline


@dataclass
class Job:
    """One microbatch's assignment for the iteration."""
    index: int                    # iteration-local id == batch row group
    data_node: int
    mb: dict                      # {"tokens", "labels"}
    chain: List[int]              # [dn, r_0, ..., r_{S-1}, dn]
    penalty: float = 0.0          # accumulated repair-discovery delay
    retries: int = 0
    failed_stage: int = -1
    failed_dir: str = ""


@dataclass
class RepairEvent:
    """One observed crash + its resolution (drives the lost-work
    dispatches of the numeric pass)."""
    job: int
    stage: int
    direction: str                # "fwd" | "bwd"
    dead: int
    substitute: Optional[int] = None   # None -> dropped
    requeued: bool = False


@dataclass
class Resolution:
    """Outcome of the bookkeeping sweep: who completed, who was
    repaired where, and what it cost."""
    completed: List[Job] = field(default_factory=list)
    dropped: int = 0
    rerouted: int = 0             # successful repairs (substitute or requeue)
    requeued: int = 0             # subset of rerouted: adopted another chain
    fwd_recomputes: int = 0
    bwd_replays: int = 0
    events: List[RepairEvent] = field(default_factory=list)
    deadline_requeues: int = 0    # subset of rerouted: re-dispatches fired
    #   by the sender's deadline on a hung/straggling (alive) relay
    rep_reports: List[int] = field(default_factory=list)
    #   detection-attributed nodes for the reputation update


class _MBView:
    """The slice of the simulator's ``_MB`` a policy's ``recover``
    reads: direction + data node (GWTF) and the restart origin
    (SWARM)."""
    __slots__ = ("id", "data_node", "direction", "path")

    def __init__(self, job: Job):
        self.id = job.index
        self.data_node = job.data_node
        self.direction = "fwd"
        self.path = job.chain


class RecoveryManager:
    """Resolves one iteration's crashes against the routing policy."""

    def __init__(self, net: FlowNetwork, policy: RoutingPolicy, *,
                 max_retries: int = 2, timeout: float = 30.0,
                 deadline_defense: bool = True):
        self.net = net
        self.policy = policy
        self.max_retries = max_retries
        # sender-side deadline window (seconds, same default as the sim
        # engine): drives the catchable-straggler predicate below
        self.timeout = timeout
        self.deadline_defense = deadline_defense

    # ------------------------------------------------------------------
    def build_view(self, crash_frac: Dict[int, float],
                   blocked: Sequence[int] = ()) -> FaultView:
        """A ``FaultView`` over the real network on the normalized
        iteration clock: ``crash[nid]`` is the crash moment in [0, 1]
        (inf for survivors); the runtime has no capacity queues, so
        ``busy``/``queues`` are empty and the policy's load penalty
        vanishes.  ``blocked`` nodes (hung / deadline-catchable
        stragglers) are marked crashed-at-0 in the view — the policy's
        *opinion* only, not the engine's liveness tables — so recovery
        never substitutes a microbatch onto one (the sim engine applies
        the identical view trick)."""
        net = self.net
        N = (max(net.nodes) + 1) if net.nodes else 0
        view = FaultView()
        view.net = net
        view.activation_bytes = net.activation_size
        alive = [False] * N
        fwd_t = [0.05] * N
        for nid, node in net.nodes.items():
            alive[nid] = node.alive
            fwd_t[nid] = max(0.05, node.compute_cost)
        view.alive = alive
        crash = [float("inf")] * N
        for nid, f in crash_frac.items():
            crash[nid] = f
        for nid in blocked:
            if nid < N:
                crash[nid] = 0.0
        view.crash = crash
        view.busy = [0] * N
        view.queues = [()] * N
        view.fwd_t = fwd_t
        view.bwd_t = [2.0 * c for c in fwd_t]
        view.comm_rows = net.comm_matrix().tolist()
        view.edge_rows = net.edge_matrix().tolist()
        cache: Dict[int, list] = {}

        def stage_nodes(s: int) -> list:
            nodes = cache.get(s)
            if nodes is None:
                nodes = net.stage_nodes(s)
                cache[s] = nodes
            return nodes

        view.stage_nodes = stage_nodes
        return view

    # ------------------------------------------------------------------
    def resolve(self, jobs: Sequence[Job], chains: Sequence[Sequence[int]],
                crash_times: Dict[int, float], horizon: float,
                adv: Optional[AdversarialPlan] = None,
                timeline: Optional[FaultTimeline] = None,
                iteration: int = 0) -> Resolution:
        """Sweep the iteration's visits through the crash plan.

        ``chains`` is the full planned chain set (assigned + spare);
        requeue candidates come from it.  Pure bookkeeping: the numeric
        pass afterwards executes exactly the completed set plus the
        recorded lost-work dispatches.  ``adv`` (when the churn model
        publishes one) adds hung/straggling relays to the sweep;
        detections and repairs land on ``timeline`` at ``iteration``.
        """
        S = self.net.num_stages
        frac = {nid: max(0.0, min(1.0, t / horizon))
                for nid, t in crash_times.items()}
        res = Resolution()
        self._frac = frac
        self._chains = [list(c) for c in chains]
        self._timeline = timeline
        self._iteration = iteration
        # adversarial stall sets, per direction.  Hung nodes stall any
        # visit; a straggler stalls a visit only when the slowed leg is
        # guaranteed past the healthy-estimate deadline — the sim
        # engine's catchability predicate, on this layer's own
        # fwd_t/bwd_t tables.
        self._hung = frozenset(adv.hung) if adv is not None else frozenset()
        slow = adv.slow if adv is not None else {}
        catch_f, catch_b = set(), set()
        for nid, f in slow.items():
            node = self.net.nodes.get(nid)
            if node is None:
                continue
            leg = max(0.05, node.compute_cost)
            if leg * (f - 1.0) > self.timeout:
                catch_f.add(nid)
            if 2.0 * leg * (f - 1.0) > self.timeout:
                catch_b.add(nid)
        self._stall_fwd = self._hung | frozenset(catch_f)
        self._stall_bwd = self._hung | frozenset(catch_b)
        # the policy's view blocks exactly the nodes the *forward*
        # predicate catches (the sim engine blocks the same set)
        blocked = self._stall_fwd if self.deadline_defense else frozenset()
        view = self.build_view(frac, sorted(blocked))
        self._view = view
        self._blocked = blocked

        live = list(jobs)
        for s in range(S):                       # forward sweep
            t = (s + 1) / (2 * S)
            live = [j for j in live
                    if self._visit(j, s, "fwd", t, res)]
        # loss at the data node (data nodes do not churn), turn around
        for s in reversed(range(S)):             # backward sweep
            t = (2 * S - s) / (2 * S)
            live = [j for j in live
                    if self._visit(j, s, "bwd", t, res)]
        res.completed = live
        return res

    # ------------------------------------------------------------------
    def _dead_at(self, nid: int, t: float) -> bool:
        f = self._frac.get(nid)
        return f is not None and f <= t

    def _record(self, fault: str, kind: str, node: int):
        if self._timeline is not None:
            self._timeline.record(self._iteration, fault, kind, node)

    def _visit(self, job: Job, s: int, direction: str, t: float,
               res: Resolution) -> bool:
        relay = job.chain[s + 1]
        stall = self._stall_fwd if direction == "fwd" else self._stall_bwd
        while True:
            now = min(1.0, t + job.penalty)
            dead = self._dead_at(relay, now)
            stalled = not dead and relay in stall
            if not dead and not stalled:
                return True                       # visit served
            if stalled:
                if not self.deadline_defense:
                    if relay in self._hung:
                        # no deadline fires: the hung relay wedges the
                        # microbatch for the whole iteration
                        job.failed_stage, job.failed_dir = s, direction
                        res.dropped += 1
                        return False
                    return True   # undefended straggler: waited out
                # sender's deadline fires on an alive-but-useless relay
                self._record("straggler", "detection", relay)
                res.rep_reports.append(relay)
            ev = RepairEvent(job.index, s, direction, relay)
            res.events.append(ev)
            job.retries += 1
            decision = ("fail",)
            if job.retries <= self.max_retries:
                mbv = _MBView(job)
                mbv.direction = direction
                frm = job.chain[s] if direction == "fwd" else job.chain[s + 2]
                decision = self.policy.recover(self._view, mbv, frm,
                                               relay, now)
            # discovery penalty: the sender's timeout window, half a
            # stage slot on the normalized clock
            job.penalty += 0.5 / (2 * self.net.num_stages)
            now = min(1.0, t + job.penalty)
            if decision[0] == "substitute":
                sub = decision[1]
                if not self._dead_at(sub, now):
                    job.chain[s + 1] = sub
                    ev.substitute = sub
                    res.rerouted += 1
                    self._count_recompute(direction, res)
                    if stalled:
                        res.deadline_requeues += 1
                        self._record("straggler", "repair", relay)
                    relay = sub
                    continue
                relay = sub                       # substitute died too
                continue
            if decision[0] == "restart":
                # SWARM-style full restart is requeue-from-the-data-node
                # in the flush schedule; fall through to the requeue
                # search (which restarts on a live chain) so no policy
                # silently drops a saveable microbatch.
                pass
            nc = self._find_requeue_chain(job, s, direction, now)
            if nc is None:
                job.failed_stage, job.failed_dir = s, direction
                res.dropped += 1
                return False
            job.chain = list(nc)
            ev.substitute = job.chain[s + 1]
            ev.requeued = True
            res.rerouted += 1
            res.requeued += 1
            self._count_recompute(direction, res)
            if stalled:
                res.deadline_requeues += 1
                self._record("straggler", "repair", relay)
            relay = job.chain[s + 1]

    # ------------------------------------------------------------------
    # Lost-work dispatch (the numeric side of each recorded crash)
    # ------------------------------------------------------------------
    @staticmethod
    def replay_lost(stages, store, stage_params, res: Resolution,
                    s: int, direction: str, *, ids: Sequence[int],
                    cotangent=None, per: int = 0,
                    remat: bool = False) -> None:
        """Dispatch the dead replica's lost work for each crash recorded
        at stage ``s`` within the chunk ``ids``.

        * forward crash: one wasted stage forward from the stored
          boundary activation (``store.get``);
        * backward crash, fused mode: one wasted VJP replay **from the
          stored (possibly quantized) residuals** of the chunk — zero
          forward recomputes, the post-rework repair primitive;
        * backward crash, remat mode (or residuals already dropped):
          one wasted rematerialising VJP from the stored boundary
          activation, as before.

        Results are discarded — the substitute's (identical)
        computation lives in the batch — but the wall time and the
        dispatch counters are real, which is what the recovery
        benchmarks and tests measure.  Cotangents handed to replay
        dispatches are copied first: the real backward donates (and
        reuses) the live buffer on donating backends.
        """
        import jax.numpy as jnp

        ids = tuple(ids)
        for ev in res.events:
            if ev.stage != s or ev.direction != direction:
                continue
            if ev.job not in ids:
                continue    # dropped, or belongs to another chunk
            if direction == "fwd":
                try:
                    xin = store.get(s, ev.job)
                except KeyError:
                    continue
                stages.forward(s, stage_params[s], xin)
                continue
            if cotangent is None:
                continue
            if not remat and store.has_residuals(s, ids):
                stages.backward_from_residuals(
                    s, store.residuals(s, ids), jnp.copy(cotangent))
                continue
            try:
                xin = store.get(s, ev.job)
            except KeyError:
                continue
            k = ids.index(ev.job)
            stages.backward(s, stage_params[s], xin,
                            jnp.copy(cotangent[k * per:(k + 1) * per]))

    @staticmethod
    def _count_recompute(direction: str, res: Resolution) -> None:
        if direction == "fwd":
            res.fwd_recomputes += 1
        else:
            res.bwd_replays += 1

    def _find_requeue_chain(self, job: Job, s: int, direction: str,
                            t: float) -> Optional[List[int]]:
        """Another planned complete-flow chain from the same data node
        whose relays for the *remaining* legs are expected alive at
        ``t`` — the stored stage-``s`` activation moves there and the
        microbatch continues instead of being dropped."""
        S = self.net.num_stages
        for chain in self._chains:
            # sharing a chain already carrying another microbatch is
            # fine: replicas are identical and the runtime does not
            # model slot capacity (the simulator answers "how long")
            if chain[0] != job.data_node or chain == job.chain:
                continue
            if direction == "fwd":
                remaining = chain[s + 1:S + 1]
            else:
                remaining = chain[1:s + 2]
            if all(self.net.nodes[r].alive and not self._dead_at(r, t)
                   and r not in self._blocked
                   for r in remaining):
                return chain
        return None
