"""ServeTrainer: real-compute decode executor over the flow engine's chains.

The serving analogue of `runtime/trainer.py`: where `RuntimeTrainer`
executes the simulator's *training* plans with real JAX compute, the
`ServeTrainer` executes the :class:`ServingEngine`'s per-request decode
schedules with real token streams.  The trainer embeds its own
`ServingEngine` instance — constructed from an independently built
policy/churn stream mirroring `build_serving_sim` — so the serving
differential check can pin per-iteration chain plans, request
conservation, and TTFT/TPOT to exact equality between the two layers.

Continuous batching reuses the same-stage stacking trick from
`stages.py`: sequences decoding at the same token index on the same
chain are stacked along the batch axis into ONE `decode_step` dispatch
(caches stacked/split with `tree_map`), which on this backend is
bit-identical to decoding each row alone — the same property the
training runtime's per-stage microbatch stacking rests on.  Dispatch
counters (`decode_dispatches`, `stacked_rows`) are the ground truth
for the batching tests, exactly like `StageCompute.fwd_calls`.

Crash-mid-decode recovery is requeue-instead-of-drop: the engine
reroutes the in-flight sequence to a surviving chain, and the executor
rebuilds the migrated KV cache by *teacher-forced replay* — prefill
the prompt, then re-run `decode_step` over the already-generated
tokens.  Replay repeats the exact ops the original incremental decode
ran, so the rebuilt cache (and every subsequent logit) is bit-identical
by construction and the token stream continues exactly where it left
off.  (Re-prefilling prompt+tokens in one `prefill` call is *not*
bitwise-stable against incremental decode — full-sequence attention
associates differently — which is why replay is the repair primitive,
mirroring `StageCompute.backward`'s replay-the-same-programs
discipline.)  `FaultTimeline` records the serving crashes verbatim
through the embedded engine.

Seeding: `serving_keys`/`serving_inputs` split one root PRNGKey into
independent params / prompt / aux-input / sampling keys — shared with
`launch/serve.py`, so a zero-churn ServeTrainer run decodes the exact
token streams of the standalone serving CLI on the same reduced config.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.flow.graph import FlowNetwork
from repro.core.sim.engine import ServingEngine
from repro.core.sim.faults import ChurnModel
from repro.core.sim.metrics import ModelProfile, ServingIterationMetrics
from repro.core.sim.policies import RoutingPolicy


def serving_keys(seed: int):
    """Independent RNG keys for the serving setup.

    One root `PRNGKey(seed)` split four ways: parameter init, prompt
    synthesis, auxiliary modality inputs (vision tokens / audio
    embeddings), and sampling.  `launch/serve.py` and `ServeTrainer`
    both consume exactly this split, which is what makes their decode
    paths bit-comparable under one seed.
    """
    import jax

    root = jax.random.PRNGKey(seed)
    k_params, k_prompt, k_aux, k_sample = jax.random.split(root, 4)
    return k_params, k_prompt, k_aux, k_sample


def serving_inputs(cfg, *, seed: int, batch: int, prompt_len: int):
    """Seeded `(params, prompt, vision, embeds, sample_key)` setup.

    Each draw consumes its own key from :func:`serving_keys` — the
    pre-fix serving driver reused one unsplit key for all four, which
    correlated the parameter init with the synthetic prompts.
    """
    import jax

    from repro.models.transformer import init_params

    k_params, k_prompt, k_aux, k_sample = serving_keys(seed)
    params = init_params(cfg, k_params)
    prompt = jax.random.randint(k_prompt, (batch, prompt_len), 0,
                                cfg.vocab_size)
    vision = (jax.random.normal(k_aux, (batch, cfg.num_image_tokens,
                                        cfg.vision_dim))
              if cfg.arch_type == "vlm" else None)
    embeds = (jax.random.normal(k_aux, (batch, prompt_len, cfg.d_model))
              if cfg.audio_frontend else None)
    return params, prompt, vision, embeds, k_sample


class _Seq:
    """One request's executor-side decode state."""

    __slots__ = ("rid", "chain", "stream", "cache", "live")

    def __init__(self, rid: int):
        self.rid = rid
        self.chain: Optional[Tuple[int, ...]] = None
        self.stream: List[int] = []        # greedy tokens generated so far
        self.cache: Any = None             # batch-1 KV cache pytree
        self.live = False                  # cache currently valid


class ServeTrainer:
    """Staged decode executor driven by an embedded `ServingEngine`.

    Each `iteration()` first advances the engine (churn sample, chain
    plan, analytic request schedule), then executes the schedule with
    real compute: batched prefills for admission cohorts, stacked
    `decode_step` dispatches for same-index same-chain cohorts, and
    teacher-forced cache replay for requeued sequences.  Token streams
    land in `token_stream(rid)`; scheduling metrics pass through from
    the engine unchanged (the executor adds no timing of its own —
    simulated time is the engine's job, real compute is ours).
    """

    def __init__(self, cfg, net: FlowNetwork, *,
                 policy: RoutingPolicy,
                 arrival_program: List[List[float]],
                 churn_model: Optional[ChurnModel] = None,
                 profile: Optional[ModelProfile] = None,
                 prompt_len: int = 8, gen_tokens: int = 8,
                 serve_batch: int = 4, tokens_per_mb: int = 128,
                 timeout: float = 5.0, reroute: bool = True,
                 max_restarts: int = 5,
                 rng: Optional[np.random.Generator] = None,
                 seed: int = 0, max_requests: int = 64):
        self.cfg = cfg
        self.net = net
        self.engine = ServingEngine(
            net, policy, arrival_program=arrival_program,
            churn_model=churn_model, profile=profile,
            prompt_len=prompt_len, gen_tokens=gen_tokens,
            serve_batch=serve_batch, tokens_per_mb=tokens_per_mb,
            timeout=timeout, reroute=reroute, max_restarts=max_restarts,
            rng=rng)
        self.timeline = self.engine.timeline
        self.prompt_len = int(prompt_len)
        self.gen_tokens = int(gen_tokens)
        self.cache_len = self.prompt_len + self.gen_tokens
        self.seed = int(seed)
        self.max_requests = int(max_requests)
        self.params, self._prompts, _, _, _ = serving_inputs(
            cfg, seed=seed, batch=max_requests, prompt_len=prompt_len)
        self._seqs: Dict[int, _Seq] = {}
        self._cache_axes = None            # per-leaf batch axis, lazy
        # dispatch accounting (the batching tests' ground truth)
        self.prefill_calls = 0
        self.decode_dispatches = 0
        self.stacked_rows = 0
        self.replay_steps = 0              # teacher-forced cache rebuilds

    # ------------------------------------------------------------------
    def _prompt_row(self, rid: int):
        """Prompt tokens for request ``rid`` (row of the shared seeded
        batch; overflow requests fold the rid into the prompt key so
        arbitrarily many arrivals stay deterministic)."""
        import jax

        if rid < self.max_requests:
            return self._prompts[rid:rid + 1]
        _, k_prompt, _, _ = serving_keys(self.seed)
        return jax.random.randint(jax.random.fold_in(k_prompt, rid),
                                  (1, self.prompt_len), 0,
                                  self.cfg.vocab_size)

    def _seq(self, rid: int) -> _Seq:
        s = self._seqs.get(rid)
        if s is None:
            s = self._seqs[rid] = _Seq(rid)
        return s

    def _stack(self, rows: List[Any]):
        """Stack batch-1 cache pytrees along each leaf's batch axis."""
        import jax
        import jax.numpy as jnp

        if self._cache_axes is None:
            self._cache_axes = _batch_axes(self.cfg, self.cache_len)
        flat = [jax.tree_util.tree_flatten(r) for r in rows]
        treedef = flat[0][1]
        leaves = [jnp.concatenate([f[0][i] for f in flat], axis=ax)
                  for i, ax in enumerate(self._cache_axes)]
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def _split(self, cache: Any, batch: int) -> List[Any]:
        """Split a batch-B cache pytree back into B batch-1 rows."""
        import jax
        import jax.numpy as jnp

        if self._cache_axes is None:
            self._cache_axes = _batch_axes(self.cfg, self.cache_len)
        leaves, treedef = jax.tree_util.tree_flatten(cache)
        return [jax.tree_util.tree_unflatten(
                    treedef,
                    [jax.lax.slice_in_dim(x, b, b + 1, axis=ax)
                     for x, ax in zip(leaves, self._cache_axes)])
                for b in range(batch)]

    # -- stacked primitives ---------------------------------------------
    def _prefill_cohort(self, seqs: List[_Seq]):
        """One stacked prefill dispatch for an admission cohort."""
        import jax
        import jax.numpy as jnp

        from repro.models.transformer import init_cache, prefill

        B = len(seqs)
        tokens = jnp.concatenate([self._prompt_row(s.rid) for s in seqs],
                                 axis=0)
        cache = init_cache(self.cfg, B, self.cache_len, dtype=jnp.float32)
        logits, cache = prefill(self.params, self.cfg, tokens=tokens,
                                cache=cache)
        self.prefill_calls += 1
        first = jnp.argmax(logits, -1)
        rows = self._split(cache, B)
        for b, s in enumerate(seqs):
            s.cache = rows[b]
            s.live = True
            s.stream = [int(first[b])]

    def _decode_cohort(self, seqs: List[_Seq], index: int):
        """ONE stacked `decode_step` dispatch: every sequence in the
        cohort sits at the same token index (the same-stage stacking
        trick applied to serving)."""
        import jax
        import jax.numpy as jnp

        from repro.models.transformer import decode_step

        B = len(seqs)
        tok = jnp.asarray([[s.stream[-1]] for s in seqs], dtype=jnp.int32)
        cache = self._stack([s.cache for s in seqs])
        logits, cache = decode_step(self.params, self.cfg, tokens=tok,
                                    cache=cache, index=jnp.int32(index))
        self.decode_dispatches += 1
        self.stacked_rows += B
        nxt = jnp.argmax(logits, -1)
        rows = self._split(cache, B)
        for b, s in enumerate(seqs):
            s.cache = rows[b]
            s.stream.append(int(nxt[b]))

    def _replay_cache(self, s: _Seq):
        """Rebuild a migrated/evicted sequence's KV cache bit-exactly:
        prefill the prompt, then teacher-force the generated tokens
        through the same `decode_step` programs the original run used.
        """
        import jax
        import jax.numpy as jnp

        from repro.models.transformer import decode_step, init_cache, prefill

        cache = init_cache(self.cfg, 1, self.cache_len, dtype=jnp.float32)
        _, cache = prefill(self.params, self.cfg,
                           tokens=self._prompt_row(s.rid), cache=cache)
        self.prefill_calls += 1
        for j in range(len(s.stream) - 1):
            tok = jnp.asarray([[s.stream[j]]], dtype=jnp.int32)
            _, cache = decode_step(self.params, self.cfg, tokens=tok,
                                   cache=cache,
                                   index=jnp.int32(self.prompt_len + j))
            self.replay_steps += 1
        s.cache = cache
        s.live = True

    # ------------------------------------------------------------------
    def _advance(self, targets: Dict[int, int]):
        """Decode every sequence up to its target token count with
        same-index same-chain cohorts stacked into single dispatches."""
        pending = {rid: tgt for rid, tgt in targets.items()
                   if tgt > len(self._seq(rid).stream)}
        # admissions first: fresh sequences need their prefill token
        fresh: Dict[Tuple[int, ...], List[_Seq]] = {}
        for rid in sorted(pending):
            s = self._seq(rid)
            if not s.stream and not s.live:
                fresh.setdefault(s.chain or (), []).append(s)
        for cohort in fresh.values():
            self._prefill_cohort(cohort)
        # then decode rounds: group by (chain, current index)
        while True:
            groups: Dict[Tuple[Tuple[int, ...], int], List[_Seq]] = {}
            for rid, tgt in sorted(pending.items()):
                s = self._seq(rid)
                if len(s.stream) >= tgt:
                    continue
                if not s.live:
                    self._replay_cache(s)
                idx = self.prompt_len + len(s.stream) - 1
                groups.setdefault((s.chain or (), idx), []).append(s)
            if not groups:
                break
            for (_, idx), cohort in groups.items():
                self._decode_cohort(cohort, idx)

    # ------------------------------------------------------------------
    def iteration(self) -> ServingIterationMetrics:
        """Advance the engine one iteration, then execute its schedule
        with real compute."""
        m = self.engine.run_iteration()
        trace = self.engine.traces[-1]
        # process schedule incidents in chronological order: requeues
        # need the victim advanced to its crash-time token count before
        # the migration replays its cache on the new chain
        for op in trace:
            kind = op[0]
            if kind == "start":
                _, _, rid, chain, pre = op
                s = self._seq(rid)
                s.chain = chain
                if pre == 0 and s.stream and not s.live:
                    s.stream = []          # drop-and-retry restart landed
                if pre > 0:
                    self._advance({rid: pre})
                    s.live = False         # queued eviction lost the KV
            elif kind == "requeue":
                _, _, rid, _old, new, k = op
                s = self._seq(rid)
                if k > 0:
                    self._advance({rid: k})
                else:
                    s.stream = []
                s.chain = new
                s.live = False             # migration re-materializes it
            elif kind == "requeue_wait":
                _, _, rid, k = op
                s = self._seq(rid)
                if k > 0:
                    self._advance({rid: k})
                else:
                    s.stream = []
                s.chain = None
                s.live = False
            elif kind == "restart":
                s = self._seq(op[2])
                s.stream = []
                s.cache = None
                s.live = False
                s.chain = None
        # advance everything to the engine's end-of-iteration census
        targets: Dict[int, int] = {}
        for rid, rec in self.engine.requests.items():
            if rec.dropped:
                continue
            tgt = self.engine.tokens_now(rid)
            if tgt:
                targets[rid] = tgt
        self._advance(targets)
        # completed sequences release their executor cache
        for rid, rec in self.engine.requests.items():
            if rec.completion is not None:
                s = self._seqs.get(rid)
                if s is not None and s.cache is not None:
                    s.cache = None
                    s.live = False
        return m

    def run(self, iterations: int) -> List[ServingIterationMetrics]:
        return [self.iteration() for _ in range(iterations)]

    # ------------------------------------------------------------------
    def token_stream(self, rid: int) -> List[int]:
        """Greedy token stream decoded so far for request ``rid``."""
        s = self._seqs.get(rid)
        return list(s.stream) if s is not None else []


def _batch_axes(cfg, cache_len: int) -> List[int]:
    """Per-leaf batch-axis index of the decode cache pytree.

    Cache layouts differ by architecture (attention leaves are
    ``(layers, batch, len, kvd)``, VLM cross-attention adds a
    cross-layer axis, SSM state has its own shape), so the batch axis
    is *detected*: allocate a batch-1 and a batch-2 cache and find the
    one axis where each leaf's shape differs.
    """
    import jax
    import jax.numpy as jnp

    from repro.models.transformer import init_cache

    l1 = jax.tree_util.tree_leaves(init_cache(cfg, 1, cache_len,
                                              dtype=jnp.float32))
    l2 = jax.tree_util.tree_leaves(init_cache(cfg, 2, cache_len,
                                              dtype=jnp.float32))
    axes = []
    for a, b in zip(l1, l2):
        diff = [i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                if x != y]
        if len(diff) != 1:  # pragma: no cover - cache layout invariant
            raise ValueError(f"ambiguous cache batch axis: "
                             f"{a.shape} vs {b.shape}")
        axes.append(diff[0])
    return axes
