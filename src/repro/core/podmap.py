"""GWTF on the pod: flow-routed pipeline-stage placement over TPU slices.

The paper's core insight — route microbatches as a min-cost flow and
repair flows instead of pipelines — applied to the production target
(DESIGN.md §3).  A TPU pod is carved into slices (sub-grids of chips);
each slice is a GWTF "relay node" whose

* capacity      = microbatches in flight (HBM-bounded),
* compute cost  = stage FLOPs / slice peak FLOPs,
* link cost     = activation bytes / ICI bandwidth x hop distance
                  (2D-torus Manhattan distance between slice centers).

Chips do not churn like volunteers, but slices DO leave in practice —
preemptions, maintenance events, failed hosts — so the same
GWTFProtocol + repair machinery schedules pipelines across slices and
re-routes around a lost slice without recomputing whole pipelines.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from repro.core.flow.decentralized import GWTFProtocol
from repro.core.flow.graph import FlowNetwork, Node
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


@dataclass(frozen=True)
class Slice:
    """A rectangular sub-grid of chips on the pod's 2D torus."""
    id: int
    origin: Tuple[int, int]       # (x, y) on the chip grid
    shape: Tuple[int, int]        # chips (dx, dy)

    @property
    def chips(self) -> int:
        return self.shape[0] * self.shape[1]

    @property
    def center(self) -> Tuple[float, float]:
        return (self.origin[0] + self.shape[0] / 2,
                self.origin[1] + self.shape[1] / 2)


def carve_pod(pod_shape: Tuple[int, int] = (16, 16),
              slice_shape: Tuple[int, int] = (4, 4)) -> List[Slice]:
    """Tile the pod into equal slices (e.g. 16 slices of 4x4 chips)."""
    sx, sy = slice_shape
    px, py = pod_shape
    slices = []
    sid = 0
    for x in range(0, px, sx):
        for y in range(0, py, sy):
            slices.append(Slice(sid, (x, y), slice_shape))
            sid += 1
    return slices


def ici_hop_distance(a: Slice, b: Slice, pod_shape=(16, 16)) -> float:
    """Torus Manhattan distance between slice centers (ICI hops)."""
    dx = abs(a.center[0] - b.center[0])
    dy = abs(a.center[1] - b.center[1])
    dx = min(dx, pod_shape[0] - dx)
    dy = min(dy, pod_shape[1] - dy)
    return max(1.0, dx + dy)


def pod_flow_network(cfg, *, num_stages: int, microbatch_tokens: int,
                     pod_shape=(16, 16), slice_shape=(4, 4),
                     inflight_per_slice: int = 2,
                     data_slices: int = 1) -> FlowNetwork:
    """Build a FlowNetwork whose nodes are pod slices.

    cfg: a ModelConfig — stage compute/activation sizes derive from it.
    """
    slices = carve_pod(pod_shape, slice_shape)
    n_relays = len(slices) - data_slices
    per_stage = n_relays // num_stages

    params_per_stage = cfg.param_count() / num_stages
    stage_flops = 2 * params_per_stage * microbatch_tokens     # fwd
    act_bytes = microbatch_tokens * cfg.d_model * 2

    nodes = {}
    nid = 0
    for _ in range(data_slices):
        nodes[nid] = Node(nid, -1, 8, 0.0, is_data=True)
        nid += 1
    stage = 0
    count = 0
    for s in slices[data_slices:]:
        if count >= per_stage and stage < num_stages - 1:
            stage += 1
            count = 0
        compute_s = stage_flops / (s.chips * PEAK_FLOPS_BF16)
        nodes[nid] = Node(nid, stage, inflight_per_slice, compute_s)
        nid += 1
        count += 1

    N = nid
    lat = np.zeros((N, N))
    bw = np.full((N, N), ICI_BW)
    for i in range(N):
        for j in range(N):
            if i == j:
                continue
            si = slices[i] if i < len(slices) else slices[-1]
            sj = slices[j] if j < len(slices) else slices[-1]
            hops = ici_hop_distance(si, sj, pod_shape)
            lat[i, j] = hops * 1e-6            # ~1us per hop
            bw[i, j] = ICI_BW / hops           # store-and-forward per hop
    return FlowNetwork(nodes=nodes, num_stages=num_stages,
                       latency=lat, bandwidth=bw,
                       activation_size=act_bytes)


def schedule_pipelines(cfg, *, num_stages: int = 5,
                       microbatch_tokens: int = 4 * 4096,
                       pod_shape=(16, 16), slice_shape=(4, 4),
                       seed: int = 0) -> Tuple[GWTFProtocol, FlowNetwork]:
    """Run GWTF's decentralized flow construction over the pod slices.

    Returns the converged protocol (complete_flows() = pipeline routes)
    and the network (for repair on slice loss)."""
    net = pod_flow_network(cfg, num_stages=num_stages,
                           microbatch_tokens=microbatch_tokens,
                           pod_shape=pod_shape, slice_shape=slice_shape)
    proto = GWTFProtocol(net, rng=np.random.default_rng(seed))
    proto.run(max_rounds=200)
    return proto, net


def lose_slice(proto: GWTFProtocol, net: FlowNetwork, slice_id: int):
    """A slice is preempted: remove + repair (the paper's crash path)."""
    if net.nodes[slice_id].is_data:
        raise ValueError("data slice loss is unrecoverable (paper Sec. VII-b)")
    net.nodes[slice_id].alive = False
    proto.remove_node(slice_id)
    proto.reclaim_sink_slots()
    proto.run(max_rounds=80)
    return proto.complete_flows()
