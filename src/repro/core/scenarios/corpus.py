"""The committed scenario corpus (paper Table II/III regimes + beyond).

~12 named `ScenarioSpec`s covering the paper's evaluation axes — the
10-location geo topology, 5-20% Bernoulli churn, heterogeneous
capacities and compute — plus the failure modes the related systems
literature calls under-evaluated: scripted regional blackouts,
correlated regional outages, flash-crowd joins, link degradation, and
the abstract Table IV/V flow settings.

`load_corpus()` also picks up any ``*.json`` spec dropped into
``corpus/`` next to this module — that directory is where the fuzz
harness (`scenarios.harness.fuzz`) writes minimized failing specs, so
a shrunk reproducer automatically becomes a named regression scenario
on the next corpus sweep.

Golden metrics (`golden.json`) pin the flow-layer outcome (chain
count, total cost — bit-stable by the engines' equivalence guarantee)
and the simulator's Table II/III `summarize` columns for every corpus
scenario.  Regenerate after an intentional behavior change with::

    PYTHONPATH=src python -m repro.core.scenarios.corpus --regen-golden
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.core.scenarios import generate
from repro.core.scenarios.spec import ScenarioSpec

_HERE = os.path.dirname(os.path.abspath(__file__))
CORPUS_DIR = os.path.join(_HERE, "corpus")
GOLDEN_PATH = os.path.join(_HERE, "golden.json")

#: scenarios whose sim `summarize` columns the golden regression test
#: pins tolerance-free (GWTF runs are bit-deterministic per seed)
GOLDEN_PINNED = ("table2-het-churn10", "geo-regional-blackout",
                 "adversarial-straggler", "adversarial-flaky",
                 "serve-steady-poisson", "serve-churn-under-load")


def _corpus() -> List[ScenarioSpec]:
    geo = dict(topology="geo", num_stages=4, relays_per_stage=4,
               num_data_nodes=2, data_capacity=4, num_locations=10,
               iterations=6)
    specs = [
        # ---- paper Table II/III regimes (geo, 10 locations) ----------
        ScenarioSpec(name="table2-hom-churn10", seed=11,
                     capacity_range=(4, 5),
                     churn=[{"kind": "bernoulli", "p": 0.10}], **geo),
        ScenarioSpec(name="table2-het-churn10", seed=12,
                     capacity_range=(1, 4),
                     churn=[{"kind": "bernoulli", "p": 0.10}], **geo),
        ScenarioSpec(name="table3-het-churn20", seed=13,
                     capacity_range=(1, 4),
                     churn=[{"kind": "bernoulli", "p": 0.20}], **geo),
        ScenarioSpec(name="geo-churn5", seed=14, capacity_range=(1, 4),
                     churn=[{"kind": "bernoulli", "p": 0.05}], **geo),
        ScenarioSpec(name="geo-zero-churn", seed=15, capacity_range=(2, 4),
                     topology="geo", num_stages=2, relays_per_stage=3,
                     num_data_nodes=1, data_capacity=4, num_locations=10,
                     iterations=6, churn=[]),
        # ---- geo failure modes beyond Bernoulli ----------------------
        ScenarioSpec(name="geo-regional-blackout", seed=16,
                     capacity_range=(1, 4),
                     churn=[{"kind": "regional_blackout", "location": 3,
                             "at_iteration": 2, "duration": 2,
                             "when": 0.25}], **geo),
        ScenarioSpec(name="geo-correlated-outages", seed=17,
                     capacity_range=(1, 4),
                     churn=[{"kind": "regional_outage", "outage_prob": 0.4,
                             "severity": 0.8, "rejoin_prob": 0.5}], **geo),
        ScenarioSpec(name="geo-flash-crowd", seed=18,
                     capacity_range=(1, 4), spare_nodes=4,
                     churn=[{"kind": "flash_crowd", "at_iteration": 2,
                             "nodes": 4},
                            {"kind": "bernoulli", "p": 0.05}], **geo),
        ScenarioSpec(name="geo-link-degradation", seed=19,
                     capacity_range=(1, 4),
                     churn=[{"kind": "link_degradation", "at_iteration": 2,
                             "factor": 6.0, "duration": 2},
                            {"kind": "bernoulli", "p": 0.05}], **geo),
        ScenarioSpec(name="geo-hetero-compute", seed=20,
                     capacity_range=(1, 4),
                     region_compute_scale=[1.0, 4.0, 1.5, 2.0, 1.0,
                                           3.0, 1.0, 2.5, 1.0, 2.0],
                     region_bandwidth_scale=[1.0, 0.25, 1.0, 0.5, 1.0,
                                             0.5, 1.0, 1.0, 0.3, 1.0],
                     churn=[{"kind": "bernoulli", "p": 0.10}], **geo),
        # ---- compression-aware WAN planning --------------------------
        # full codec menu under a budget admitting everything up to
        # top-k; per-link choices and bytes-on-wire are pinned by the
        # golden table and cross-checked by harness.check_codec_agreement
        ScenarioSpec(name="geo-wan-compress", seed=24,
                     capacity_range=(1, 4),
                     compression={"menu": ["fp32", "bf16", "int8",
                                           "top-k"],
                                  "fidelity_budget": 0.1,
                                  "fidelity_weight": 1.0},
                     churn=[{"kind": "bernoulli", "p": 0.10}], **geo),
        ScenarioSpec(name="trace-crash-rejoin", seed=21,
                     capacity_range=(2, 4),
                     churn=[{"kind": "trace",
                             "events": [[1, "crash", 3, 0.3],
                                        [1, "crash", 7, 0.6],
                                        [3, "rejoin", 3],
                                        [4, "rejoin", 7],
                                        [4, "crash", 11, 0.2]]}], **geo),
        # ---- beyond fail-stop: adversarial fault classes (ISSUE 9) ---
        # corrupt relay 3 carries 2 of the 4 planned chains at seed 25;
        # the runtime gradient screen catches both poisoned
        # contributions at iteration 0 (mode "perturb" is certainly
        # detectable — sign_flip is regime-dependent near init and
        # deliberately not pinned), reputation quarantines the relay
        # and the planner reroutes off it from iteration 1.  Swept by
        # check_fault_timeline + check_detection_precision_recall.
        ScenarioSpec(name="adversarial-corrupt", seed=25,
                     topology="geo", num_stages=2, relays_per_stage=3,
                     num_data_nodes=1, data_capacity=4,
                     capacity_range=(2, 3), iterations=4, microbatches=4,
                     model_layers=2, model_d=32, model_vocab=256,
                     seq_len=16, microbatch_size=1,
                     churn=[{"kind": "corrupt_gradient", "nodes": [3],
                             "mode": "perturb", "scale": 1.0, "seed": 7}]),
        # stage-1 relay 4 hangs for iterations 1-2 (deadline-catchable
        # on both layers: only a timeout ever completes it) while relay
        # 5 runs 1.5x slow (deliberately *below* both layers' catch
        # thresholds — injected and timed, never detected); the shared
        # fault timeline pins identical detection/repair counts
        ScenarioSpec(name="adversarial-straggler", seed=25,
                     topology="geo", num_stages=2, relays_per_stage=3,
                     num_data_nodes=1, data_capacity=4,
                     capacity_range=(2, 3), iterations=4, microbatches=4,
                     model_layers=2, model_d=32, model_vocab=256,
                     seq_len=16, microbatch_size=1,
                     churn=[{"kind": "straggler", "nodes": [4],
                             "hang": True, "at_iteration": 1,
                             "duration": 2},
                            {"kind": "straggler", "nodes": [5],
                             "factor": 1.5, "at_iteration": 1,
                             "duration": 2}]),
        # per-leg Bernoulli delivery failure: detection/repair is
        # engine-local (the runtime performs no transfer legs), so only
        # the injections cross-compare; sim retries/timeouts are pinned
        # by the golden table
        ScenarioSpec(name="adversarial-flaky", seed=25,
                     topology="geo", num_stages=2, relays_per_stage=3,
                     num_data_nodes=1, data_capacity=4,
                     capacity_range=(2, 3), iterations=4, microbatches=4,
                     model_layers=2, model_d=32, model_vocab=256,
                     seq_len=16, microbatch_size=1,
                     churn=[{"kind": "flaky_link", "p": 0.15,
                             "seed": 3}]),
        # ---- serving plane: decode traffic over the flow engine ------
        # steady open-loop Poisson load, zero churn, KV-residency
        # pricing on: the baseline serving regime whose TTFT/TPOT row
        # the golden table pins and whose zero-churn decode must be
        # bit-identical to the standalone launch/serve.py path
        # (harness.check_serving_consistency)
        ScenarioSpec(name="serve-steady-poisson", seed=26,
                     topology="geo", num_stages=3, relays_per_stage=3,
                     num_data_nodes=1, data_capacity=4,
                     capacity_range=(2, 4), iterations=4, microbatches=4,
                     model_layers=2, model_d=32, model_vocab=128,
                     seq_len=16, microbatch_size=1,
                     prompt_len=8, gen_tokens=8, serve_batch=4,
                     kv_weight=0.5,
                     arrivals=[{"kind": "poisson", "rate": 2.0}]),
        # the geo-flash-crowd shape reused as a serving spike: spare
        # relays rejoin exactly when the request flash crowd lands, so
        # admission pressure and fresh capacity hit the planner in the
        # same iteration
        ScenarioSpec(name="serve-flash-spike", seed=27,
                     topology="geo", num_stages=3, relays_per_stage=3,
                     num_data_nodes=1, data_capacity=4,
                     capacity_range=(2, 4), iterations=4, microbatches=4,
                     model_layers=2, model_d=32, model_vocab=128,
                     seq_len=16, microbatch_size=1, spare_nodes=2,
                     prompt_len=8, gen_tokens=8, serve_batch=2,
                     arrivals=[{"kind": "poisson", "rate": 1.0},
                               {"kind": "spike", "at_iteration": 1,
                                "requests": 6, "when": 0.3}],
                     churn=[{"kind": "flash_crowd", "at_iteration": 1,
                             "nodes": 2}]),
        # deterministic crash while decodes are in flight: the
        # requeue-instead-of-drop path (KV migration + crashed-stage
        # re-prefill) pinned by the golden table and replayed with real
        # compute by the serving differential
        ScenarioSpec(name="serve-churn-under-load", seed=28,
                     topology="geo", num_stages=3, relays_per_stage=3,
                     num_data_nodes=1, data_capacity=4,
                     capacity_range=(2, 4), iterations=4, microbatches=4,
                     model_layers=2, model_d=32, model_vocab=128,
                     seq_len=16, microbatch_size=1,
                     prompt_len=8, gen_tokens=48, serve_batch=4,
                     arrivals=[{"kind": "spike", "at_iteration": 1,
                                "requests": 4, "when": 0.2},
                               {"kind": "poisson", "rate": 1.0}],
                     churn=[{"kind": "trace",
                             "events": [[1, "crash", 5, 0.4]]}]),
        # ---- abstract flow settings (paper Tables IV/V) --------------
        ScenarioSpec(name="flow-tableV-1", seed=22, topology="synthetic",
                     num_stages=8, relays_per_stage=5, num_data_nodes=1,
                     source_capacity=4, capacity_range=(1, 3),
                     cost_range=(1, 20), iterations=2),
        ScenarioSpec(name="flow-tableV-multisource", seed=23,
                     topology="synthetic", num_stages=8,
                     relays_per_stage=10, num_data_nodes=4,
                     source_capacity=3, capacity_range=(1, 3),
                     cost_range=(1, 20), iterations=2),
    ]
    for s in specs:
        s.validate()
    return specs


def _scale_corpus() -> List[ScenarioSpec]:
    """The ``--scale`` tier: bench_scale-style topologies at 500-2000
    relays under churn.  Swept by the scenario-corpus CI job with the
    restricted `harness.scale_checks` set (the real-compute and
    reference-engine differentials stay bounded; nothing here runs
    JAX), never part of the golden corpus."""
    specs = [
        # engine-vs-reference bit-equality through the harness'
        # crash -> repair -> rejoin episode at >= 500 relays
        ScenarioSpec(name="scale-flow-500", seed=41, tier="scale",
                     topology="synthetic", num_stages=10,
                     relays_per_stage=50, num_data_nodes=2,
                     source_capacity=25, capacity_range=(1, 4),
                     cost_range=(1, 20), iterations=2, objective="sum"),
        # 1000-relay geo-abstract swarm under Bernoulli churn: event
        # engine + planner at scale (sim-invariants, hierarchy gap)
        ScenarioSpec(name="scale-geo-1000-churn10", seed=42, tier="scale",
                     topology="geo-abstract", num_stages=10,
                     relays_per_stage=100, num_data_nodes=2,
                     source_capacity=50, capacity_range=(1, 4),
                     cost_range=(4, 21), num_locations=10,
                     iterations=2, objective="sum",
                     churn=[{"kind": "bernoulli", "p": 0.10}]),
        # regional blackout at scale: location-keyed churn on the
        # geo-abstract topology + hierarchical-vs-oracle gap bound
        ScenarioSpec(name="scale-geo-2000-blackout", seed=43, tier="scale",
                     topology="geo-abstract", num_stages=10,
                     relays_per_stage=200, num_data_nodes=2,
                     source_capacity=100, capacity_range=(1, 4),
                     cost_range=(4, 21), num_locations=10,
                     iterations=2, objective="sum",
                     churn=[{"kind": "regional_blackout", "location": 2,
                             "at_iteration": 0, "duration": 1}]),
    ]
    for s in specs:
        s.validate()
    return specs


def load_corpus(include_shrunk: bool = True,
                tier: str = "standard") -> List[ScenarioSpec]:
    """Committed scenarios of one tier (or ``"all"``): the named set
    plus — for the standard tier — any fuzz-minimized ``corpus/*.json``
    regression specs."""
    if tier not in ("standard", "scale", "all"):
        raise ValueError(f"unknown corpus tier {tier!r}")
    specs: List[ScenarioSpec] = _corpus() + _scale_corpus()
    if include_shrunk and os.path.isdir(CORPUS_DIR):
        for path in sorted(glob.glob(os.path.join(CORPUS_DIR, "*.json"))):
            with open(path) as fh:
                specs.append(ScenarioSpec.from_json(fh.read()))
    if tier != "all":
        specs = [s for s in specs if s.tier == tier]
    names = [s.name for s in specs]
    if len(set(names)) != len(names):
        dupes = sorted({n for n in names if names.count(n) > 1})
        raise ValueError(f"duplicate scenario names in corpus: {dupes}")
    return specs


def get_scenario(name: str) -> ScenarioSpec:
    for spec in load_corpus(tier="all"):
        if spec.name == name:
            return spec
    raise KeyError(f"unknown scenario {name!r}; corpus has "
                   f"{[s.name for s in load_corpus(tier='all')]}")


# ---------------------------------------------------------------------------
# Golden metrics
# ---------------------------------------------------------------------------

def compute_golden(spec: ScenarioSpec) -> Dict:
    """The pinned observables for one scenario: flow-layer outcome,
    the simulator's summarize() table, and — for specs with an arrival
    program — the serving plane's summarize_serving() row (request
    counters + p50/p99 TTFT/TPOT, bit-deterministic per seed)."""
    from repro.core.sim.metrics import summarize, summarize_serving

    flow = generate.run_flow(spec, "batched")
    table = summarize(generate.run_sim(spec), warmup=1)
    out = {
        "flow": {"chains": len(flow.flows),
                 "total_cost": flow.total_cost,
                 "rounds": flow.rounds},
        "sim": {k: list(v) for k, v in table.items()},
    }
    if spec.has_arrivals:
        out["serving"] = summarize_serving(generate.run_serving_sim(spec))
    return out


def load_golden() -> Dict[str, Dict]:
    with open(GOLDEN_PATH) as fh:
        return json.load(fh)


def regen_golden(path: Optional[str] = None) -> Dict[str, Dict]:
    golden = {spec.name: compute_golden(spec)
              for spec in load_corpus(include_shrunk=False)}
    with open(path or GOLDEN_PATH, "w") as fh:
        json.dump(golden, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return golden


def main(argv: Optional[List[str]] = None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--regen-golden", action="store_true",
                    help="rerun every corpus scenario and rewrite "
                         "golden.json")
    ap.add_argument("--list", action="store_true",
                    help="print the corpus table")
    ap.add_argument("--scale", action="store_true",
                    help="also list the scale tier (1000+ relay specs "
                         "swept by harness.scale_checks)")
    args = ap.parse_args(argv)
    if args.regen_golden:
        golden = regen_golden()
        print(f"wrote {GOLDEN_PATH} ({len(golden)} scenarios)")
    if args.list or args.scale or not args.regen_golden:
        print(f"{'name':28s} {'tier':8s} {'topology':12s} {'nodes':>5s} "
              f"{'stages':>6s} churn")
        tier = "all" if args.scale else "standard"
        for spec in load_corpus(tier=tier):
            kinds = ",".join(c["kind"] for c in spec.churn) or "-"
            print(f"{spec.name:28s} {spec.tier:8s} {spec.topology:12s} "
                  f"{spec.base_nodes + spec.spare_nodes:5d} "
                  f"{spec.num_stages:6d} {kinds}")


if __name__ == "__main__":
    main()
