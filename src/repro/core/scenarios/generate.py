"""Materialize one `ScenarioSpec` as each of the three execution layers.

The same spec deterministically becomes:

* a `FlowNetwork` + GWTF flow problem — solvable by the batched
  `GWTFProtocol`, its ``strict_rng`` scalar mode, the frozen
  `ReferenceGWTFProtocol`, and the centralized `MinCostFlow` oracle
  (`build_network`, `build_flow`, `solve_optimal`);
* a discrete-event simulator run — `TrainingSimulator` with the spec's
  scheduler, model profile and composed churn program (`build_sim`,
  `run_sim`);
* a reduced real-compute run — `RuntimeTrainer` over the staged JAX
  runtime with the *same* churn program and the same policy seeding
  (`build_runtime`, `run_runtime`).

Determinism discipline
----------------------
Every random draw is keyed on ``default_rng([spec.seed, salt])`` with a
fixed per-purpose salt (`_SALT_*`), so layers never perturb each
other's streams: the topology draw is identical for all three layers,
and the *policy* stream is identical between the simulator and the
runtime — both construct their routing policy and sample churn in the
same order, which is what makes the cross-layer plan-equality check in
`scenarios.harness` possible at all.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.flow.graph import (FlowNetwork, Node,
                                   geo_distributed_network,
                                   synthetic_network)
from repro.core.scenarios.spec import ScenarioSpec
from repro.core.sim.faults import (AdversarialPlan, BernoulliChurn,
                                   ChurnModel, ComposedChurn,
                                   CorruptGradientChurn, FlakyLinkChurn,
                                   LinkDegradationChurn, RegionalOutageChurn,
                                   StragglerChurn, TraceChurn,
                                   adversarial_plan)
from repro.core.sim.metrics import IterationMetrics, ModelProfile
from repro.core.sim.policies import make_policy

# fixed per-purpose RNG salts (never reuse across purposes)
_SALT_CAPS = 1        # relay capacity draw
_SALT_NET = 2         # topology link/jitter draw
_SALT_SPARE = 3       # spare-node (flash crowd) attribute draw
_SALT_FLOW = 4        # flow-protocol annealing stream
_SALT_POLICY = 5      # sim/runtime policy + churn stream (shared!)
_SALT_ARRIVALS = 6    # serving request-arrival program compilation


def _rng(spec: ScenarioSpec, salt: int) -> np.random.Generator:
    return np.random.default_rng([spec.seed, salt])


# ---------------------------------------------------------------------------
# Topology
# ---------------------------------------------------------------------------

def relay_capacities(spec: ScenarioSpec) -> List[int]:
    lo, hi = spec.capacity_range
    rng = _rng(spec, _SALT_CAPS)
    return [int(rng.uniform(lo, hi)) for _ in range(spec.num_relays)]


def build_network(spec: ScenarioSpec
                  ) -> Tuple[FlowNetwork, Optional[np.ndarray]]:
    """Materialize the spec's topology.

    Returns ``(net, cost_matrix)`` — ``cost_matrix`` is the directly
    drawn integer d_ij for the synthetic topology (passed through to
    the flow engines, as in the paper's Table IV/V experiments) and
    for geo-abstract (integer per-location-pair base + node jitter,
    ``Node.location`` stamped — the bench_scale internet-scale shape),
    and ``None`` for geo (Eq. 1 costs from the network's own caches).
    """
    spec.validate()
    if spec.topology == "geo-abstract":
        return _geo_abstract_network(spec)
    if spec.topology == "synthetic":
        lo, hi = spec.cost_range
        clo, chi = spec.capacity_range
        net, cost = synthetic_network(
            num_stages=spec.num_stages,
            relays_per_stage=spec.relays_per_stage,
            capacities=lambda r: int(r.uniform(clo, chi)),
            link_costs=lambda r: float(int(r.uniform(lo, hi))),
            num_sources=spec.num_data_nodes,
            source_capacity=spec.source_capacity,
            rng=_rng(spec, _SALT_NET))
        return net, cost

    net = geo_distributed_network(
        num_stages=spec.num_stages,
        relay_capacities=relay_capacities(spec),
        num_data_nodes=spec.num_data_nodes,
        data_capacity=spec.data_capacity,
        num_locations=spec.num_locations,
        min_bandwidth=spec.min_bandwidth,
        max_bandwidth=spec.max_bandwidth,
        compute_cost=spec.compute_cost,
        compute_jitter=spec.compute_jitter,
        rng=_rng(spec, _SALT_NET))
    _apply_region_heterogeneity(spec, net)
    _add_spare_nodes(spec, net)
    _apply_compression(spec, net)
    return net, None


def _apply_compression(spec: ScenarioSpec, net: FlowNetwork) -> None:
    """Install the spec's ``compression`` clause on the network: the
    per-link codec menu and the scenario-level fidelity budget/weight
    that gate and price it.  RNG-free, so it never perturbs the
    topology or policy streams."""
    if spec.compression is None:
        return
    net.codec_menu = tuple(spec.compression["menu"])
    net.fidelity_budget = float(
        spec.compression.get("fidelity_budget", 0.0))
    net.fidelity_weight = float(
        spec.compression.get("fidelity_weight", 1.0))


def _geo_abstract_network(spec: ScenarioSpec
                          ) -> Tuple[FlowNetwork, np.ndarray]:
    """The bench_scale internet-scale topology as a spec: integer
    per-location-pair base cost ~U{cost_range} (intra-location
    ~U{1..4}) plus symmetric per-node-pair jitter ~U{0..2}, relays
    round-robin over stages, ``Node.location`` stamped so the
    hierarchical planner and location-keyed churn clauses apply.

    Capacities come from the shared `relay_capacities` draw
    (``_SALT_CAPS``) like geo; link structure from ``_SALT_NET``.
    """
    caps = relay_capacities(spec)
    rng = _rng(spec, _SALT_NET)
    N = spec.base_nodes
    L = spec.num_locations
    nodes: Dict[int, Node] = {}
    loc = np.empty(N, np.int64)
    for d in range(spec.num_data_nodes):
        nodes[d] = Node(d, -1, spec.source_capacity, 0.0, is_data=True)
        loc[d] = int(rng.integers(0, L))
    for i in range(spec.num_relays):
        nid = spec.num_data_nodes + i
        nodes[nid] = Node(nid, i % spec.num_stages, caps[i], 0.0,
                          location=int(rng.integers(0, L)))
        loc[nid] = nodes[nid].location
    lo, hi = spec.cost_range
    base = rng.integers(lo, hi, (L, L)).astype(float)
    base = np.maximum(base, base.T)
    np.fill_diagonal(base, 0.0)
    base += np.diag(rng.integers(1, 5, L).astype(float))
    jitter = rng.integers(0, 3, (N, N)).astype(float)
    cm = base[np.ix_(loc, loc)] + np.maximum(jitter, jitter.T)
    np.fill_diagonal(cm, 0.0)
    net = FlowNetwork(nodes=nodes, num_stages=spec.num_stages,
                      latency=cm, bandwidth=np.full((N, N), np.inf),
                      activation_size=0.0)
    return net, cm


def _apply_region_heterogeneity(spec: ScenarioSpec, net: FlowNetwork) -> None:
    """Per-region compute/bandwidth multipliers on top of the base draw
    (the heterogeneous-compute axis of Tables II/III)."""
    if spec.region_compute_scale is None \
            and spec.region_bandwidth_scale is None:
        return
    n = net.latency.shape[0]
    loc = np.zeros(n, np.int64)
    for nid, node in net.nodes.items():
        loc[nid] = max(0, node.location)
    if spec.region_compute_scale is not None:
        cs = np.asarray(spec.region_compute_scale, float)
        for node in net.nodes.values():
            if not node.is_data:
                node.compute_cost *= float(cs[max(0, node.location)])
    if spec.region_bandwidth_scale is not None:
        bs = np.asarray(spec.region_bandwidth_scale, float)
        # a link is as good as its worse endpoint region
        link_scale = np.minimum(bs[loc][:, None], bs[loc][None, :])
        net.bandwidth *= link_scale
    net.invalidate_costs()


def _add_spare_nodes(spec: ScenarioSpec, net: FlowNetwork) -> None:
    """Provision the flash-crowd pool: ``spare_nodes`` relays created
    *dead* (alive=False), round-robin over stages, with links drawn
    from the same intra/inter-location distributions as the base
    topology.  A ``flash_crowd`` churn clause revives them mid-run,
    which exercises protocol `add_node` + policy `on_rejoin` on every
    layer."""
    if not spec.spare_nodes:
        return
    rng = _rng(spec, _SALT_SPARE)
    lo, hi = spec.capacity_range
    for k in range(spec.spare_nodes):
        nid = spec.base_nodes + k
        stage = k % spec.num_stages
        cap = int(rng.uniform(lo, hi))
        c = spec.compute_cost * (
            1.0 + spec.compute_jitter * rng.standard_normal())
        loc = int(rng.integers(0, spec.num_locations))
        n_existing = nid
        same = np.array([net.nodes[i].location == loc
                         for i in range(n_existing)])
        lat_row = np.where(same, rng.uniform(0.001, 0.005, n_existing),
                           rng.uniform(0.02, 0.15, n_existing))
        lat_col = np.where(same, rng.uniform(0.001, 0.005, n_existing),
                           rng.uniform(0.02, 0.15, n_existing))
        bw_row = np.where(same, spec.max_bandwidth,
                          rng.uniform(spec.min_bandwidth,
                                      spec.max_bandwidth, n_existing))
        bw_col = np.where(same, spec.max_bandwidth,
                          rng.uniform(spec.min_bandwidth,
                                      spec.max_bandwidth, n_existing))
        node = Node(nid, stage, cap, max(0.5, c), alive=False, location=loc)
        net.add_node(node, latency_row=lat_row, latency_col=lat_col,
                     bandwidth_row=bw_row, bandwidth_col=bw_col)


def spare_node_ids(spec: ScenarioSpec) -> List[int]:
    return list(range(spec.base_nodes, spec.base_nodes + spec.spare_nodes))


# ---------------------------------------------------------------------------
# Churn program
# ---------------------------------------------------------------------------

def _blackout_location(net: FlowNetwork, location: int) -> int:
    """Resolve a spec's blackout location against the built topology.

    A spec draws its location before relay placement is known, so an
    index that happens to land on an empty region wraps onto the
    sorted populated locations deterministically (identity whenever
    the drawn location already has relays — committed scenarios are
    unaffected).  `TraceChurn.regional_blackout` itself stays strict:
    direct callers name locations on a topology they can inspect.
    """
    populated = sorted({n.location for n in net.nodes.values()
                        if not n.is_data and n.location >= 0})
    if location in populated or not populated:
        return location
    return populated[location % len(populated)]


def build_churn_model(spec: ScenarioSpec, net: FlowNetwork) -> ChurnModel:
    """Compose the spec's churn clauses into one `ChurnModel`.

    An empty program compiles to an (RNG-free) empty trace so zero-churn
    scenarios consume no fault-layer randomness.
    """
    models: List[ChurnModel] = []
    spare_cursor = spec.base_nodes
    for clause in spec.churn:
        kind = clause["kind"]
        if kind == "bernoulli":
            models.append(BernoulliChurn(clause["p"]))
        elif kind == "trace":
            models.append(TraceChurn(clause["events"]))
        elif kind == "regional_blackout":
            models.append(TraceChurn.regional_blackout(
                net, location=_blackout_location(net, clause["location"]),
                at_iteration=clause["at_iteration"],
                duration=clause.get("duration", 2),
                when=clause.get("when", 0.25)))
        elif kind == "regional_outage":
            models.append(RegionalOutageChurn(
                clause["outage_prob"],
                severity=clause.get("severity", 1.0),
                rejoin_prob=clause.get("rejoin_prob", 0.5)))
        elif kind == "flash_crowd":
            k = int(clause["nodes"])
            ids = list(range(spare_cursor, spare_cursor + k))
            spare_cursor += k
            models.append(TraceChurn(
                [(clause["at_iteration"], "rejoin", nid) for nid in ids]))
        elif kind == "link_degradation":
            models.append(LinkDegradationChurn(
                clause["at_iteration"], clause["factor"],
                duration=clause.get("duration", 0),
                inter_region_only=clause.get("inter_region_only", True)))
        elif kind == "straggler":
            nodes = [int(n) for n in clause["nodes"]]
            hang = bool(clause.get("hang", False))
            factor = float(clause.get("factor", 4.0))
            models.append(StragglerChurn(
                None if hang else {n: factor for n in nodes},
                hangs=nodes if hang else (),
                at_iteration=int(clause.get("at_iteration", 0)),
                duration=int(clause.get("duration", 0)),
                known_ids=net.nodes.keys()))
        elif kind == "corrupt_gradient":
            models.append(CorruptGradientChurn(
                [int(n) for n in clause["nodes"]],
                mode=clause.get("mode", "perturb"),
                scale=float(clause.get("scale", 1.0)),
                seed=int(clause.get("seed", 0)),
                at_iteration=int(clause.get("at_iteration", 0)),
                duration=int(clause.get("duration", 0)),
                known_ids=net.nodes.keys()))
        elif kind == "flaky_link":
            models.append(FlakyLinkChurn(
                float(clause["p"]),
                seed=int(clause.get("seed", 0)),
                at_iteration=int(clause.get("at_iteration", 0)),
                duration=int(clause.get("duration", 0))))
        else:  # pragma: no cover - validate() rejects unknown kinds
            raise ValueError(f"unknown churn clause kind {kind!r}")
    if not models:
        return TraceChurn([])
    if len(models) == 1:
        return models[0]
    return ComposedChurn(models)


def iteration_crash_plan(spec: ScenarioSpec) -> Dict[int, List[Tuple[int, float]]]:
    """Static view of a *deterministic* churn program: per-iteration
    ``[(node_id, when_fraction), ...]`` crash lists, resolved against a
    throwaway materialization of the topology (blackout clauses need
    node locations).  Raises if the program draws randomness."""
    if not spec.deterministic_churn:
        raise ValueError(f"{spec.name}: churn program is not deterministic")
    net, _ = build_network(spec)
    plan: Dict[int, List[Tuple[int, float]]] = {}
    for clause in spec.churn:
        kind = clause["kind"]
        if kind == "trace":
            for ev in clause["events"]:
                if str(ev[1]) == "crash":
                    when = float(ev[3]) if len(ev) > 3 else 0.5
                    plan.setdefault(int(ev[0]), []).append(
                        (int(ev[2]), when))
        elif kind == "regional_blackout":
            loc = _blackout_location(net, clause["location"])
            nids = [n.id for n in net.nodes.values()
                    if not n.is_data and n.location == loc]
            when = clause.get("when", 0.25)
            for nid in nids:
                plan.setdefault(int(clause["at_iteration"]), []).append(
                    (nid, when))
        # flash_crowd / link_degradation / adversarial clauses crash
        # nobody (stragglers, corrupters and flaky links stay alive)
    return plan


def iteration_adversarial_plan(spec: ScenarioSpec
                               ) -> Dict[int, AdversarialPlan]:
    """Static per-iteration `AdversarialPlan` view of a deterministic
    churn program: what the beyond-fail-stop clauses inject at each
    iteration, resolved without running either execution layer.  The
    harness uses it to pin expected injection counts against both
    layers' fault timelines.  Raises if the program draws randomness."""
    if not spec.deterministic_churn:
        raise ValueError(f"{spec.name}: churn program is not deterministic")
    net, _ = build_network(spec)
    model = build_churn_model(spec, net)
    out: Dict[int, AdversarialPlan] = {}
    for it in range(spec.iterations):
        plan = adversarial_plan(model, it)
        if plan is not None and not plan.is_empty():
            out[it] = plan
    return out


# ---------------------------------------------------------------------------
# Layer (a): flow engines + optimal oracle
# ---------------------------------------------------------------------------

FLOW_ENGINES = ("batched", "strict", "reference")


def build_flow(spec: ScenarioSpec, engine: str = "batched",
               net: Optional[FlowNetwork] = None,
               cost_matrix: Optional[np.ndarray] = None):
    """A GWTF protocol instance over the spec's topology.

    ``engine``: ``"batched"`` (default optimized scans), ``"strict"``
    (optimized engine, scalar-scan compatibility mode) or
    ``"reference"`` (the frozen pre-optimization implementation).
    Passing ``net``/``cost_matrix`` reuses an existing materialization
    (the differential harness builds one per engine).
    """
    from repro.core.flow.decentralized import GWTFProtocol
    from repro.core.flow.reference import ReferenceGWTFProtocol

    if net is None:
        net, cost_matrix = build_network(spec)
    rng = _rng(spec, _SALT_FLOW)
    if engine == "reference":
        return ReferenceGWTFProtocol(net, cost_matrix=cost_matrix,
                                     objective=spec.objective, rng=rng)
    if engine not in ("batched", "strict"):
        raise ValueError(f"unknown flow engine {engine!r} "
                         f"(expected one of {FLOW_ENGINES})")
    return GWTFProtocol(net, cost_matrix=cost_matrix,
                        objective=spec.objective,
                        strict_rng=(engine == "strict"), rng=rng)


@dataclass
class FlowResult:
    engine: str
    flows: List[List[int]]
    total_cost: float
    temperature: float
    rounds: int
    rng_state: dict
    protocol: Any = field(repr=False, default=None)
    net: FlowNetwork = field(repr=False, default=None)


def run_flow(spec: ScenarioSpec, engine: str = "batched",
             max_rounds: int = 120) -> FlowResult:
    net, cm = build_network(spec)
    proto = build_flow(spec, engine, net=net, cost_matrix=cm)
    rounds = proto.run(max_rounds=max_rounds)
    return FlowResult(engine=engine, flows=proto.complete_flows(),
                      total_cost=proto.total_cost(), temperature=proto.T,
                      rounds=rounds,
                      rng_state=proto.rng.bit_generator.state,
                      protocol=proto, net=net)


def solve_optimal(spec: ScenarioSpec, method: str = "auto",
                  max_flow: Optional[float] = None,
                  net: Optional[FlowNetwork] = None,
                  cost_matrix: Optional[np.ndarray] = None):
    """Centralized `MinCostFlow` optimum over the spec's layered graph."""
    from repro.core.flow.mincost import solve_training_flow

    if net is None:
        net, cost_matrix = build_network(spec)
    return solve_training_flow(net, cost_matrix=cost_matrix,
                               max_flow=max_flow, method=method)


# ---------------------------------------------------------------------------
# Layer (b): event simulator
# ---------------------------------------------------------------------------

def model_config(spec: ScenarioSpec):
    """The reduced model family shared by the profile and the runtime."""
    from repro.configs import get_config

    cfg = get_config(spec.model).reduced(num_layers=spec.model_layers,
                                         d_model=spec.model_d)
    return dataclasses.replace(cfg, vocab_size=spec.model_vocab)


def model_profile(spec: ScenarioSpec) -> ModelProfile:
    return ModelProfile.from_config(model_config(spec),
                                    num_stages=spec.num_stages,
                                    microbatch=spec.microbatch_size,
                                    seq_len=spec.seq_len)


def build_sim(spec: ScenarioSpec,
              policy_wrapper=None, **sim_kw):
    """`TrainingSimulator` over the spec; ``policy_wrapper`` (if given)
    wraps the routing policy before the engine sees it — the harness
    uses it to record per-iteration plans without perturbing the RNG
    stream.  Extra keywords (``deadline_defense``, ``corrupt_screen``)
    reach the engine — the benches use them for the undefended
    baselines."""
    from repro.core.sim.facade import TrainingSimulator

    net, _ = build_network(spec)
    rng = _rng(spec, _SALT_POLICY)
    policy = make_policy(spec.scheduler, net, rng=rng)
    if policy_wrapper is not None:
        policy = policy_wrapper(policy)
    return TrainingSimulator(
        net, profile=model_profile(spec),
        churn_model=build_churn_model(spec, net), policy=policy, rng=rng,
        **sim_kw)


def run_sim(spec: ScenarioSpec,
            iterations: Optional[int] = None) -> List[IterationMetrics]:
    sim = build_sim(spec)
    return sim.run(iterations if iterations is not None else spec.iterations)


# ---------------------------------------------------------------------------
# Layer (c): real-compute runtime
# ---------------------------------------------------------------------------

def runtime_batches(spec: ScenarioSpec, net: FlowNetwork
                    ) -> Dict[int, List[dict]]:
    """Per-data-node microbatches (one fixed batch reused every
    iteration, like the runtime tests — keeps loss trajectories
    comparable across layers and runs)."""
    from repro.data.pipeline import DataConfig, DataNodeShard

    cfg = model_config(spec)
    dns = [n.id for n in net.data_nodes()]
    out: Dict[int, List[dict]] = {}
    for i, dn in enumerate(dns):
        dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=spec.seq_len,
                        batch_size=spec.microbatches * spec.microbatch_size,
                        microbatch_size=spec.microbatch_size,
                        seed=spec.seed)
        out[dn] = DataNodeShard(dc, i, len(dns)).microbatches()
    return out


def build_runtime(spec: ScenarioSpec, *, lr: float = 3e-3,
                  policy_wrapper=None, **trainer_kw):
    """`(RuntimeTrainer, batches)` over the spec — same topology draw,
    same churn program, and the *same* policy/churn RNG stream as
    `build_sim` (construction order mirrored), so the two layers plan
    identical chain sets on rng-free churn programs."""
    from repro.core.runtime.trainer import RuntimeTrainer

    net, _ = build_network(spec)
    rng = _rng(spec, _SALT_POLICY)
    policy = make_policy(spec.scheduler, net, rng=rng)
    if policy_wrapper is not None:
        policy = policy_wrapper(policy)
    if spec.compression is not None:
        # non-trivial menu: boundary transfers follow the planner's
        # per-link codec choices unless the caller forces a codec
        trainer_kw.setdefault("wire_codec", "planner")
    trainer = RuntimeTrainer(
        model_config(spec), net, lr=lr, seed=spec.seed, rng=rng,
        policy=policy, churn_model=build_churn_model(spec, net),
        **trainer_kw)
    return trainer, runtime_batches(spec, net)


def run_runtime(spec: ScenarioSpec, iterations: Optional[int] = None,
                **kw) -> List[Any]:
    trainer, batches = build_runtime(spec, **kw)
    its = iterations if iterations is not None else spec.iterations
    return [trainer.iteration(batches) for _ in range(its)]


# ---------------------------------------------------------------------------
# Serving plane: arrival programs + the serving sim/runtime builders
# ---------------------------------------------------------------------------

def _arrivals_rng(spec: ScenarioSpec, clause_seed: int, clause_idx: int,
                  iteration: int) -> np.random.Generator:
    """Counter-based generator for one (clause, iteration) cell — the
    flaky-link seeding pattern, so arrival programs are a pure function
    of the spec with no cross-iteration or cross-clause stream
    coupling (clauses can be added/removed without reshuffling the
    others' draws)."""
    return np.random.default_rng(
        [spec.seed, _SALT_ARRIVALS, clause_seed, clause_idx, iteration])


def _clause_active(clause: Dict[str, Any], it: int) -> bool:
    at = int(clause.get("at_iteration", 0))
    dur = int(clause.get("duration", 0))
    return it >= at and (dur == 0 or it < at + dur)


def compile_arrivals(spec: ScenarioSpec) -> List[List[float]]:
    """Compile the spec's ``arrivals`` clauses into the open-loop
    request program: per-iteration sorted lists of arrival offsets in
    ``[0, 1)`` (fractions of the iteration horizon).

    ``poisson`` draws ``Poisson(rate)`` arrivals per active iteration
    at sorted-uniform offsets; ``diurnal`` modulates the rate with a
    raised cosine (trough at ``low_scale * rate``, period in
    iterations); ``spike`` lands ``requests`` simultaneous arrivals at
    fraction ``when`` of one iteration (the flash-crowd shape).  An
    empty program compiles to empty lists (RNG-free).
    """
    program: List[List[float]] = []
    for it in range(spec.iterations):
        offs: List[float] = []
        for idx, clause in enumerate(spec.arrivals):
            kind = clause["kind"]
            if not _clause_active(clause, it):
                continue
            if kind == "poisson":
                rng = _arrivals_rng(spec, int(clause.get("seed", 0)),
                                    idx, it)
                n = int(rng.poisson(float(clause["rate"])))
                offs.extend(float(u) for u in np.sort(rng.uniform(0, 1, n)))
            elif kind == "diurnal":
                low = float(clause.get("low_scale", 0.25))
                period = int(clause["period"])
                phase = (it - int(clause.get("at_iteration", 0))) % period
                scale = low + (1.0 - low) * 0.5 * (
                    1.0 + np.cos(2.0 * np.pi * phase / period))
                rng = _arrivals_rng(spec, int(clause.get("seed", 0)),
                                    idx, it)
                n = int(rng.poisson(float(clause["rate"]) * scale))
                offs.extend(float(u) for u in np.sort(rng.uniform(0, 1, n)))
            elif kind == "spike":
                if it == int(clause["at_iteration"]):
                    offs.extend([float(clause.get("when", 0.25))]
                                * int(clause["requests"]))
            else:  # pragma: no cover - validate() rejects unknown kinds
                raise ValueError(f"unknown arrival clause kind {kind!r}")
        offs.sort()
        program.append(offs)
    return program


def build_serving_sim(spec: ScenarioSpec, policy_wrapper=None, **kw):
    """`ServingEngine` over the spec: same topology draw, same policy +
    churn RNG stream as `build_sim`/`build_runtime` (construction order
    mirrored), decode requests from the compiled arrival program.  The
    spec's ``kv_weight`` lands on the network so residency feedback
    prices the next plan; ``kw`` reaches the engine (the bench uses
    ``reroute=False`` for the drop-and-retry baseline)."""
    from repro.core.sim.engine import ServingEngine

    net, _ = build_network(spec)
    net.kv_weight = spec.kv_weight
    rng = _rng(spec, _SALT_POLICY)
    policy = make_policy(spec.scheduler, net, rng=rng)
    if policy_wrapper is not None:
        policy = policy_wrapper(policy)
    return ServingEngine(
        net, policy, arrival_program=compile_arrivals(spec),
        churn_model=build_churn_model(spec, net),
        profile=model_profile(spec),
        prompt_len=spec.prompt_len, gen_tokens=spec.gen_tokens,
        serve_batch=spec.serve_batch,
        tokens_per_mb=spec.microbatch_size * spec.seq_len,
        rng=rng, **kw)


def run_serving_sim(spec: ScenarioSpec,
                    iterations: Optional[int] = None) -> List[Any]:
    eng = build_serving_sim(spec)
    return eng.run(iterations if iterations is not None else spec.iterations)


def build_serving_runtime(spec: ScenarioSpec, policy_wrapper=None, **kw):
    """`ServeTrainer` over the spec — real decode compute following the
    embedded engine's schedule, constructed with the *same* RNG stream
    discipline as `build_serving_sim` so the serving differential
    check can pin chain plans and TTFT/TPOT to exact equality."""
    from repro.core.runtime.serving import ServeTrainer

    net, _ = build_network(spec)
    net.kv_weight = spec.kv_weight
    rng = _rng(spec, _SALT_POLICY)
    policy = make_policy(spec.scheduler, net, rng=rng)
    if policy_wrapper is not None:
        policy = policy_wrapper(policy)
    return ServeTrainer(
        model_config(spec), net, policy=policy,
        arrival_program=compile_arrivals(spec),
        churn_model=build_churn_model(spec, net),
        profile=model_profile(spec),
        prompt_len=spec.prompt_len, gen_tokens=spec.gen_tokens,
        serve_batch=spec.serve_batch,
        tokens_per_mb=spec.microbatch_size * spec.seq_len,
        rng=rng, seed=spec.seed, **kw)
