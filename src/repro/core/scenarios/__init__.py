"""Scenario corpus + cross-layer differential harness.

One declarative `ScenarioSpec` (spec) deterministically materializes
the same training scenario for every execution layer in the repo
(generate): the GWTF flow engines and the `MinCostFlow` oracle, the
discrete-event simulator, and the reduced real-compute runtime.  The
differential/metamorphic harness (harness) checks the layers against
each other, and the committed corpus (corpus) pins ~12 named
scenarios — the paper's Table II/III regimes plus geo failure modes —
with golden metrics.
"""
from repro.core.scenarios.spec import (CHURN_CLAUSES,
                                       DETERMINISTIC_CLAUSES, ScenarioSpec)

__all__ = ["ScenarioSpec", "CHURN_CLAUSES", "DETERMINISTIC_CLAUSES"]
