"""Cross-layer differential / metamorphic harness over scenario specs.

One `ScenarioSpec` drives every execution layer the repo has; the
harness checks that they *agree*:

* `check_flow_equivalence` — the three flow engines (batched
  `GWTFProtocol`, its ``strict_rng`` scalar mode, and the frozen
  `ReferenceGWTFProtocol`) produce bit-identical flows, total cost,
  annealing temperature and RNG stream on the scenario — including
  after a scripted crash/reclaim/repair/rejoin episode;
* `check_optimal_consistency` — the `MinCostFlow` dial (bucket-queue)
  and dense Dijkstra cores find the same optimum on the scenario's
  layered graph;
* `check_sim_runtime_consistency` — the event simulator and the
  real-compute runtime, given the same spec, plan identical chain
  sets every iteration and agree on reroute/requeue/recompute
  accounting for deterministic churn programs;
* metamorphic invariants — `check_capacity_monotonicity` (adding
  relay capacity never increases the optimal cost of the same flow
  volume), `check_zero_churn` (no churn ⇒ no wasted GPU, no reroutes,
  and the runtime's trajectory is bit-identical to
  `CentralizedTrainer`), `check_permutation_invariance` (relabeling
  node ids preserves the optimum);
* `check_hierarchy_gap` — the hierarchical geo-planner
  (`flow.hierarchy.solve_hierarchical`) emits feasible chains within
  the committed optimality-gap bound of the flat dial MCMF oracle;
* `check_codec_agreement` — on scenarios with a ``compression``
  clause, the flow planner's per-edge codec choices, the simulator's
  bytes-on-wire accounting and the runtime's per-boundary wire codecs
  all derive from the same codec-choice matrix, and an fp32-only menu
  is bit-identical to no clause at all on every layer;
* `fuzz` — seeded randomized spec generation under a wall-clock
  budget; a failing spec is shrunk (`minimize`) to a minimal
  reproducer and written into the committed corpus directory so it
  becomes a named regression scenario on the next run.  Two sampling
  regimes: `random_spec` (tiny shapes, every check) and
  `random_scale_spec` (1000+ relays, the restricted `scale_checks`
  regime — no reference engine, no real compute).

Failures raise `ScenarioDiscrepancy` carrying the spec (as JSON) so a
reproducer is always one ``ScenarioSpec.from_json`` away.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.scenarios import generate
from repro.core.scenarios.spec import ADVERSARIAL_CLAUSES, ScenarioSpec


class ScenarioDiscrepancy(AssertionError):
    """Two layers (or two engines) disagreed on the same scenario."""

    def __init__(self, spec: ScenarioSpec, check: str, detail: str):
        self.spec = spec
        self.check = check
        self.detail = detail
        super().__init__(
            f"[{check}] {detail}\n--- failing spec ---\n{spec.to_json()}")


def _require(cond: bool, spec: ScenarioSpec, check: str, detail: str) -> None:
    if not cond:
        raise ScenarioDiscrepancy(spec, check, detail)


# ---------------------------------------------------------------------------
# Flow-layer differential: batched vs strict vs reference, bit-equal
# ---------------------------------------------------------------------------

def check_flow_equivalence(spec: ScenarioSpec, max_rounds: int = 120,
                           churn_episode: bool = True) -> Dict[str, Any]:
    """All three flow engines agree bit-for-bit on the scenario."""
    runs = {eng: generate.run_flow(spec, eng, max_rounds=max_rounds)
            for eng in generate.FLOW_ENGINES}
    ref = runs["reference"]
    for eng in ("batched", "strict"):
        r = runs[eng]
        _require(r.flows == ref.flows, spec, "flow-equivalence",
                 f"{eng}: flows diverged from reference "
                 f"({len(r.flows)} vs {len(ref.flows)} chains)")
        _require(r.total_cost == ref.total_cost, spec, "flow-equivalence",
                 f"{eng}: total cost {r.total_cost!r} != "
                 f"reference {ref.total_cost!r}")
        _require(r.temperature == ref.temperature, spec, "flow-equivalence",
                 f"{eng}: annealing temperature diverged")
        _require(r.rng_state == ref.rng_state, spec, "flow-equivalence",
                 f"{eng}: RNG stream diverged from reference")
    episode = None
    if churn_episode and ref.flows:
        episode = _flow_churn_episode(spec, runs)
    return {"flows": len(ref.flows), "total_cost": ref.total_cost,
            "rounds": ref.rounds, "churn_episode": episode}


def _flow_churn_episode(spec: ScenarioSpec, runs) -> Dict[str, Any]:
    """Crash the same deterministically-chosen relays in every engine,
    repair, rejoin, and re-check bit-equality (exercises remove_node /
    reclaim / add_node index maintenance on the scenario topology)."""
    flows = runs["reference"].flows
    victims = sorted({flows[0][1]} |
                     ({flows[-1][2]} if spec.num_stages > 1 else set()))
    for phase in ("crash", "rejoin"):
        for r in runs.values():
            for v in victims:
                if phase == "crash":
                    r.net.kill_node(v)
                    r.protocol.remove_node(v)
                else:
                    r.net.nodes[v].alive = True
                    r.protocol.add_node(r.net.nodes[v])
            r.protocol.reclaim_sink_slots()
            r.protocol.run(40, quiet_rounds=5)
        ref = runs["reference"].protocol
        for eng in ("batched", "strict"):
            p = runs[eng].protocol
            _require(p.complete_flows() == ref.complete_flows(), spec,
                     "flow-equivalence",
                     f"{eng}: flows diverged after {phase} of {victims}")
            _require(p.total_cost() == ref.total_cost(), spec,
                     "flow-equivalence",
                     f"{eng}: cost diverged after {phase} of {victims}")
            _require(p.rng.bit_generator.state ==
                     ref.rng.bit_generator.state, spec, "flow-equivalence",
                     f"{eng}: RNG stream diverged after {phase}")
    return {"victims": victims,
            "flows_after": len(ref.complete_flows())}


# ---------------------------------------------------------------------------
# Oracle differential: dial vs dense Dijkstra cores
# ---------------------------------------------------------------------------

def check_optimal_consistency(spec: ScenarioSpec) -> Dict[str, Any]:
    """`MinCostFlow` dial and dense cores agree on the scenario's
    layered graph (exact on the synthetic integer-cost topologies)."""
    net, cm = generate.build_network(spec)
    dense = generate.solve_optimal(spec, "dense", net=net, cost_matrix=cm)
    if spec.topology == "synthetic":
        net2, cm2 = generate.build_network(spec)
        dial = generate.solve_optimal(spec, "dial", net=net2,
                                      cost_matrix=cm2)
        _require(dial.flow == dense.flow, spec, "optimal-consistency",
                 f"dial flow {dial.flow} != dense flow {dense.flow}")
        _require(abs(dial.cost - dense.cost) <= 1e-6 * max(1.0, dense.cost),
                 spec, "optimal-consistency",
                 f"dial cost {dial.cost!r} != dense cost {dense.cost!r}")
        return {"flow": dense.flow, "cost": dense.cost, "methods": 2}
    return {"flow": dense.flow, "cost": dense.cost, "methods": 1}


# ---------------------------------------------------------------------------
# Sim vs runtime: plans and fault accounting
# ---------------------------------------------------------------------------

class RecordingPolicy:
    """Transparent `RoutingPolicy` wrapper recording per-iteration
    plans and recover() decisions without touching any RNG stream."""

    def __init__(self, inner):
        self.inner = inner
        self.name = inner.name
        self.plans: List[List[List[int]]] = []
        self.recover_calls: int = 0

    @property
    def protocol(self):
        return getattr(self.inner, "protocol", None)

    def plan(self):
        paths = self.inner.plan()
        self.plans.append([list(p) for p in paths])
        return paths

    def recover(self, view, mb, frm, dead, t):
        self.recover_calls += 1
        return self.inner.recover(view, mb, frm, dead, t)

    def on_rejoin(self, node):
        self.inner.on_rejoin(node)

    def on_crash(self, nid):
        self.inner.on_crash(nid)


def check_sim_runtime_consistency(spec: ScenarioSpec,
                                  iterations: Optional[int] = None
                                  ) -> Dict[str, Any]:
    """The simulator and the real-compute runtime, driven by the same
    spec, must agree on what was *planned* and on the shape of what
    went wrong.

    Checked every iteration:

    * identical planned chain sets (GWTF recovery draws no RNG, so the
      policy streams stay aligned across layers);
    * runtime conservation: ``completed + dropped == launched`` and
      ``fwd_recomputes + bwd_replays == rerouted``;
    * with a *deterministic* churn program: iterations whose crash set
      is empty are clean on both layers (no reroutes, no wasted GPU,
      no drops), and iterations where a planned relay crashes before
      mid-sweep produce repair activity on both layers.
    """
    its = iterations if iterations is not None else spec.iterations
    sim_rec: Dict[str, RecordingPolicy] = {}

    def wrap_sim(p):
        sim_rec["p"] = RecordingPolicy(p)
        return sim_rec["p"]

    sim = generate.build_sim(spec, policy_wrapper=wrap_sim)
    sim_metrics = sim.run(its)

    rt_rec: Dict[str, RecordingPolicy] = {}

    def wrap_rt(p):
        rt_rec["p"] = RecordingPolicy(p)
        return rt_rec["p"]

    trainer, batches = generate.build_runtime(spec, policy_wrapper=wrap_rt)
    rt_results = [trainer.iteration(batches) for _ in range(its)]

    sim_plans = sim_rec["p"].plans
    rt_plans = rt_rec["p"].plans
    _require(len(sim_plans) == len(rt_plans) == its, spec,
             "sim-runtime", "per-iteration plan counts diverged")
    if spec.scheduler == "gwtf":
        # SWARM's backward recovery replans with RNG draws, so its
        # streams legitimately diverge after the first fault; GWTF's
        # recovery is RNG-free and must stay in lock-step.
        for i, (a, b) in enumerate(zip(sim_plans, rt_plans)):
            _require(a == b, spec, "sim-runtime",
                     f"iteration {i}: planned chain sets diverged "
                     f"(sim {len(a)} chains vs runtime {len(b)})")

    for i, (m, r) in enumerate(zip(sim_metrics, rt_results)):
        _require(r.completed + r.dropped == r.launched, spec, "sim-runtime",
                 f"iteration {i}: runtime conservation violated "
                 f"({r.completed} + {r.dropped} != {r.launched})")
        _require(r.fwd_recomputes + r.bwd_replays == r.rerouted, spec,
                 "sim-runtime",
                 f"iteration {i}: runtime recompute accounting violated "
                 f"({r.fwd_recomputes} + {r.bwd_replays} != {r.rerouted})")
        _require(r.requeued <= r.rerouted, spec, "sim-runtime",
                 f"iteration {i}: requeued > rerouted")
        _require(r.deadline_requeues <= r.rerouted, spec, "sim-runtime",
                 f"iteration {i}: deadline_requeues "
                 f"{r.deadline_requeues} > rerouted {r.rerouted}")
        _require(m.completed <= m.launched, spec, "sim-runtime",
                 f"iteration {i}: sim completed > launched")
        _require(m.retries <= m.timeouts, spec, "sim-runtime",
                 f"iteration {i}: sim retries {m.retries} > fired "
                 f"deadline checks {m.timeouts}")
        if spec.microbatches >= spec.data_capacity:
            _require(r.launched == m.launched, spec, "sim-runtime",
                     f"iteration {i}: launch counts diverged "
                     f"(sim {m.launched} vs runtime {r.launched})")

    if spec.deterministic_churn:
        crash_plan = generate.iteration_crash_plan(spec)
        adv_plans = generate.iteration_adversarial_plan(spec)
        for i, (m, r) in enumerate(zip(sim_metrics, rt_results)):
            crashes = crash_plan.get(i, [])
            planned = {nid for chain in rt_plans[i] for nid in chain}
            on_plan_early = [nid for nid, when in crashes
                             if nid in planned and when <= 0.5]
            if not crashes and i in adv_plans:
                # adversarial faults legitimately cause reroutes, wasted
                # compute and drops without any crash; the fault-timeline
                # check pins their exact accounting instead
                continue
            if not crashes:
                _require(m.reroutes == 0 and m.wasted_gpu == 0.0, spec,
                         "sim-runtime",
                         f"iteration {i}: sim reports faults "
                         f"(reroutes={m.reroutes}, "
                         f"wasted={m.wasted_gpu}) on a crash-free "
                         f"iteration")
                _require(r.rerouted == 0 and r.dropped == 0, spec,
                         "sim-runtime",
                         f"iteration {i}: runtime reports faults on a "
                         f"crash-free iteration")
            elif on_plan_early and spec.scheduler == "gwtf":
                sim_saw = (m.reroutes > 0 or m.completed < m.launched
                           or m.wasted_gpu > 0.0)
                rt_saw = r.rerouted > 0 or r.dropped > 0
                _require(sim_saw, spec, "sim-runtime",
                         f"iteration {i}: relays {on_plan_early} crashed "
                         f"on-plan but the simulator saw no fault")
                _require(rt_saw, spec, "sim-runtime",
                         f"iteration {i}: relays {on_plan_early} crashed "
                         f"on-plan but the runtime saw no fault")
    return {
        "iterations": its,
        "sim_launched": [m.launched for m in sim_metrics],
        "runtime_launched": [r.launched for r in rt_results],
        "runtime_rerouted": sum(r.rerouted for r in rt_results),
        "sim_reroutes": sum(m.reroutes for m in sim_metrics),
    }


# ---------------------------------------------------------------------------
# Fault timeline: the shared beyond-fail-stop record (ISSUE 9)
# ---------------------------------------------------------------------------

def _adversarial_kinds(spec: ScenarioSpec) -> set:
    return {c["kind"] for c in spec.churn if c["kind"] in ADVERSARIAL_CLAUSES}


def check_fault_timeline(spec: ScenarioSpec,
                         iterations: Optional[int] = None) -> Dict[str, Any]:
    """The simulator and the runtime, driven by the same deterministic
    adversarial churn program, must produce *identical* fault
    timelines where the faults are cross-layer:

    * per-iteration injection counts for every fault class equal the
      static `iteration_adversarial_plan` view on both layers (the
      layers can't even disagree by both being wrong the same way);
    * per-iteration detection and repair counts agree exactly between
      the layers for the cross-layer fault classes (straggler,
      corrupt_gradient) — the deadline defense and the gradient screen
      fire the same number of times at the same iterations whether the
      training step is simulated or real.

    Flaky-link detection/repair is engine-local (the runtime performs
    no physical transfer legs) and is excluded by
    ``FaultTimeline.comparable_counts``; its injections still compare.
    """
    from repro.core.sim.timeline import CROSS_LAYER_FAULTS

    check = "fault-timeline"
    if not spec.deterministic_churn:
        raise ValueError(f"{spec.name}: check_fault_timeline needs a "
                         f"deterministic churn program")
    if not _adversarial_kinds(spec):
        raise ValueError(f"{spec.name}: check_fault_timeline needs at "
                         f"least one adversarial churn clause")
    its = iterations if iterations is not None else spec.iterations
    adv_plans = generate.iteration_adversarial_plan(spec)

    sim = generate.build_sim(spec)
    sim.run(its)
    sim_tl = sim.engine.timeline
    trainer, batches = generate.build_runtime(spec)
    for _ in range(its):
        trainer.iteration(batches)
    rt_tl = trainer.timeline

    # ---- injections vs the static plan, on both layers ----------------
    for layer, tl in (("sim", sim_tl), ("runtime", rt_tl)):
        counts = tl.counts()
        for it in range(its):
            plan = adv_plans.get(it)
            expect = {
                "straggler": (len(set(plan.slow) | set(plan.hung))
                              if plan else 0),
                "corrupt_gradient": len(plan.corrupt) if plan else 0,
                "flaky_link": plan.flaky_episodes if plan else 0,
            }
            for fault, want in expect.items():
                got = counts.get((it, fault, "injection"), 0)
                _require(got == want, spec, check,
                         f"{layer} iteration {it}: {fault} injections "
                         f"{got} != planned {want}")

    # ---- cross-layer detection / repair equality ----------------------
    sim_cmp = sim_tl.comparable_counts()
    rt_cmp = rt_tl.comparable_counts()
    if sim_cmp != rt_cmp:
        diff = {k: (sim_cmp.get(k, 0), rt_cmp.get(k, 0))
                for k in sorted(set(sim_cmp) | set(rt_cmp))
                if sim_cmp.get(k, 0) != rt_cmp.get(k, 0)}
        _require(False, spec, check,
                 f"fault timelines diverged (key -> (sim, runtime)): "
                 f"{diff}")
    detections = sum(v for (it, fault, kind), v in sim_cmp.items()
                     if kind == "detection" and fault in CROSS_LAYER_FAULTS)
    return {"iterations": its, "records": (len(sim_tl), len(rt_tl)),
            "cross_layer_detections": detections}


def check_detection_precision_recall(spec: ScenarioSpec,
                                     iterations: Optional[int] = None
                                     ) -> Dict[str, Any]:
    """The runtime gradient screen, on a deterministic corrupt-gradient
    program with a certainly-detectable mode ("perturb"/"zero"), has

    * recall 1.0 — every completed contribution whose final chain
      crossed a corrupt relay is detected (ground truth re-derived
      from the recorded per-iteration plans and the static adversarial
      plan, not from the screen's own bookkeeping);
    * precision 1.0 on attribution — every detection record names a
      relay the churn program actually corrupted that iteration.

    Sign-flip corruption is excluded by construction: near
    initialization honest per-microbatch gradients are close to
    orthogonal, so a flipped sign is statistically invisible — the
    corpus pins the detectable modes and documents the regime split.
    """
    check = "detection-precision-recall"
    corrupt_clauses = [c for c in spec.churn
                       if c["kind"] == "corrupt_gradient"]
    if not corrupt_clauses:
        raise ValueError(f"{spec.name}: needs a corrupt_gradient clause")
    undetectable = [c for c in corrupt_clauses
                    if c.get("mode", "perturb") not in ("perturb", "zero")]
    if undetectable:
        raise ValueError(f"{spec.name}: precision/recall is only exact "
                         f"for certainly-detectable modes, got "
                         f"{[c.get('mode') for c in undetectable]}")
    its = iterations if iterations is not None else spec.iterations
    adv_plans = generate.iteration_adversarial_plan(spec)

    rec: Dict[str, RecordingPolicy] = {}

    def wrap(p):
        rec["p"] = RecordingPolicy(p)
        return rec["p"]

    trainer, batches = generate.build_runtime(spec, policy_wrapper=wrap)
    results = [trainer.iteration(batches) for _ in range(its)]
    counts = trainer.timeline.counts()

    truths: List[int] = []
    detected: List[int] = []
    for it in range(its):
        plan = adv_plans.get(it)
        corrupt = set(plan.corrupt) if plan else set()
        # ground truth: planned chains crossing a corrupt relay, one
        # detection record per (contribution, corrupt stage hop); with
        # no crash clauses the final chain is the planned chain
        truth = sum(1 for chain in rec["p"].plans[it]
                    for nid in chain[1:-1] if nid in corrupt)
        got = counts.get((it, "corrupt_gradient", "detection"), 0)
        truths.append(truth)
        detected.append(got)
        _require(got == truth, spec, check,
                 f"iteration {it}: screen detected {got} corrupt "
                 f"contributions, ground truth {truth} (recall/precision "
                 f"broken)")
        _require(results[it].grads_flagged >= got, spec, check,
                 f"iteration {it}: {got} detections but only "
                 f"{results[it].grads_flagged} contributions excluded")
        for r_it, fault, kind, node in [
                (r.iteration, r.fault, r.kind, r.node)
                for r in trainer.timeline.records]:
            if r_it == it and fault == "corrupt_gradient" \
                    and kind == "detection":
                _require(node in corrupt, spec, check,
                         f"iteration {it}: detection accused node "
                         f"{node}, not a corrupt relay {sorted(corrupt)}")
    return {"iterations": its, "ground_truth": truths,
            "detected": detected}


# ---------------------------------------------------------------------------
# Metamorphic invariants
# ---------------------------------------------------------------------------

def check_capacity_monotonicity(spec: ScenarioSpec,
                                bumps: int = 3) -> Dict[str, Any]:
    """Adding relay capacity never increases the optimal cost of
    routing the *same* flow volume."""
    net, cm = generate.build_network(spec)
    base = generate.solve_optimal(spec, "dense", net=net, cost_matrix=cm)
    if base.flow <= 0:
        return {"flow": 0.0, "skipped": True}
    relays = [n for n in net.nodes.values() if not n.is_data]
    for k in range(min(bumps, len(relays))):
        relays[(k * 7919) % len(relays)].capacity += 1
    grown = generate.solve_optimal(spec, "dense", net=net, cost_matrix=cm,
                                   max_flow=base.flow)
    _require(grown.flow == base.flow, spec, "capacity-monotonicity",
             f"flow changed under a flow cap ({grown.flow} != {base.flow})")
    tol = 1e-9 * max(1.0, abs(base.cost))
    _require(grown.cost <= base.cost + tol, spec, "capacity-monotonicity",
             f"adding capacity increased optimal cost "
             f"({base.cost!r} -> {grown.cost!r})")
    return {"flow": base.flow, "cost": base.cost, "grown_cost": grown.cost}


def check_zero_churn(spec: ScenarioSpec,
                     iterations: Optional[int] = None,
                     runtime: bool = True) -> Dict[str, Any]:
    """Zero churn ⇒ a perfectly clean simulation (no wasted GPU, no
    reroutes, nothing truncated) and — for single-data-node scenarios —
    a runtime loss trajectory bit-identical to `CentralizedTrainer`
    on the same completed microbatch prefix."""
    if spec.churn:
        raise ValueError(f"{spec.name}: check_zero_churn needs an empty "
                         f"churn program")
    its = iterations if iterations is not None else spec.iterations
    metrics = generate.run_sim(spec, iterations=its)
    for i, m in enumerate(metrics):
        _require(m.wasted_gpu == 0.0, spec, "zero-churn",
                 f"iteration {i}: wasted_gpu={m.wasted_gpu} without churn")
        _require(m.reroutes == 0, spec, "zero-churn",
                 f"iteration {i}: reroutes={m.reroutes} without churn")
        _require(not m.truncated, spec, "zero-churn",
                 f"iteration {i}: truncated without churn")
        _require(m.completed == m.launched > 0, spec, "zero-churn",
                 f"iteration {i}: {m.completed}/{m.launched} completed")
        _require(m.timeouts == 0 and m.retries == 0, spec, "zero-churn",
                 f"iteration {i}: deadline fired without churn "
                 f"(timeouts={m.timeouts}, retries={m.retries})")
    result = {"iterations": its, "sim_completed":
              [m.completed for m in metrics]}
    if runtime and spec.num_data_nodes == 1:
        from repro.core.runtime.trainer import CentralizedTrainer

        trainer, batches = generate.build_runtime(spec)
        oracle, _ = generate.build_runtime(spec, remat=True)
        dn = next(iter(batches))
        cen = CentralizedTrainer(generate.model_config(spec),
                                 spec.num_stages, lr=3e-3, seed=spec.seed)
        rt_its = min(its, 3)       # real compute: keep the check cheap
        for i in range(rt_its):
            r = trainer.iteration(batches)
            _require(r.dropped == 0 and r.rerouted == 0, spec, "zero-churn",
                     f"iteration {i}: runtime repaired/dropped without "
                     f"churn")
            cl = cen.iteration(batches[dn][:r.completed])
            _require(r.loss == cl, spec, "zero-churn",
                     f"iteration {i}: decentralized loss {r.loss!r} != "
                     f"centralized {cl!r} (bit-equality broken)")
            # fused vs remat: the in-engine equality oracle (same
            # compiled programs, composed) must agree bitwise too
            ro = oracle.iteration(batches)
            _require(r.loss == ro.loss, spec, "zero-churn",
                     f"iteration {i}: fused loss {r.loss!r} != remat "
                     f"oracle {ro.loss!r} (bit-equality broken)")
        _require(trainer.stages.remat_recompute_count == 0, spec,
                 "zero-churn", "fused path recomputed a forward")
        result["runtime_iterations"] = rt_its
        result["store_peak_bytes"] = trainer.last_store_peak_bytes
    return result


def permuted_network(net, perm: Dict[int, int]):
    """Relabel node ids by ``perm`` (a bijection over all ids), keeping
    every attribute and permuting the link matrices accordingly."""
    from repro.core.flow.graph import FlowNetwork, Node

    n = net.latency.shape[0]
    inv = np.empty(n, np.int64)
    for old, new in perm.items():
        inv[new] = old
    nodes = {}
    for old, node in net.nodes.items():
        new = perm[old]
        nodes[new] = Node(new, node.stage, node.capacity, node.compute_cost,
                          is_data=node.is_data, alive=node.alive,
                          location=node.location)
    return FlowNetwork(nodes=nodes, num_stages=net.num_stages,
                       latency=net.latency[np.ix_(inv, inv)].copy(),
                       bandwidth=net.bandwidth[np.ix_(inv, inv)].copy(),
                       activation_size=net.activation_size,
                       codec_menu=net.codec_menu,
                       fidelity_budget=net.fidelity_budget,
                       fidelity_weight=net.fidelity_weight)


def check_permutation_invariance(spec: ScenarioSpec) -> Dict[str, Any]:
    """Relabeling node ids (data nodes fixed, relays permuted) must not
    change the centralized optimum."""
    net, cm = generate.build_network(spec)
    base = generate.solve_optimal(spec, "dense", net=net, cost_matrix=cm)
    n = net.latency.shape[0]
    relay_ids = [nid for nid, node in net.nodes.items() if not node.is_data]
    shuffled = list(relay_ids)
    rng = np.random.default_rng([spec.seed, 17])
    rng.shuffle(shuffled)
    perm = {nid: nid for nid in net.nodes}
    perm.update(dict(zip(relay_ids, shuffled)))
    pnet = permuted_network(net, perm)
    pcm = None
    if cm is not None:
        inv = np.empty(n, np.int64)
        for old, new in perm.items():
            inv[new] = old
        pcm = np.asarray(cm)[np.ix_(inv, inv)].copy()
    from repro.core.flow.mincost import solve_training_flow
    permuted = solve_training_flow(pnet, cost_matrix=pcm, method="dense")
    _require(permuted.flow == base.flow, spec, "permutation-invariance",
             f"optimal flow changed under relabeling "
             f"({base.flow} -> {permuted.flow})")
    exact = spec.topology == "synthetic"
    tol = 0.0 if exact else 1e-9 * max(1.0, abs(base.cost))
    _require(abs(permuted.cost - base.cost) <= tol, spec,
             "permutation-invariance",
             f"optimal cost changed under relabeling "
             f"({base.cost!r} -> {permuted.cost!r})")
    return {"flow": base.flow, "cost": base.cost}


#: committed hierarchical-vs-oracle optimality-gap bound.  The same
#: bound gates `benchmarks/bench_scale.py --smoke` and is recorded in
#: BENCH_scale.json meta (``hier_gap_bound``); measured gaps on the
#: bench topology sit at 1.03-1.10.
HIER_GAP_BOUND = 1.15


def check_hierarchy_gap(spec: ScenarioSpec,
                        gap_bound: float = HIER_GAP_BOUND) -> Dict[str, Any]:
    """`solve_hierarchical` produces a *feasible* plan (stage-ordered
    closed chains, relay and source capacities respected) whose total
    cost is within the committed gap bound of the flat dial MCMF
    oracle routing the same flow volume.  Geo-abstract topologies only
    — the gap bound is calibrated for per-location-pair base costs
    plus bounded node jitter, not arbitrary cost structure."""
    from repro.core.flow.hierarchy import solve_hierarchical

    net, cm = generate.build_network(spec)
    h = solve_hierarchical(net, cost_matrix=cm)
    S = net.num_stages
    used: Dict[int, int] = {}
    for path in h.paths:
        _require(len(path) == S + 2, spec, "hierarchy-gap",
                 f"chain has {len(path)} hops, expected {S + 2}")
        _require(path[0] == path[-1] and net.nodes[path[0]].is_data,
                 spec, "hierarchy-gap",
                 f"chain does not close at a data node: {path[0]} ... "
                 f"{path[-1]}")
        for hop in path[:-1]:      # origin once per chain + each relay
            used[hop] = used.get(hop, 0) + 1
        for s, nid in enumerate(path[1:-1]):
            node = net.nodes[nid]
            _require(not node.is_data and node.alive and node.stage == s,
                     spec, "hierarchy-gap",
                     f"hop {nid} at position {s} is not an alive "
                     f"stage-{s} relay")
    for nid, cnt in used.items():
        _require(cnt <= net.nodes[nid].capacity, spec, "hierarchy-gap",
                 f"node {nid} carries {cnt} chains over capacity "
                 f"{net.nodes[nid].capacity}")
    net2, cm2 = generate.build_network(spec)
    flat = generate.solve_optimal(spec, "dial", net=net2, cost_matrix=cm2,
                                  max_flow=h.flow)
    _require(flat.flow == h.flow, spec, "hierarchy-gap",
             f"flat oracle routed {flat.flow} units vs hierarchical "
             f"{h.flow}")
    gap = None
    if flat.cost > 0:
        gap = h.cost / flat.cost
        _require(gap <= gap_bound, spec, "hierarchy-gap",
                 f"optimality gap {gap:.4f} exceeds committed bound "
                 f"{gap_bound} (hier {h.cost!r} vs oracle {flat.cost!r})")
    return {"flow": h.flow, "hier_cost": h.cost, "oracle_cost": flat.cost,
            "gap": gap, "regions": h.num_regions}


def check_sim_invariants(spec: ScenarioSpec,
                         iterations: Optional[int] = None) -> Dict[str, Any]:
    """Cheap engine-level invariants that hold under *any* churn
    program — this is the fuzz check that actually samples the spec's
    churn clauses through the full event engine: conservation
    (completed <= launched), non-negative accounting, no event-budget
    runaway, and bit-determinism of a seeded rerun."""
    from repro.core.sim.metrics import summarize

    its = min(iterations if iterations is not None else spec.iterations, 3)
    first = generate.run_sim(spec, iterations=its)
    for i, m in enumerate(first):
        _require(0 <= m.completed <= m.launched, spec, "sim-invariants",
                 f"iteration {i}: completed {m.completed} out of "
                 f"[0, launched={m.launched}]")
        _require(m.wasted_gpu >= 0.0 and m.comm_time >= 0.0
                 and m.duration >= 0.0, spec, "sim-invariants",
                 f"iteration {i}: negative accounting "
                 f"(wasted={m.wasted_gpu}, comm={m.comm_time}, "
                 f"duration={m.duration})")
        _require(m.reroutes >= 0 and m.queue_depth_peak >= 0, spec,
                 "sim-invariants",
                 f"iteration {i}: negative reroute/queue accounting")
        _require(not m.truncated, spec, "sim-invariants",
                 f"iteration {i}: event budget exhausted on a tiny "
                 f"scenario (runaway event loop)")
    second = generate.run_sim(spec, iterations=its)
    _require(summarize(first) == summarize(second), spec, "sim-invariants",
             "seeded rerun diverged — simulator lost determinism")
    return {"iterations": its,
            "completed": [m.completed for m in first]}


def check_codec_agreement(spec: ScenarioSpec,
                          iterations: Optional[int] = None) -> Dict[str, Any]:
    """Compression clauses price consistently across every layer.

    * fp32-menu oracle: a spec whose menu is ``["fp32"]`` produces flows,
      total cost, annealing temperature, RNG stream and simulator
      summary *bit-identical* to the same spec with no compression
      clause at all (the codec machinery has a zero-cost off switch);
    * flow layer: every codec the protocol records per flow edge is on
      the spec's menu, admissible under the budget, and is the true
      per-edge price argmin (re-derived scalar-wise from the raw
      latency/bandwidth matrices, first-min tie-breaking);
    * sim layer: the chosen-codec histogram only names admissible
      codecs and ``bytes_on_wire`` equals the histogram folded against
      the codec ratios at the profile's activation size;
    * runtime layer: the per-boundary wire codecs the trainer applied
      are the modal choice over its planned chains in the *same*
      codec-choice matrix the flow layer exposes, and a non-trivial
      wire moves a positive number of encoded bytes.
    """
    from repro.core.flow.graph import WIRE_CODECS
    from repro.core.sim.metrics import summarize

    check = "codec-agreement"
    if spec.compression is None:
        raise ValueError(f"{spec.name}: check_codec_agreement needs a "
                         f"compression clause")

    # ---- fp32-menu oracle vs no clause at all -------------------------
    base = spec.replace(compression=None)
    fp32 = spec.replace(compression={"menu": ["fp32"]})
    rb = generate.run_flow(base)
    rf = generate.run_flow(fp32)
    _require(rf.flows == rb.flows and rf.total_cost == rb.total_cost,
             spec, check,
             f"fp32-only menu perturbed the flow outcome "
             f"({len(rf.flows)} chains / {rf.total_cost!r} vs "
             f"{len(rb.flows)} / {rb.total_cost!r})")
    _require(rf.temperature == rb.temperature
             and rf.rng_state == rb.rng_state, spec, check,
             "fp32-only menu perturbed the annealing/RNG stream")
    _require(summarize(generate.run_sim(fp32))
             == summarize(generate.run_sim(base)), spec, check,
             "fp32-only menu perturbed the simulator summary")

    # ---- flow layer: per-edge argmin ----------------------------------
    flow = generate.run_flow(spec)
    net = flow.net
    names = net.wire_codec_names()
    adm = net.admissible_codecs()
    budget = float(spec.compression.get("fidelity_budget", 0.0))
    menu = set(spec.compression["menu"])
    lat_avg = 0.5 * (net.latency + net.latency.T)
    bw_sum = net.bandwidth + net.bandwidth.T
    fw, size = net.fidelity_weight, net.activation_size
    hist: Dict[str, int] = {}
    for chain, chain_codecs in zip(flow.flows,
                                   flow.protocol.flow_codecs()):
        for (a, b), cname in zip(zip(chain, chain[1:]), chain_codecs):
            _require(cname in menu, spec, check,
                     f"edge ({a},{b}) chose {cname!r}, not on the menu")
            _require(cname == "fp32"
                     or WIRE_CODECS[cname].fidelity_penalty <= budget,
                     spec, check,
                     f"edge ({a},{b}) chose {cname!r} over the fidelity "
                     f"budget {budget}")
            prices = [lat_avg[a, b] + 2.0 * (c.ratio * size) / bw_sum[a, b]
                      + c.coder_rate * size + fw * c.fidelity_penalty
                      for c in adm]
            want = names[int(np.argmin(prices))]   # first-min, like argmin
            _require(cname == want, spec, check,
                     f"edge ({a},{b}) chose {cname!r} but the price "
                     f"argmin is {want!r}")
            hist[cname] = hist.get(cname, 0) + 1

    # ---- sim layer: histogram + bytes accounting ----------------------
    its = min(iterations if iterations is not None else spec.iterations, 3)
    sim = generate.build_sim(spec)
    act = sim.profile.activation_bytes
    ratio = {c.name: c.ratio for c in adm}
    for i, m in enumerate(sim.run(its)):
        legs = m.codec_legs or {}
        _require(set(legs) <= set(names), spec, check,
                 f"iteration {i}: sim histogram names inadmissible "
                 f"codecs {sorted(set(legs) - set(names))}")
        if legs:
            expect = sum(cnt * ratio[n] * act for n, cnt in legs.items())
            _require(abs(m.bytes_on_wire - expect)
                     <= 1e-6 * max(1.0, expect), spec, check,
                     f"iteration {i}: bytes_on_wire {m.bytes_on_wire!r} "
                     f"!= histogram fold {expect!r}")

    # ---- runtime layer: modal per-boundary choice ---------------------
    trainer, batches = generate.build_runtime(spec)
    r = trainer.iteration(batches)
    rt_names = list(r.wire_codecs)
    _require(all(n in menu for n in rt_names), spec, check,
             f"runtime applied off-menu codecs {rt_names}")
    tnet = trainer.net
    choice = tnet.wire_codec_matrix()
    tmenu = tnet.wire_codec_names()
    S = tnet.num_stages
    expected: List[str] = []
    for s in range(S - 1):
        votes: Dict[int, int] = {}
        for chain in trainer.last_chains:
            k = int(choice[chain[s + 1], chain[s + 2]])
            votes[k] = votes.get(k, 0) + 1
        expected.append(tmenu[min(votes, key=lambda k: (-votes[k], k))]
                        if votes else "fp32")
    if all(n == "fp32" for n in expected):
        expected = []
    _require(rt_names == expected, spec, check,
             f"runtime wire codecs {rt_names} != modal planner choice "
             f"{expected}")
    _require((r.wire_bytes > 0) == bool(rt_names), spec, check,
             f"runtime wire bytes {r.wire_bytes} inconsistent with "
             f"codecs {rt_names}")
    return {"flow_codec_hist": hist, "runtime_codecs": rt_names,
            "runtime_wire_bytes": r.wire_bytes}


# ---------------------------------------------------------------------------
# Serving plane: invariants + the sim<->runtime serving differential
# ---------------------------------------------------------------------------

def check_serving_invariants(spec: ScenarioSpec,
                             iterations: Optional[int] = None
                             ) -> Dict[str, Any]:
    """Numpy-only serving invariants (the serve-fuzz loop's check).

    * exact request conservation after every iteration:
      ``sum(admitted) == sum(completed) + sum(dropped) + in_flight``;
    * every admitted arrival is accounted for (admissions equal the
      compiled arrival program's request count);
    * latency sanity: TTFT/TPOT non-negative, first token after
      arrival, completion after first token;
    * seeded-rerun determinism: a second engine on the same spec
      reproduces the summary row and the chain plans exactly;
    * KV-residency triviality: with ``kv_weight == 0`` the network's
      residency state must never materialize (the serving-free
      bit-identity guarantee).
    """
    from repro.core.sim.metrics import summarize_serving

    check = "serving-invariants"
    its = iterations if iterations is not None else spec.iterations
    eng = generate.build_serving_sim(spec)
    ms = eng.run(its)
    cum_adm = cum_done = cum_drop = 0
    for i, m in enumerate(ms):
        cum_adm += m.admitted
        cum_done += m.completed
        cum_drop += m.dropped
        _require(cum_adm == cum_done + cum_drop + m.in_flight, spec, check,
                 f"iteration {i}: conservation violated ({cum_adm} != "
                 f"{cum_done} + {cum_drop} + {m.in_flight})")
        _require(m.queued <= m.in_flight, spec, check,
                 f"iteration {i}: queued {m.queued} > in_flight "
                 f"{m.in_flight}")
        _require(all(t >= 0.0 for t in m.ttfts)
                 and all(t >= 0.0 for t in m.tpots), spec, check,
                 f"iteration {i}: negative TTFT/TPOT")
    expected = sum(len(p) for p in generate.compile_arrivals(spec)[:its])
    _require(cum_adm == expected, spec, check,
             f"admissions {cum_adm} != compiled arrivals {expected}")
    for rid, rec in eng.requests.items():
        if rec.first_token is not None:
            _require(rec.first_token >= rec.arrival, spec, check,
                     f"request {rid}: first token before arrival")
        if rec.completion is not None:
            _require(rec.first_token is not None
                     and rec.completion >= rec.first_token, spec, check,
                     f"request {rid}: completion before first token")
    eng2 = generate.build_serving_sim(spec)
    ms2 = eng2.run(its)
    _require(summarize_serving(ms) == summarize_serving(ms2), spec, check,
             "seeded rerun changed the serving summary")
    _require(eng.chain_plans == eng2.chain_plans, spec, check,
             "seeded rerun changed the serving chain plans")
    if spec.kv_weight == 0.0:
        _require(not eng.net.kv_active(), spec, check,
                 "kv_weight == 0 but residency state materialized on "
                 "the network")
    return {"iterations": its, "admitted": cum_adm,
            "completed": cum_done, "dropped": cum_drop,
            "summary": summarize_serving(ms)}


def check_serving_consistency(spec: ScenarioSpec,
                              iterations: Optional[int] = None
                              ) -> Dict[str, Any]:
    """The serving simulator and the real-compute decode executor,
    driven by the same spec, must agree *exactly*.

    * identical per-iteration planned chain sets (both the recorded
      ``policy.plan()`` output and the engines' deduplicated serving
      chains) — decode requests ride the same flow plans on both
      layers;
    * bit-identical per-iteration serving ledgers and TTFT/TPOT lists
      (the executor adds no timing of its own, so any divergence is a
      scheduling bug);
    * identical fault timelines (serving crashes recorded verbatim);
    * every request the engine marks completed holds a full
      ``gen_tokens`` decoded stream in the executor;
    * zero-churn specs: the executor's token streams are bit-identical
      to the standalone ``launch/serve.py``-style sequential decode on
      the same reduced config and seed (text architectures).
    """
    from repro.core.sim.metrics import summarize_serving

    check = "serving-consistency"
    its = iterations if iterations is not None else spec.iterations

    sim_rec: Dict[str, RecordingPolicy] = {}

    def wrap_sim(p):
        sim_rec["p"] = RecordingPolicy(p)
        return sim_rec["p"]

    eng = generate.build_serving_sim(spec, policy_wrapper=wrap_sim)
    sim_ms = eng.run(its)

    rt_rec: Dict[str, RecordingPolicy] = {}

    def wrap_rt(p):
        rt_rec["p"] = RecordingPolicy(p)
        return rt_rec["p"]

    tr = generate.build_serving_runtime(spec, policy_wrapper=wrap_rt)
    rt_ms = tr.run(its)

    if spec.scheduler == "gwtf":
        for i, (a, b) in enumerate(zip(sim_rec["p"].plans,
                                       rt_rec["p"].plans)):
            _require(a == b, spec, check,
                     f"iteration {i}: planned chain sets diverged "
                     f"(sim {len(a)} vs runtime {len(b)})")
        _require(eng.chain_plans == tr.engine.chain_plans, spec, check,
                 "serving chain plans diverged between layers")
    for i, (a, b) in enumerate(zip(sim_ms, rt_ms)):
        _require(a == b, spec, check,
                 f"iteration {i}: serving ledgers diverged "
                 f"(sim {a} vs runtime {b})")
    _require(summarize_serving(sim_ms) == summarize_serving(rt_ms), spec,
             check, "serving summaries diverged")
    _require(eng.timeline.records == tr.engine.timeline.records, spec,
             check, "serving fault timelines diverged")
    for rid, rec in tr.engine.requests.items():
        if rec.completion is not None:
            got = len(tr.token_stream(rid))
            _require(got == spec.gen_tokens, spec, check,
                     f"request {rid}: completed with {got} of "
                     f"{spec.gen_tokens} tokens decoded")
    streams_checked = 0
    if not spec.churn:
        import jax.numpy as jnp

        from repro.core.runtime.serving import serving_inputs
        from repro.models.transformer import (decode_step, init_cache,
                                              prefill)

        cfg = generate.model_config(spec)
        params, prompt, _, _, _ = serving_inputs(
            cfg, seed=spec.seed, batch=tr.max_requests,
            prompt_len=spec.prompt_len)
        done = sorted(rid for rid, rec in tr.engine.requests.items()
                      if rec.completion is not None
                      and rid < tr.max_requests)[:2]
        for rid in done:
            cache = init_cache(cfg, 1, spec.prompt_len + spec.gen_tokens,
                               dtype=jnp.float32)
            logits, cache = prefill(params, cfg,
                                    tokens=prompt[rid:rid + 1],
                                    cache=cache)
            toks = [int(jnp.argmax(logits, -1)[0])]
            for j in range(spec.gen_tokens - 1):
                logits, cache = decode_step(
                    params, cfg,
                    tokens=jnp.asarray([[toks[-1]]], jnp.int32),
                    cache=cache, index=jnp.int32(spec.prompt_len + j))
                toks.append(int(jnp.argmax(logits, -1)[0]))
            _require(toks == tr.token_stream(rid), spec, check,
                     f"request {rid}: zero-churn stream diverged from "
                     f"the standalone decode path")
            streams_checked += 1
    return {"iterations": its, "summary": summarize_serving(sim_ms),
            "prefill_calls": tr.prefill_calls,
            "decode_dispatches": tr.decode_dispatches,
            "stacked_rows": tr.stacked_rows,
            "replay_steps": tr.replay_steps,
            "streams_checked": streams_checked}


# ---------------------------------------------------------------------------
# Check registry / corpus sweep
# ---------------------------------------------------------------------------

#: name -> (callable, applicability predicate)
CHECKS: Dict[str, Tuple[Callable[[ScenarioSpec], Dict], Callable]] = {
    "flow-equivalence": (check_flow_equivalence, lambda s: True),
    "optimal-consistency": (check_optimal_consistency, lambda s: True),
    "capacity-monotonicity": (check_capacity_monotonicity, lambda s: True),
    "permutation-invariance": (check_permutation_invariance,
                               lambda s: True),
    "zero-churn": (check_zero_churn, lambda s: not s.churn),
    "sim-invariants": (check_sim_invariants, lambda s: True),
    "sim-runtime": (check_sim_runtime_consistency,
                    lambda s: s.scheduler == "gwtf"),
    "fault-timeline": (check_fault_timeline,
                       lambda s: (s.scheduler == "gwtf"
                                  and s.deterministic_churn
                                  and bool(_adversarial_kinds(s)))),
    "detection-precision-recall": (
        check_detection_precision_recall,
        lambda s: (s.scheduler == "gwtf" and s.deterministic_churn
                   and all(c["kind"] in ADVERSARIAL_CLAUSES
                           for c in s.churn)
                   and any(c["kind"] == "corrupt_gradient"
                           and c.get("mode", "perturb") in ("perturb",
                                                            "zero")
                           for c in s.churn)
                   and not any(c["kind"] == "corrupt_gradient"
                               and c.get("mode", "perturb") not in
                               ("perturb", "zero")
                               for c in s.churn))),
    "hierarchy-gap": (check_hierarchy_gap,
                      lambda s: s.topology == "geo-abstract"),
    "codec-agreement": (check_codec_agreement,
                        lambda s: s.compression is not None),
    "serving-invariants": (check_serving_invariants,
                           lambda s: s.has_arrivals),
    "serving-consistency": (check_serving_consistency,
                            lambda s: (s.has_arrivals
                                       and s.scheduler == "gwtf")),
}

#: checks cheap enough for the fuzz loop (no real JAX compute).
#: sim-invariants is what exercises the generated churn programs — the
#: flow/oracle checks never sample them.
FUZZ_CHECKS = ("flow-equivalence", "optimal-consistency",
               "capacity-monotonicity", "permutation-invariance",
               "sim-invariants")

#: checks for the randomized scale-tier fuzz loop (1000+ relays):
#: everything quadratic-in-nodes or running the frozen reference
#: engine is out; the event engine + hierarchical planner are in.
SCALE_FUZZ_CHECKS = ("sim-invariants", "hierarchy-gap")


def scale_checks(spec: ScenarioSpec) -> Tuple[str, ...]:
    """The check set a ``tier="scale"`` corpus spec is swept with.

    The engine-vs-reference bit-equality differential (including its
    crash→repair→rejoin episode) runs only up to ~600 nodes — the
    frozen reference engine is O(N²) per round and exists to be an
    oracle, not to scale.  `sim-invariants` (full event engine +
    planner under the spec's churn program, determinism via seeded
    rerun) runs everywhere; `hierarchy-gap` wherever the hierarchical
    planner applies.  The real-compute `sim-runtime` differential is
    never part of the scale tier."""
    names: List[str] = []
    if spec.base_nodes <= 600:
        names.append("flow-equivalence")
    names.append("sim-invariants")
    if spec.topology == "geo-abstract":
        names.append("hierarchy-gap")
    return tuple(names)


def run_checks(spec: ScenarioSpec,
               checks: Optional[Sequence[str]] = None) -> Dict[str, Any]:
    """Run the named (or all applicable) checks; raises on the first
    discrepancy, returns per-check summaries otherwise."""
    names = checks if checks is not None else list(CHECKS)
    out: Dict[str, Any] = {}
    for name in names:
        fn, applicable = CHECKS[name]
        if not applicable(spec):
            out[name] = {"skipped": True}
            continue
        out[name] = fn(spec)
    return out


# ---------------------------------------------------------------------------
# Fuzzing with shrinking
# ---------------------------------------------------------------------------

def random_spec(rng: np.random.Generator, index: int) -> ScenarioSpec:
    """One random small scenario (kept tiny: the fuzz loop's value is
    breadth of shapes, not node count)."""
    topology = "geo" if rng.uniform() < 0.5 else "synthetic"
    num_stages = int(rng.integers(2, 5))
    spec = ScenarioSpec(
        name=f"fuzz-{index}",
        seed=int(rng.integers(0, 2 ** 16)),
        topology=topology,
        num_stages=num_stages,
        relays_per_stage=int(rng.integers(2, 5)),
        num_data_nodes=int(rng.integers(1, 3)),
        data_capacity=int(rng.integers(2, 5)),
        capacity_range=(1, int(rng.integers(2, 5))),
        cost_range=(1, int(rng.integers(3, 21))),
        source_capacity=int(rng.integers(2, 5)),
        num_locations=int(rng.integers(2, 11)),
        compute_jitter=float(rng.uniform(0.0, 0.4)),
        iterations=2,
        objective="sum" if rng.uniform() < 0.5 else "minmax",
    )
    clauses: List[Dict[str, Any]] = []
    if topology == "geo" and rng.uniform() < 0.5:
        clauses.append({"kind": "regional_blackout",
                        "location": int(rng.integers(0, spec.num_locations)),
                        "at_iteration": 0,
                        "duration": 1,
                        "when": float(rng.uniform(0.1, 0.9))})
    if rng.uniform() < 0.5:
        clauses.append({"kind": "bernoulli",
                        "p": float(rng.uniform(0.0, 0.3))})
    if topology == "geo" and rng.uniform() < 0.3:
        clauses.append({"kind": "link_degradation", "at_iteration": 0,
                        "factor": float(rng.uniform(1.5, 8.0)),
                        "duration": 1})
    spec = spec.replace(churn=clauses)
    if topology == "geo" and rng.uniform() < 0.3:
        spare = int(rng.integers(1, 4))
        spec = spec.replace(spare_nodes=spare, churn=spec.churn + [
            {"kind": "flash_crowd", "at_iteration": 1, "nodes": spare}])
    if topology == "geo" and rng.uniform() < 0.3:
        # a random codec-menu prefix under a random budget: exercises
        # codec-aware pricing through flow-equivalence + sim-invariants
        # (check_codec_agreement itself stays out of the fuzz set — its
        # runtime leg runs real JAX compute)
        menu = ["fp32", "bf16", "int8", "top-k"]
        spec = spec.replace(compression={
            "menu": menu[:int(rng.integers(2, 5))],
            "fidelity_budget": float(rng.choice([0.004, 0.02, 0.1])),
            "fidelity_weight": float(rng.uniform(0.1, 2.0))})
    return spec


#: checks for the adversarial fuzz loop: `sim-invariants` pushes the
#: sampled straggler/corrupt/flaky programs through the full event
#: engine (deadline checks, hedged re-dispatch, modelled screen,
#: reputation) including the seeded-rerun determinism gate.  The
#: real-compute cross-layer checks stay out — they run JAX per case.
ADVERSARIAL_FUZZ_CHECKS = ("sim-invariants",)


def random_adversarial_spec(rng: np.random.Generator,
                            index: int) -> ScenarioSpec:
    """One random small scenario whose churn program samples the
    beyond-fail-stop fault classes (optionally mixed with crashes)."""
    topology = "geo" if rng.uniform() < 0.5 else "synthetic"
    spec = ScenarioSpec(
        name=f"adv-fuzz-{index}",
        seed=int(rng.integers(0, 2 ** 16)),
        topology=topology,
        num_stages=int(rng.integers(2, 4)),
        relays_per_stage=int(rng.integers(2, 5)),
        num_data_nodes=1,
        data_capacity=int(rng.integers(2, 5)),
        capacity_range=(1, int(rng.integers(2, 5))),
        iterations=2,
        objective="sum" if rng.uniform() < 0.5 else "minmax",
    )
    first_relay = spec.num_data_nodes
    relays = list(range(first_relay, first_relay + spec.num_relays))
    clauses: List[Dict[str, Any]] = []
    if rng.uniform() < 0.7:
        k = int(rng.integers(1, max(2, len(relays) // 3)))
        nodes = sorted(int(n) for n in
                       rng.choice(relays, size=k, replace=False))
        clauses.append({"kind": "straggler", "nodes": nodes,
                        "factor": float(rng.uniform(1.5, 30.0)),
                        "hang": bool(rng.uniform() < 0.4),
                        "at_iteration": int(rng.integers(0, 2)),
                        "duration": int(rng.integers(0, 3))})
    if rng.uniform() < 0.6:
        k = int(rng.integers(1, max(2, len(relays) // 4)))
        nodes = sorted(int(n) for n in
                       rng.choice(relays, size=k, replace=False))
        clauses.append({"kind": "corrupt_gradient", "nodes": nodes,
                        "mode": ["perturb", "zero",
                                 "sign_flip"][int(rng.integers(0, 3))],
                        "scale": float(rng.uniform(0.5, 4.0)),
                        "seed": int(rng.integers(0, 2 ** 16)),
                        "at_iteration": int(rng.integers(0, 2)),
                        "duration": int(rng.integers(0, 3))})
    if rng.uniform() < 0.5:
        clauses.append({"kind": "flaky_link",
                        "p": float(rng.uniform(0.0, 0.4)),
                        "seed": int(rng.integers(0, 2 ** 16))})
    if rng.uniform() < 0.3:
        clauses.append({"kind": "bernoulli",
                        "p": float(rng.uniform(0.0, 0.2))})
    if not clauses:
        clauses.append({"kind": "flaky_link", "p": 0.2,
                        "seed": int(rng.integers(0, 2 ** 16))})
    return spec.replace(churn=clauses)


#: checks for the serving fuzz loop: `serving-invariants` pushes the
#: sampled arrival programs + churn through the ServingEngine
#: (conservation, latency sanity, seeded-rerun determinism) without
#: real compute.  `serving-consistency` stays out — it decodes real
#: tokens per case.
SERVE_FUZZ_CHECKS = ("serving-invariants",)


def random_serving_spec(rng: np.random.Generator,
                        index: int) -> ScenarioSpec:
    """One random small serving scenario: an arrival program (always at
    least a Poisson clause, optionally spike/diurnal), a decode shape,
    sometimes KV-residency pricing, sometimes churn hitting mid-run."""
    topology = "geo" if rng.uniform() < 0.6 else "synthetic"
    spec = ScenarioSpec(
        name=f"serve-fuzz-{index}",
        seed=int(rng.integers(0, 2 ** 16)),
        topology=topology,
        num_stages=int(rng.integers(2, 4)),
        relays_per_stage=int(rng.integers(2, 5)),
        num_data_nodes=1,
        data_capacity=int(rng.integers(2, 5)),
        iterations=2,
        prompt_len=int(rng.integers(4, 17)),
        gen_tokens=int(rng.integers(2, 33)),
        serve_batch=int(rng.integers(1, 5)),
        kv_weight=float(rng.choice([0.0, 0.0, 0.5, 2.0])),
    )
    arrivals: List[Dict[str, Any]] = [
        {"kind": "poisson", "rate": float(rng.uniform(0.5, 4.0)),
         "seed": int(rng.integers(0, 2 ** 16))}]
    if rng.uniform() < 0.4:
        arrivals.append({"kind": "spike",
                         "at_iteration": int(rng.integers(0, 2)),
                         "requests": int(rng.integers(1, 9)),
                         "when": float(rng.uniform(0.05, 1.0))})
    if rng.uniform() < 0.3:
        arrivals.append({"kind": "diurnal",
                         "rate": float(rng.uniform(1.0, 4.0)),
                         "period": int(rng.integers(1, 5)),
                         "low_scale": float(rng.uniform(0.0, 1.0)),
                         "seed": int(rng.integers(0, 2 ** 16))})
    clauses: List[Dict[str, Any]] = []
    if rng.uniform() < 0.6:
        clauses.append({"kind": "bernoulli",
                        "p": float(rng.uniform(0.0, 0.3))})
    if rng.uniform() < 0.3:
        relay = int(rng.integers(spec.num_data_nodes,
                                 spec.num_data_nodes + spec.num_relays))
        clauses.append({"kind": "trace", "events": [
            (int(rng.integers(0, 2)), "crash", relay,
             float(rng.uniform(0.1, 0.9)))]})
    return spec.replace(arrivals=arrivals, churn=clauses)


def random_scale_spec(rng: np.random.Generator, index: int) -> ScenarioSpec:
    """One random *internet-scale* scenario (1000+ relays, mostly
    geo-abstract) for the scale-tier fuzz loop.  Cost ranges stay in
    the bench_scale regime (per-location-pair base + bounded node
    jitter) — that is the structure the hierarchical planner's gap
    bound is calibrated for."""
    topology = "geo-abstract" if rng.uniform() < 0.75 else "synthetic"
    num_stages = int(rng.choice([5, 8, 10]))
    relays_per_stage = int(rng.integers(1000, 1801)) // num_stages
    num_data_nodes = int(rng.integers(1, 3))
    relays = num_stages * relays_per_stage
    spec = ScenarioSpec(
        name=f"scale-fuzz-{index}",
        seed=int(rng.integers(0, 2 ** 16)),
        tier="scale",
        topology=topology,
        num_stages=num_stages,
        relays_per_stage=relays_per_stage,
        num_data_nodes=num_data_nodes,
        data_capacity=4,
        capacity_range=(1, int(rng.integers(3, 5))),
        cost_range=(int(rng.integers(3, 6)), int(rng.integers(18, 25))),
        source_capacity=max(4, relays // (20 * num_data_nodes)),
        num_locations=int(rng.integers(8, 13)),
        iterations=2,
        objective="sum",
    )
    clauses: List[Dict[str, Any]] = []
    if rng.uniform() < 0.6:
        clauses.append({"kind": "bernoulli",
                        "p": float(rng.uniform(0.0, 0.2))})
    if topology == "geo-abstract" and rng.uniform() < 0.4:
        clauses.append({"kind": "regional_blackout",
                        "location": int(rng.integers(0, spec.num_locations)),
                        "at_iteration": 0, "duration": 1,
                        "when": float(rng.uniform(0.1, 0.9))})
    return spec.replace(churn=clauses)


def _fails(spec: ScenarioSpec, checks: Sequence[str]
           ) -> Optional[ScenarioDiscrepancy]:
    try:
        run_checks(spec, checks)
        return None
    except ScenarioDiscrepancy as e:
        return e
    except Exception as e:                       # noqa: BLE001
        # a crash-class bug (IndexError deep in an engine, a numerical
        # blow-up in the oracle, ...) is exactly what differential
        # fuzzing is for: wrap it so the shrink+commit pipeline runs on
        # it instead of aborting the session with a spec-less traceback
        return ScenarioDiscrepancy(
            spec, f"crash:{type(e).__name__}", repr(e))


_SHRINK_PASSES: Tuple[Tuple[str, Callable[[ScenarioSpec], Dict]], ...] = (
    ("drop-compression", lambda s: {"compression": None}),
    ("drop-arrivals", lambda s: {"arrivals": s.arrivals[:-1]}),
    ("fewer-gen-tokens", lambda s: {"gen_tokens":
                                    max(1, s.gen_tokens // 2)}),
    ("drop-adversarial", lambda s: {
        "churn": [c for c in s.churn
                  if c["kind"] not in ADVERSARIAL_CLAUSES]}),
    ("drop-churn", lambda s: {"churn": s.churn[:-1],
                              "spare_nodes": 0
                              if not any(c["kind"] == "flash_crowd"
                                         for c in s.churn[:-1])
                              else s.spare_nodes}),
    ("fewer-relays", lambda s: {"relays_per_stage": s.relays_per_stage - 1}),
    ("fewer-stages", lambda s: {"num_stages": s.num_stages - 1}),
    ("one-source", lambda s: {"num_data_nodes": 1}),
    ("no-jitter", lambda s: {"compute_jitter": 0.0}),
    ("tight-caps", lambda s: {"capacity_range": (1, 2)}),
    ("tight-costs", lambda s: {"cost_range": (1, 3)}),
    ("fewer-iterations", lambda s: {"iterations": 1}),
)


def minimize(spec: ScenarioSpec, checks: Sequence[str],
             max_attempts: int = 64) -> ScenarioSpec:
    """Greedy shrink: repeatedly try simplifying edits, keeping any
    that still reproduce a discrepancy.  Deterministic (no RNG)."""
    current = spec
    attempts = 0
    improved = True
    while improved and attempts < max_attempts:
        improved = False
        for tag, edit in _SHRINK_PASSES:
            attempts += 1
            if attempts >= max_attempts:
                break
            try:
                candidate = current.replace(**edit(current))
            except (ValueError, TypeError):
                continue                     # edit made the spec invalid
            if candidate == current:
                continue
            if _fails(candidate, checks) is not None:
                current = candidate
                improved = True
    return current


@dataclass
class FuzzFailure:
    spec: ScenarioSpec
    minimized: ScenarioSpec
    check: str
    detail: str
    written_to: Optional[str] = None


@dataclass
class FuzzReport:
    seed: int
    budget_seconds: float
    cases: int = 0
    elapsed: float = 0.0
    failures: List[FuzzFailure] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.failures


def fuzz(seed: int = 0, budget_seconds: float = 10.0,
         corpus_dir: Optional[str] = None,
         checks: Sequence[str] = FUZZ_CHECKS,
         max_cases: Optional[int] = None,
         spec_factory: Callable[[np.random.Generator, int],
                                ScenarioSpec] = random_spec,
         shrink: bool = True) -> FuzzReport:
    """Seeded randomized differential testing under a wall-clock budget.

    Each failing case is shrunk with `minimize` and (when
    ``corpus_dir`` is given — defaulting it to the committed corpus
    directory is the caller's choice) written as
    ``shrunk-<check>-<seed>.json`` so it permanently joins the corpus.

    ``spec_factory`` picks the sampling regime: `random_spec` (tiny
    shapes, the default) or `random_scale_spec` (1000+ relays swept
    with `SCALE_FUZZ_CHECKS`).  Pass ``shrink=False`` at scale —
    `minimize` steps one relay at a time, which is useless against
    thousand-relay specs; the unshrunk reproducer is still committed.
    """
    rng = np.random.default_rng(seed)
    report = FuzzReport(seed=seed, budget_seconds=budget_seconds)
    t0 = time.monotonic()
    while time.monotonic() - t0 < budget_seconds:
        if max_cases is not None and report.cases >= max_cases:
            break
        spec = spec_factory(rng, report.cases)
        report.cases += 1
        err = _fails(spec, checks)
        if err is None:
            continue
        small = minimize(spec, checks) if shrink else spec
        small_err = _fails(small, checks) or err
        failure = FuzzFailure(spec=spec, minimized=small,
                              check=small_err.check,
                              detail=small_err.detail)
        if corpus_dir:
            os.makedirs(corpus_dir, exist_ok=True)
            named = small.replace(
                name=f"shrunk-{small_err.check}-{spec.seed}")
            path = os.path.join(corpus_dir, f"{named.name}.json")
            with open(path, "w") as fh:
                fh.write(named.to_json() + "\n")
            failure.written_to = path
        report.failures.append(failure)
    report.elapsed = time.monotonic() - t0
    return report
