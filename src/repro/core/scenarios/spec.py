"""Declarative scenario specification (the corpus' single source of truth).

A `ScenarioSpec` names everything the three execution layers need to
materialize *the same* training scenario deterministically:

* topology — geo-distributed (paper Sec. VI: 10 locations, 50-500 Mb/s
  links, heterogeneous compute), abstract synthetic (paper Tables
  IV/V: integer d_ij drawn directly), or geo-abstract (bench_scale's
  internet-scale shape: integer per-location-pair base costs + node
  jitter with ``Node.location`` stamped, so the hierarchical planner
  and location-keyed churn both apply at 1000+ relays), node counts,
  capacity ranges, per-region compute/bandwidth heterogeneity, and a
  pool of *spare* nodes (created dead) for flash-crowd joins;
* churn program — an ordered list of clauses composed into one
  `ChurnModel`: Bernoulli coin-flips, deterministic trace replay,
  scripted regional blackouts, correlated regional outages,
  flash-crowd joins, link degradation;
* model family and run shape — the reduced model config the
  real-compute runtime trains, the simulator profile derived from it,
  iterations and per-data-node microbatch provisioning;
* seed — every random draw in the generator is keyed on
  ``(spec.seed, fixed salt)`` so the same spec always materializes the
  same networks, plans and faults across the flow, sim, and runtime
  layers.

Specs round-trip through plain dicts/JSON (`to_dict` / `from_dict`);
`from_dict` rejects unknown fields and `validate()` rejects
out-of-range or cross-field-inconsistent values, so a corpus file that
drifts from the schema fails loudly instead of silently running a
different scenario.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

#: churn clause schema: kind -> (required fields, {optional: default}).
CHURN_CLAUSES: Dict[str, Tuple[Tuple[str, ...], Dict[str, Any]]] = {
    # independent per-relay crash/rejoin coin flips (paper Sec. VI)
    "bernoulli": (("p",), {}),
    # deterministic replay: events = [[iteration, "crash"|"rejoin",
    # node_id(, when)], ...]
    "trace": (("events",), {}),
    # scripted blackout: every relay in `location` crashes at
    # `at_iteration` (fraction `when` into it), rejoins `duration`
    # iterations later
    "regional_blackout": (("location", "at_iteration"),
                          {"duration": 2, "when": 0.25}),
    # correlated random outages keyed on Node.location
    "regional_outage": (("outage_prob",),
                        {"severity": 1.0, "rejoin_prob": 0.5}),
    # `nodes` spare relays (pre-created dead) join at `at_iteration`
    "flash_crowd": (("at_iteration", "nodes"), {}),
    # inter-region bandwidth divided by `factor` at `at_iteration`,
    # restored `duration` iterations later (0 = permanent)
    "link_degradation": (("at_iteration", "factor"),
                         {"duration": 0, "inter_region_only": True}),
    # beyond fail-stop (adversarial fault classes, PR 9) -----------------
    # `nodes` stay alive but compute `factor`x slower (hang=True: stall
    # forever — only a sender-side deadline catches them) inside the
    # window [at_iteration, at_iteration+duration) (duration 0 = forever)
    "straggler": (("nodes",),
                  {"factor": 4.0, "hang": False,
                   "at_iteration": 0, "duration": 0}),
    # `nodes` complete backward on time but return perturbed gradients
    # (mode: "sign_flip" | "zero" | "perturb"; "perturb" adds
    # N(0, scale^2) noise seeded on (seed, iteration, microbatch, stage))
    "corrupt_gradient": (("nodes",),
                         {"mode": "perturb", "scale": 1.0, "seed": 0,
                          "at_iteration": 0, "duration": 0}),
    # every relay-to-relay transfer leg independently fails to arrive
    # with probability `p` (counter-based coin on (seed, iteration,
    # microbatch, leg), so replay is exact and no shared stream is read)
    "flaky_link": (("p",),
                   {"seed": 0, "at_iteration": 0, "duration": 0}),
}

#: clause kinds that draw no randomness (replayable / analyzable exactly)
#: — the adversarial clauses qualify because their noise/coins are
#: counter-based on their own embedded seeds, not the shared policy
#: stream
DETERMINISTIC_CLAUSES = frozenset(
    {"trace", "regional_blackout", "flash_crowd", "link_degradation",
     "straggler", "corrupt_gradient", "flaky_link"})

#: the beyond-fail-stop fault classes (ISSUE 9): clauses the defense
#: layer (deadline + gradient screen + reputation quarantine) targets
ADVERSARIAL_CLAUSES = frozenset(
    {"straggler", "corrupt_gradient", "flaky_link"})

#: clause kinds that need real link bandwidth (geo topology only)
GEO_ONLY_CLAUSES = frozenset({"link_degradation"})

#: clause kinds keyed on Node.location (any topology that stamps it)
LOCATION_CLAUSES = frozenset({"regional_blackout", "regional_outage"})

#: topologies whose nodes carry a real Node.location
LOCATED_TOPOLOGIES = frozenset({"geo", "geo-abstract"})

#: arrival clause schema: kind -> (required fields, {optional: default}).
#: Arrival programs describe the *open-loop* decode request traffic the
#: serving plane must absorb (the serving analogue of the churn
#: program).  Every clause is deterministic by construction: the random
#: kinds draw from ``np.random.default_rng([clause seed, iteration,
#: clause index])`` — counter-based, never the shared policy stream —
#: so the same spec always replays the same request trace across the
#: sim and runtime layers (the serving differential tier depends on
#: this).  ``at_iteration``/``duration`` window a clause the same way
#: the adversarial churn clauses are windowed (duration 0 = forever).
ARRIVAL_CLAUSES: Dict[str, Tuple[Tuple[str, ...], Dict[str, Any]]] = {
    # Poisson(rate) new requests per iteration, offsets ~ U[0, 1)
    "poisson": (("rate",),
                {"seed": 0, "at_iteration": 0, "duration": 0}),
    # diurnal load: Poisson whose rate swings sinusoidally between
    # `low_scale`*rate and rate with period `period` iterations
    "diurnal": (("rate", "period"),
                {"low_scale": 0.25, "seed": 0,
                 "at_iteration": 0, "duration": 0}),
    # flash-crowd spike: exactly `requests` arrivals at `at_iteration`,
    # evenly spread over the first `when` fraction of the iteration
    "spike": (("at_iteration", "requests"), {"when": 0.25}),
}


@dataclass
class ScenarioSpec:
    """One scenario, materializable as a flow problem, a simulated
    training run, and a reduced real-compute training run."""

    name: str
    seed: int = 0
    #: "standard" (default corpus) or "scale" — bench_scale-style
    #: topologies at 1000+ relays; swept with the restricted check set
    #: (harness.scale_checks), never the real-compute differentials
    tier: str = "standard"

    # ---- topology -----------------------------------------------------
    topology: str = "geo"        # "geo" | "synthetic" | "geo-abstract"
    num_stages: int = 4
    relays_per_stage: int = 4
    num_data_nodes: int = 2
    data_capacity: int = 4
    capacity_range: Tuple[int, int] = (1, 4)   # relay cap ~ int(U[lo, hi))
    num_locations: int = 10                     # geo only
    compute_cost: float = 6.0                   # geo: mean sec/microbatch
    compute_jitter: float = 0.3                 # geo: per-node jitter
    min_bandwidth: float = 50e6 / 8             # geo: inter-location floor
    max_bandwidth: float = 500e6 / 8            # geo: intra-location links
    region_compute_scale: Optional[List[float]] = None   # geo: c_i multiplier
    region_bandwidth_scale: Optional[List[float]] = None  # geo: bw multiplier
    cost_range: Tuple[int, int] = (1, 20)       # synthetic: integer d_ij
    source_capacity: int = 4                    # synthetic source capacity
    spare_nodes: int = 0                        # flash-crowd pool (geo)

    # ---- churn program ------------------------------------------------
    churn: List[Dict[str, Any]] = field(default_factory=list)

    # ---- compression clause (per-link wire-codec co-optimization) -----
    #: ``{"menu": [codec names], "fidelity_budget": float,
    #:   "fidelity_weight": float (optional)}`` — the wire-codec menu
    #: the planner prices per link (flow.graph.WIRE_CODECS names; must
    #: include "fp32" as the lossless fallback), the scenario-level
    #: fidelity budget gating admissibility, and the optional
    #: seconds-per-unit-distortion weight.  ``None`` = fp32 everywhere
    #: (bit-identical to the pre-codec stack).  Geo topology only: the
    #: abstract topologies store d_ij directly (infinite bandwidth), so
    #: codec pricing would be degenerate there.
    compression: Optional[Dict[str, Any]] = None

    # ---- serving plane (decode traffic routed through the flow engine)
    #: open-loop request-arrival program (see ARRIVAL_CLAUSES); an empty
    #: list means the spec has no serving plane and none of the serving
    #: layers/checks apply — bit-identical to the pre-serving stack.
    arrivals: List[Dict[str, Any]] = field(default_factory=list)
    prompt_len: int = 8            # tokens prefilled per request
    gen_tokens: int = 8            # tokens decoded per request
    serve_batch: int = 4           # continuous-batching width per chain
    #: Eq. 1 surcharge (seconds-equivalent) per KV-resident sequence on
    #: a destination node — prices loaded nodes out of new chain plans.
    #: 0.0 keeps the flow network's trivial (bit-identical) state.
    kv_weight: float = 0.0

    # ---- run shape ----------------------------------------------------
    iterations: int = 6
    scheduler: str = "gwtf"                     # "gwtf" | "swarm"
    objective: str = "minmax"                   # GWTF refinement objective

    # ---- model family (runtime + simulator profile) -------------------
    model: str = "gwtf-llama-300m"
    model_layers: int = 4
    model_d: int = 128
    model_vocab: int = 256
    seq_len: int = 64
    microbatch_size: int = 2
    microbatches: int = 4                       # per data node per iteration

    # ------------------------------------------------------------------
    @property
    def num_relays(self) -> int:
        return self.num_stages * self.relays_per_stage

    @property
    def base_nodes(self) -> int:
        """Node count before the spare (flash-crowd) pool."""
        return self.num_data_nodes + self.num_relays

    @property
    def deterministic_churn(self) -> bool:
        """True iff every churn clause replays without RNG draws."""
        return all(c.get("kind") in DETERMINISTIC_CLAUSES
                   for c in self.churn)

    @property
    def has_arrivals(self) -> bool:
        """True iff the spec carries a serving plane (arrival program)."""
        return bool(self.arrivals)

    # ------------------------------------------------------------------
    def validate(self) -> "ScenarioSpec":
        """Raise ``ValueError`` on any inconsistent field; returns self."""
        if not self.name or not isinstance(self.name, str):
            raise ValueError("scenario name must be a non-empty string")
        if self.topology not in ("geo", "synthetic", "geo-abstract"):
            raise ValueError(
                f"{self.name}: unknown topology {self.topology!r} "
                f"(expected 'geo' | 'synthetic' | 'geo-abstract')")
        if self.tier not in ("standard", "scale"):
            raise ValueError(f"{self.name}: unknown tier {self.tier!r} "
                             f"(expected 'standard' | 'scale')")
        if self.scheduler not in ("gwtf", "swarm"):
            raise ValueError(
                f"{self.name}: unknown scheduler {self.scheduler!r} "
                f"(expected 'gwtf' | 'swarm')")
        if self.objective not in ("minmax", "sum"):
            raise ValueError(f"{self.name}: unknown objective "
                             f"{self.objective!r}")
        for fld, lo in (("num_stages", 1), ("relays_per_stage", 1),
                        ("num_data_nodes", 1), ("data_capacity", 1),
                        ("num_locations", 1), ("iterations", 1),
                        ("microbatches", 1), ("microbatch_size", 1),
                        ("seq_len", 1), ("spare_nodes", 0),
                        ("prompt_len", 1), ("gen_tokens", 1),
                        ("serve_batch", 1)):
            v = getattr(self, fld)
            if not isinstance(v, int) or v < lo:
                raise ValueError(f"{self.name}: {fld}={v!r} must be an "
                                 f"int >= {lo}")
        for rng_fld in ("capacity_range", "cost_range"):
            lo, hi = getattr(self, rng_fld)
            if not (lo >= 1 and hi > lo):
                raise ValueError(f"{self.name}: {rng_fld}=({lo}, {hi}) "
                                 f"must satisfy 1 <= lo < hi")
        for scale_fld in ("region_compute_scale", "region_bandwidth_scale"):
            scale = getattr(self, scale_fld)
            if scale is None:
                continue
            if self.topology != "geo":
                raise ValueError(f"{self.name}: {scale_fld} requires the "
                                 f"geo topology")
            if len(scale) != self.num_locations:
                raise ValueError(
                    f"{self.name}: {scale_fld} has {len(scale)} entries "
                    f"for {self.num_locations} locations")
            if any(s <= 0 for s in scale):
                raise ValueError(f"{self.name}: {scale_fld} entries must "
                                 f"be positive")
        if self.spare_nodes and self.topology != "geo":
            raise ValueError(f"{self.name}: spare_nodes (flash crowd) "
                             f"requires the geo topology")
        if not isinstance(self.kv_weight, (int, float)) or self.kv_weight < 0:
            raise ValueError(f"{self.name}: kv_weight={self.kv_weight!r} "
                             f"must be a number >= 0")
        self._validate_compression()
        self._validate_churn()
        self._validate_arrivals()
        return self

    def _validate_compression(self) -> None:
        if self.compression is None:
            return
        from repro.core.flow.graph import WIRE_CODECS
        c = self.compression
        if not isinstance(c, dict):
            raise ValueError(f"{self.name}: compression must be a dict")
        unknown = set(c) - {"menu", "fidelity_budget", "fidelity_weight"}
        if unknown:
            raise ValueError(f"{self.name}: compression has unknown "
                             f"field(s) {sorted(unknown)}")
        if self.topology != "geo":
            raise ValueError(f"{self.name}: compression requires the geo "
                             f"topology (abstract d_ij links have no "
                             f"bandwidth for a codec to save)")
        menu = c.get("menu")
        if not isinstance(menu, (list, tuple)) or not menu:
            raise ValueError(f"{self.name}: compression.menu must be a "
                             f"non-empty list of codec names")
        bad = [n for n in menu if n not in WIRE_CODECS]
        if bad:
            raise ValueError(f"{self.name}: compression.menu has unknown "
                             f"codec(s) {bad} (known: "
                             f"{sorted(WIRE_CODECS)})")
        if "fp32" not in menu:
            raise ValueError(f"{self.name}: compression.menu must include "
                             f"'fp32' (the lossless fallback)")
        budget = c.get("fidelity_budget", 0.0)
        if not isinstance(budget, (int, float)) or budget < 0:
            raise ValueError(f"{self.name}: compression.fidelity_budget="
                             f"{budget!r} must be a number >= 0")
        weight = c.get("fidelity_weight", 1.0)
        if not isinstance(weight, (int, float)) or weight < 0:
            raise ValueError(f"{self.name}: compression.fidelity_weight="
                             f"{weight!r} must be a number >= 0")

    def _validate_churn(self) -> None:
        flash_total = 0
        for i, clause in enumerate(self.churn):
            if not isinstance(clause, dict):
                raise ValueError(f"{self.name}: churn[{i}] must be a dict")
            kind = clause.get("kind")
            if kind not in CHURN_CLAUSES:
                raise ValueError(
                    f"{self.name}: churn[{i}] has unknown kind {kind!r} "
                    f"(expected one of {sorted(CHURN_CLAUSES)})")
            required, optional = CHURN_CLAUSES[kind]
            fields = set(clause) - {"kind"}
            missing = set(required) - fields
            unknown = fields - set(required) - set(optional)
            if missing:
                raise ValueError(f"{self.name}: churn[{i}] ({kind}) is "
                                 f"missing field(s) {sorted(missing)}")
            if unknown:
                raise ValueError(f"{self.name}: churn[{i}] ({kind}) has "
                                 f"unknown field(s) {sorted(unknown)}")
            if kind in GEO_ONLY_CLAUSES and self.topology != "geo":
                raise ValueError(f"{self.name}: churn[{i}] ({kind}) "
                                 f"requires the geo topology")
            if kind in LOCATION_CLAUSES \
                    and self.topology not in LOCATED_TOPOLOGIES:
                raise ValueError(f"{self.name}: churn[{i}] ({kind}) "
                                 f"requires a geo topology")
            if kind == "bernoulli" and not 0.0 <= clause["p"] <= 1.0:
                raise ValueError(f"{self.name}: churn[{i}] p={clause['p']} "
                                 f"out of [0, 1]")
            if kind == "flash_crowd":
                flash_total += int(clause["nodes"])
            if kind == "regional_blackout" and not (
                    0 <= clause["location"] < self.num_locations):
                raise ValueError(
                    f"{self.name}: churn[{i}] location={clause['location']} "
                    f"out of range for {self.num_locations} locations")
            if kind == "link_degradation" and clause["factor"] <= 0:
                raise ValueError(f"{self.name}: churn[{i}] factor must be "
                                 f"positive")
            if kind in ("straggler", "corrupt_gradient"):
                nodes = clause["nodes"]
                if (not isinstance(nodes, (list, tuple)) or not nodes
                        or not all(isinstance(n, int) and 0 <= n
                                   for n in nodes)):
                    raise ValueError(
                        f"{self.name}: churn[{i}] ({kind}) nodes must be a "
                        f"non-empty list of node ids (ints >= 0)")
                hi_id = self.base_nodes + self.spare_nodes
                bad = [n for n in nodes if n >= hi_id]
                if bad:
                    raise ValueError(
                        f"{self.name}: churn[{i}] ({kind}) names node(s) "
                        f"{bad} outside the topology's {hi_id} node ids")
            if kind in ADVERSARIAL_CLAUSES:
                at = clause.get("at_iteration", 0)
                dur = clause.get("duration", 0)
                if not isinstance(at, int) or at < 0:
                    raise ValueError(f"{self.name}: churn[{i}] ({kind}) "
                                     f"at_iteration={at!r} must be an "
                                     f"int >= 0")
                if not isinstance(dur, int) or dur < 0:
                    raise ValueError(f"{self.name}: churn[{i}] ({kind}) "
                                     f"duration={dur!r} must be an "
                                     f"int >= 0")
            if kind == "straggler":
                factor = clause.get("factor", 4.0)
                if not isinstance(factor, (int, float)) or factor < 1.0:
                    raise ValueError(f"{self.name}: churn[{i}] (straggler) "
                                     f"factor={factor!r} must be >= 1")
            if kind == "corrupt_gradient":
                from repro.core.sim.faults import CorruptGradientChurn
                mode = clause.get("mode", "perturb")
                if mode not in CorruptGradientChurn.MODES:
                    raise ValueError(
                        f"{self.name}: churn[{i}] (corrupt_gradient) "
                        f"mode={mode!r} not in "
                        f"{sorted(CorruptGradientChurn.MODES)}")
                scale = clause.get("scale", 1.0)
                if not isinstance(scale, (int, float)) or scale <= 0:
                    raise ValueError(
                        f"{self.name}: churn[{i}] (corrupt_gradient) "
                        f"scale={scale!r} must be > 0")
            if kind == "flaky_link" and not 0.0 <= clause["p"] <= 1.0:
                raise ValueError(f"{self.name}: churn[{i}] p={clause['p']} "
                                 f"out of [0, 1]")
        if flash_total > self.spare_nodes:
            raise ValueError(
                f"{self.name}: flash_crowd clauses join {flash_total} nodes "
                f"but only spare_nodes={self.spare_nodes} are provisioned")

    def _validate_arrivals(self) -> None:
        for i, clause in enumerate(self.arrivals):
            if not isinstance(clause, dict):
                raise ValueError(f"{self.name}: arrivals[{i}] must be a "
                                 f"dict")
            kind = clause.get("kind")
            if kind not in ARRIVAL_CLAUSES:
                raise ValueError(
                    f"{self.name}: arrivals[{i}] has unknown kind {kind!r} "
                    f"(expected one of {sorted(ARRIVAL_CLAUSES)})")
            required, optional = ARRIVAL_CLAUSES[kind]
            fields = set(clause) - {"kind"}
            missing = set(required) - fields
            unknown = fields - set(required) - set(optional)
            if missing:
                raise ValueError(f"{self.name}: arrivals[{i}] ({kind}) is "
                                 f"missing field(s) {sorted(missing)}")
            if unknown:
                raise ValueError(f"{self.name}: arrivals[{i}] ({kind}) has "
                                 f"unknown field(s) {sorted(unknown)}")
            if kind in ("poisson", "diurnal"):
                rate = clause["rate"]
                if not isinstance(rate, (int, float)) or rate < 0:
                    raise ValueError(f"{self.name}: arrivals[{i}] ({kind}) "
                                     f"rate={rate!r} must be a number >= 0")
                at = clause.get("at_iteration", 0)
                dur = clause.get("duration", 0)
                for fld, v in (("at_iteration", at), ("duration", dur)):
                    if not isinstance(v, int) or v < 0:
                        raise ValueError(
                            f"{self.name}: arrivals[{i}] ({kind}) "
                            f"{fld}={v!r} must be an int >= 0")
            if kind == "diurnal":
                period = clause["period"]
                if not isinstance(period, int) or period < 1:
                    raise ValueError(f"{self.name}: arrivals[{i}] (diurnal) "
                                     f"period={period!r} must be an "
                                     f"int >= 1")
                low = clause.get("low_scale", 0.25)
                if not isinstance(low, (int, float)) or not 0 <= low <= 1:
                    raise ValueError(f"{self.name}: arrivals[{i}] (diurnal) "
                                     f"low_scale={low!r} out of [0, 1]")
            if kind == "spike":
                at = clause["at_iteration"]
                reqs = clause["requests"]
                if not isinstance(at, int) or at < 0:
                    raise ValueError(f"{self.name}: arrivals[{i}] (spike) "
                                     f"at_iteration={at!r} must be an "
                                     f"int >= 0")
                if not isinstance(reqs, int) or reqs < 1:
                    raise ValueError(f"{self.name}: arrivals[{i}] (spike) "
                                     f"requests={reqs!r} must be an "
                                     f"int >= 1")
                when = clause.get("when", 0.25)
                if not isinstance(when, (int, float)) or not 0 < when <= 1:
                    raise ValueError(f"{self.name}: arrivals[{i}] (spike) "
                                     f"when={when!r} out of (0, 1]")

    # ------------------------------------------------------------------
    # dict / JSON round-trip
    # ------------------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["capacity_range"] = list(self.capacity_range)
        d["cost_range"] = list(self.cost_range)
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ScenarioSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(
                f"scenario {d.get('name', '<unnamed>')!r}: unknown "
                f"field(s) {sorted(unknown)} — the spec schema is "
                f"documented in scenarios/README.md")
        kwargs = dict(d)
        for rng_fld in ("capacity_range", "cost_range"):
            if rng_fld in kwargs:
                kwargs[rng_fld] = tuple(kwargs[rng_fld])
        spec = cls(**kwargs)
        return spec.validate()

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "ScenarioSpec":
        return cls.from_dict(json.loads(text))

    def replace(self, **changes) -> "ScenarioSpec":
        """Functional update (used by the fuzz shrinker); validates."""
        return dataclasses.replace(self, **changes).validate()
