"""Membership: simulated DHT peer discovery + leader election.

The paper uses a Kademlia-style DHT [16] for discovery and a robust
election among data nodes [17], [18].  Networking is simulated: the DHT
is a key->contact registry with per-lookup hop costs; elections follow the
bully algorithm over data nodes (lowest alive id wins), which is what
Garcia-Molina-style elections reduce to under crash faults.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set

import numpy as np


@dataclass
class Contact:
    node_id: int
    stage: int
    capacity: int
    is_data: bool = False
    alive: bool = True


class DHT:
    """Simulated Kademlia registry.

    ``lookup`` charges O(log N) hop latency to model real DHT cost;
    the returned view can be truncated to model partial knowledge.
    """

    def __init__(self, rng: Optional[np.random.Generator] = None,
                 hop_latency: float = 0.05):
        self.registry: Dict[int, Contact] = {}
        self.rng = rng or np.random.default_rng(0)
        self.hop_latency = hop_latency
        self.lookup_time_total = 0.0

    def publish(self, c: Contact):
        self.registry[c.node_id] = c

    def unpublish(self, node_id: int):
        self.registry.pop(node_id, None)

    def lookup_stage(self, stage: int, k: Optional[int] = None) -> List[Contact]:
        hops = max(1, int(np.log2(max(2, len(self.registry)))))
        self.lookup_time_total += hops * self.hop_latency
        found = [c for c in self.registry.values()
                 if c.stage == stage and c.alive]
        if k is not None and len(found) > k:
            idx = self.rng.choice(len(found), size=k, replace=False)
            found = [found[i] for i in idx]
        return found

    def lookup_data_nodes(self) -> List[Contact]:
        hops = max(1, int(np.log2(max(2, len(self.registry)))))
        self.lookup_time_total += hops * self.hop_latency
        return [c for c in self.registry.values() if c.is_data and c.alive]


def elect_leader(dht: DHT) -> Optional[int]:
    """Bully election among alive data nodes: lowest id wins."""
    data = dht.lookup_data_nodes()
    if not data:
        return None
    return min(c.node_id for c in data)
