"""Real-JAX decentralized stage executor (paper Fig. 6 convergence).

Runs actual forward/backward computation through GWTF-routed flows:

* the data node holds embedding + final norm + LM head ("first and last
  stages colocated on the data node", Sec. II);
* each relay node holds a *replica* of its stage's transformer blocks;
* microbatches follow the flows built by :class:`GWTFProtocol`;
* crashes drop a node mid-iteration: forward crashes reroute to a
  same-stage replica (recomputing that stage only), backward crashes are
  repaired the same way from the stored upstream activation;
* the aggregation phase averages gradients per stage across replicas and
  applies the same update everywhere, so replicas stay bit-identical —
  GWTF therefore has exactly SGD's convergence on the microbatches that
  completed (the paper's claim: same convergence as centralized).

This module shares routing/recovery code with the event simulator; the
simulator answers *how long*, this executor answers *what is learned*.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flow.decentralized import GWTFProtocol
from repro.core.flow.graph import FlowNetwork
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.transformer import _apply_block, _init_block
from repro.optim.adamw import AdamW


# ---------------------------------------------------------------------------
# Stage modules
# ---------------------------------------------------------------------------

def init_stage_params(cfg: ModelConfig, stage: int, num_stages: int, key):
    """Blocks [lo, hi) of the model as one stage (stacked for scan)."""
    lo, hi = stage_bounds(cfg, stage, num_stages)
    keys = jax.random.split(jax.random.fold_in(key, stage), hi - lo)
    dtype = jnp.dtype(cfg.param_dtype)
    return jax.vmap(lambda kk: _init_block(kk, cfg, dtype))(keys)


def stage_bounds(cfg: ModelConfig, stage: int, num_stages: int):
    per = cfg.num_layers // num_stages
    extra = cfg.num_layers - per * num_stages
    lo = stage * per + min(stage, extra)
    hi = lo + per + (1 if stage < extra else 0)
    return lo, hi


def stage_forward(stage_params, x, cfg: ModelConfig):
    positions = jnp.arange(x.shape[1])

    def body(carry, bp):
        h, _aux, _ = _apply_block(bp, carry, cfg, positions=positions,
                                  window=None, cache=None, write_index=None,
                                  kv_valid=None, moe_impl="dense",
                                  use_kernel=False)
        return h, None

    out, _ = jax.lax.scan(body, x, stage_params)
    return out


def init_head_params(cfg: ModelConfig, key):
    """Data-node module: embedding + final norm + LM head."""
    return {"embed": L.init_embed(key, cfg, jnp.dtype(cfg.param_dtype)),
            "final_norm": L.init_norm(cfg)}


def embed_fn(head_params, tokens):
    return L.embed_tokens(head_params["embed"], tokens)


def loss_fn(head_params, hidden, labels, cfg: ModelConfig):
    h = L.apply_norm(head_params["final_norm"], hidden, cfg)
    return L.chunked_xent_loss(head_params["embed"], h, labels, cfg)


# ---------------------------------------------------------------------------
# Decentralized trainer
# ---------------------------------------------------------------------------

@dataclass
class IterationResult:
    loss: float
    completed: int
    launched: int
    dropped: int


class DecentralizedTrainer:
    """GWTF training over a FlowNetwork with real JAX compute."""

    def __init__(self, cfg: ModelConfig, net: FlowNetwork, *,
                 churn: float = 0.0, lr: float = 1e-3,
                 seed: int = 0,
                 rng: Optional[np.random.Generator] = None):
        self.cfg = cfg
        self.net = net
        self.churn = churn
        self.rng = rng or np.random.default_rng(seed)
        self.protocol = GWTFProtocol(net, rng=self.rng)
        self.protocol.run(max_rounds=100)
        key = jax.random.PRNGKey(seed)
        S = net.num_stages
        # identical replicas per stage (paper: joining nodes download the
        # stage weights) -> store ONE canonical copy per stage; replicas
        # share it because aggregation keeps them identical.
        self.stage_params = [init_stage_params(cfg, s, S, key)
                             for s in range(S)]
        self.head_params = {d.id: init_head_params(cfg, jax.random.fold_in(key, 999))
                            for d in net.data_nodes()}
        self.opt = AdamW(lr=lr)
        self.stage_opt = [self.opt.init(p) for p in self.stage_params]
        self.head_opt = {d: self.opt.init(p)
                         for d, p in self.head_params.items()}
        self._jit_cache: Dict[str, Any] = {}
        self.losses: List[float] = []

    # ------------------------------------------------------------------
    def _fwd_stage(self, s: int, x):
        key = f"stage{s}"
        if key not in self._jit_cache:
            self._jit_cache[key] = jax.jit(
                lambda p, x: stage_forward(p, x, self.cfg))
        return self._jit_cache[key](self.stage_params[s], x)

    # ------------------------------------------------------------------
    def iteration(self, batches_per_data_node: Dict[int, List[dict]]
                  ) -> IterationResult:
        """One training iteration: route, fwd, bwd, aggregate, update."""
        cfg, S = self.cfg, self.net.num_stages
        # --- churn: pick crashing relays for this iteration -------------
        crashed = set()
        for n in self.net.nodes.values():
            if n.is_data:
                continue
            if n.alive and self.rng.uniform() < self.churn:
                crashed.add(n.id)
            elif not n.alive and self.rng.uniform() < self.churn:
                n.alive = True
                self.protocol.add_node(n)
        # --- routing -----------------------------------------------------
        self.protocol.reclaim_sink_slots()
        self.protocol.run(max_rounds=30, quiet_rounds=2)
        flows = self.protocol.complete_flows()
        # crash points: a crashed node fails after processing k of its
        # microbatches (uniform), modelling a mid-iteration fault.
        mb_queue: List[Tuple[int, dict, List[int]]] = []
        per_dn_counts: Dict[int, int] = {d.id: 0 for d in self.net.data_nodes()}
        for chain in flows:
            dn = chain[0]
            avail = batches_per_data_node.get(dn, [])
            k = per_dn_counts[dn]
            if k < len(avail):
                mb_queue.append((dn, avail[k], chain))
                per_dn_counts[dn] += 1
        launched = len(mb_queue)
        crash_budget = {nid: self.rng.integers(0, 2) for nid in crashed}

        # --- forward + backward per microbatch ---------------------------
        grad_stage = [None] * S
        grad_head: Dict[int, Any] = {}
        counts = [0] * S
        head_counts: Dict[int, int] = {}
        total_loss, completed, dropped = 0.0, 0, 0

        for dn, mb, chain in mb_queue:
            relays = list(chain[1:-1])
            # forward, with crash-triggered same-stage substitution
            ok = True
            for idx, nid in enumerate(relays):
                if nid in crashed and crash_budget[nid] <= 0:
                    sub = self._substitute(nid, crashed)
                    if sub is None:
                        ok = False
                        break
                    relays[idx] = sub
                elif nid in crashed:
                    crash_budget[nid] -= 1
            if not ok:
                dropped += 1
                continue
            loss, g_head, g_stages = self._train_microbatch(dn, mb, relays)
            total_loss += loss
            completed += 1
            for s, g in enumerate(g_stages):
                grad_stage[s] = g if grad_stage[s] is None else jax.tree.map(
                    jnp.add, grad_stage[s], g)
                counts[s] += 1
            if dn in grad_head:
                grad_head[dn] = jax.tree.map(jnp.add, grad_head[dn], g_head)
                head_counts[dn] += 1
            else:
                grad_head[dn] = g_head
                head_counts[dn] = 1

        # --- aggregation + update (Sec. V-E) ------------------------------
        for s in range(S):
            if grad_stage[s] is None:
                continue
            g = jax.tree.map(lambda x: x / counts[s], grad_stage[s])
            self.stage_params[s], self.stage_opt[s] = self.opt.update(
                g, self.stage_opt[s], self.stage_params[s])
        for dn, g in grad_head.items():
            g = jax.tree.map(lambda x: x / head_counts[dn], g)
            self.head_params[dn], self.head_opt[dn] = self.opt.update(
                g, self.head_opt[dn], self.head_params[dn])

        # --- commit crashes ------------------------------------------------
        for nid in crashed:
            self.net.nodes[nid].alive = False
            self.protocol.remove_node(nid)

        mean_loss = total_loss / max(1, completed)
        self.losses.append(mean_loss)
        return IterationResult(loss=mean_loss, completed=completed,
                               launched=launched, dropped=dropped)

    # ------------------------------------------------------------------
    def _substitute(self, dead: int, crashed: set) -> Optional[int]:
        stage = self.net.nodes[dead].stage
        cands = [n.id for n in self.net.stage_nodes(stage)
                 if n.id not in crashed and n.id != dead]
        return cands[0] if cands else None

    def _train_microbatch(self, dn: int, mb: dict, relays: List[int]):
        """Full fwd+bwd for one microbatch along its (repaired) path.

        Relay identity matters for routing/fault semantics; numerically all
        replicas of a stage are identical (aggregation invariant), so the
        math uses the canonical stage params.
        """
        cfg, S = self.cfg, self.net.num_stages
        key = "trainmb"
        if key not in self._jit_cache:
            def full(head_p, stage_ps, tokens, labels):
                x = embed_fn(head_p, tokens)
                for s in range(S):
                    x = stage_forward(stage_ps[s], x, cfg)
                return loss_fn(head_p, x, labels, cfg)
            self._jit_cache[key] = jax.jit(jax.value_and_grad(
                full, argnums=(0, 1)))
        tokens = jnp.asarray(mb["tokens"])
        labels = jnp.asarray(mb["labels"])
        loss, (g_head, g_stages) = self._jit_cache[key](
            self.head_params[dn], self.stage_params, tokens, labels)
        return float(loss), g_head, list(g_stages)


class CentralizedTrainer:
    """Baseline: same model, same data, no decentralization (Fig. 6)."""

    def __init__(self, cfg: ModelConfig, num_stages: int, *, lr: float = 1e-3,
                 seed: int = 0):
        self.cfg = cfg
        key = jax.random.PRNGKey(seed)
        self.stage_params = [init_stage_params(cfg, s, num_stages, key)
                             for s in range(num_stages)]
        self.head_params = init_head_params(cfg, jax.random.fold_in(key, 999))
        self.opt = AdamW(lr=lr)
        self.stage_opt = [self.opt.init(p) for p in self.stage_params]
        self.head_opt = self.opt.init(self.head_params)
        self.num_stages = num_stages
        self._jit = None
        self.losses: List[float] = []

    def iteration(self, microbatches: List[dict]) -> float:
        cfg, S = self.cfg, self.num_stages
        if self._jit is None:
            def full(head_p, stage_ps, tokens, labels):
                x = embed_fn(head_p, tokens)
                for s in range(S):
                    x = stage_forward(stage_ps[s], x, cfg)
                return loss_fn(head_p, x, labels, cfg)
            self._jit = jax.jit(jax.value_and_grad(full, argnums=(0, 1)))
        g_head_acc, g_stage_acc, total = None, None, 0.0
        for mb in microbatches:
            loss, (gh, gs) = self._jit(self.head_params, self.stage_params,
                                       jnp.asarray(mb["tokens"]),
                                       jnp.asarray(mb["labels"]))
            total += float(loss)
            g_head_acc = gh if g_head_acc is None else jax.tree.map(
                jnp.add, g_head_acc, gh)
            g_stage_acc = (list(gs) if g_stage_acc is None else
                           [jax.tree.map(jnp.add, a, b)
                            for a, b in zip(g_stage_acc, gs)])
        n = len(microbatches)
        g_head = jax.tree.map(lambda x: x / n, g_head_acc)
        self.head_params, self.head_opt = self.opt.update(
            g_head, self.head_opt, self.head_params)
        for s in range(S):
            g = jax.tree.map(lambda x: x / n, g_stage_acc[s])
            self.stage_params[s], self.stage_opt[s] = self.opt.update(
                g, self.stage_opt[s], self.stage_params[s])
        mean = total / n
        self.losses.append(mean)
        return mean
