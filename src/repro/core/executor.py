"""Trainer facades over the staged real-compute runtime (Fig. 6).

This module is the stable entry point for real-JAX decentralized
training; the implementation lives in the layered
:mod:`repro.core.runtime` package (``stages`` / ``activations`` /
``recovery`` / ``trainer`` — the same layered shape as
:mod:`repro.core.sim`):

* :class:`DecentralizedTrainer` — GWTF training over a
  :class:`~repro.core.flow.graph.FlowNetwork` with *per-stage* fused
  jitted execution: each stage forward is one residual-carrying
  dispatch (``jax.vjp`` closure capture), the backward consumes the
  stored residuals so it never recomputes the forward
  (``remat=True`` restores the rematerialising oracle), microbatches
  are stacked in depth-first dispatch chunks, boundary activations
  and residuals are stored per (chunk, stage) — optionally int8
  quantised via ``activation_codec="int8"`` — and mid-iteration
  crashes are repaired stage-locally: a forward crash recomputes only
  the crashed stage from the stored input, a backward crash replays
  that stage's VJP from the stored residuals on a substitute replica
  (paper Sec. V-D) with zero forward recompute.  Churn is sampled by the
  simulator's :class:`~repro.core.sim.faults.ChurnModel` layer and
  repair decisions come from its
  :class:`~repro.core.sim.policies.RoutingPolicy` layer, so the flow
  engine, the event simulator, and real compute share one
  fault/recovery vocabulary.  Microbatches whose relay has no live
  substitute are requeued onto another complete-flow chain when one
  exists (``IterationResult.rerouted``) instead of silently dropped.
* :class:`CentralizedTrainer` — the no-decentralization baseline; at
  churn 0 the decentralized trajectory coincides with it (the paper's
  convergence claim).

The simulator answers *how long*, this runtime answers *what is
learned*.  The pre-refactor per-microbatch whole-model-jit executor is
frozen in :mod:`repro.core.runtime.reference` for benchmarking
(``benchmarks/bench_exec.py``).
"""
from __future__ import annotations

# Stage modules (re-exported for compatibility with the pre-refactor API)
from repro.core.runtime.stages import (embed_fn, init_head_params,
                                       init_stage_params, loss_fn,
                                       stage_bounds, stage_forward)
from repro.core.runtime.trainer import (CentralizedTrainer, IterationResult,
                                        RuntimeTrainer)


class DecentralizedTrainer(RuntimeTrainer):
    """GWTF training over a FlowNetwork with real JAX compute.

    Drop-in facade: the pre-refactor constructor signature
    ``(cfg, net, *, churn, lr, seed, rng)`` still works, and
    ``iteration()`` returns the same ``IterationResult`` head fields
    (``loss``/``completed``/``launched``/``dropped``) extended with the
    runtime's reroute/recompute counters.  Keyword arguments of
    :class:`~repro.core.runtime.trainer.RuntimeTrainer` (``policy=``,
    ``churn_model=``, ``checkpoint_dir=``, ``batch_microbatches=``,
    ...) pass straight through.
    """


__all__ = [
    "CentralizedTrainer",
    "DecentralizedTrainer",
    "IterationResult",
    "RuntimeTrainer",
    "embed_fn",
    "init_head_params",
    "init_stage_params",
    "loss_fn",
    "stage_bounds",
    "stage_forward",
]
