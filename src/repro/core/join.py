"""Node insertion (paper Sec. V-B): bottleneck-stage-first assignment.

The elected leader periodically (1) floods a utilization query through the
stages — each node appends (capacity, flows-through) and forwards to known
peers of the next stage — and (2) assigns the highest-capacity joining
candidates to the most-utilized stages, one per stage, highest to highest.

Baselines for Fig. 5: highest-capacity-first (ignore utilization, fill
stages round-robin by raw capacity) and random assignment.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.flow.graph import FlowNetwork, Node


@dataclass
class StageReport:
    stage: int
    capacity: int
    flows: int

    @property
    def utilization(self) -> float:
        return self.flows / self.capacity if self.capacity else float("inf")


def flood_utilization(net: FlowNetwork, flows: Sequence[Sequence[int]]
                      ) -> List[StageReport]:
    """The leader's flooding query: per-stage (capacity, flow count).

    ``flows`` are node-id chains (data -> s0 -> ... -> data); each chain
    contributes one flow to every stage it crosses.
    """
    per_stage_flows = [0] * net.num_stages
    for chain in flows:
        for nid in chain[1:-1]:
            node = net.nodes.get(nid)
            if node is not None and not node.is_data:
                per_stage_flows[node.stage] += 1
    return [StageReport(s, net.stage_capacity(s), per_stage_flows[s])
            for s in range(net.num_stages)]


def assign_joiners(reports: List[StageReport],
                   candidate_capacities: Sequence[int],
                   policy: str = "gwtf",
                   rng: Optional[np.random.Generator] = None) -> List[int]:
    """Returns the stage assignment for each candidate (parallel list).

    * gwtf     — highest capacity -> most utilized stage (paper Sec. V-B)
    * capacity — highest capacity candidate first, stages filled round-
                 robin (utilization-blind; the paper's "highest capacity
                 first" baseline)
    * random   — uniform random stage per candidate
    """
    rng = rng or np.random.default_rng(0)
    n = len(candidate_capacities)
    if policy == "random":
        return list(rng.integers(0, len(reports), size=n))
    order = np.argsort(candidate_capacities)[::-1]      # high cap first
    out = [0] * n
    if policy == "gwtf":
        stage_rank = sorted(reports, key=lambda r: -r.utilization)
        for k, ci in enumerate(order):
            out[ci] = stage_rank[k % len(stage_rank)].stage
    elif policy == "capacity":
        for k, ci in enumerate(order):
            out[ci] = reports[k % len(reports)].stage    # round robin
    else:
        raise ValueError(policy)
    return out
