"""Flow-network model of a decentralized training system (paper Sec. III/IV).

Nodes are data nodes or relay nodes, grouped into pipeline stages.  Link
costs follow Eq. 1:

    d_ij = (c_i + c_j)/2 + (lambda_ij + lambda_ji)/2 + 2*size/(beta_ij + beta_ji)

with asymmetric latency/bandwidth averaged because every link is used once
forward and once backward.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclass
class Node:
    id: int
    stage: int                  # 0..S-1 for relays; -1 for data nodes
    capacity: int               # max concurrent microbatches (cap_i)
    compute_cost: float         # c_i: time to process one microbatch
    is_data: bool = False
    alive: bool = True

    def __hash__(self):
        return self.id


@dataclass
class FlowNetwork:
    """Global network description — the *simulator's* ground truth.

    Decentralized protocol code only ever reads local slices of this
    (a node's own row/column and its known peers), preserving the paper's
    partial-knowledge property.
    """
    nodes: Dict[int, Node]
    num_stages: int
    latency: np.ndarray          # (N, N) lambda_ij, seconds
    bandwidth: np.ndarray        # (N, N) beta_ij, bytes/s
    activation_size: float       # bytes per microbatch activation

    def edge_cost(self, i: int, j: int, size: Optional[float] = None) -> float:
        """Eq. 1 cost of moving one microbatch between nodes i and j."""
        size = self.activation_size if size is None else size
        ni, nj = self.nodes[i], self.nodes[j]
        comp = 0.5 * (ni.compute_cost + nj.compute_cost)
        lat = 0.5 * (self.latency[i, j] + self.latency[j, i])
        bw = self.bandwidth[i, j] + self.bandwidth[j, i]
        return comp + lat + 2.0 * size / bw

    def comm_cost(self, i: int, j: int, size: Optional[float] = None) -> float:
        """Communication-only part of Eq. 1 (no compute term)."""
        size = self.activation_size if size is None else size
        lat = 0.5 * (self.latency[i, j] + self.latency[j, i])
        bw = self.bandwidth[i, j] + self.bandwidth[j, i]
        return lat + 2.0 * size / bw

    # ------------------------------------------------------------------
    def stage_nodes(self, stage: int, alive_only: bool = True) -> List[Node]:
        return [n for n in self.nodes.values()
                if n.stage == stage and not n.is_data
                and (n.alive or not alive_only)]

    def data_nodes(self) -> List[Node]:
        return [n for n in self.nodes.values() if n.is_data]

    def alive_nodes(self) -> List[Node]:
        return [n for n in self.nodes.values() if n.alive]

    def stage_capacity(self, stage: int) -> int:
        return sum(n.capacity for n in self.stage_nodes(stage))

    def add_node(self, node: Node, latency_row=None, latency_col=None,
                 bandwidth_row=None, bandwidth_col=None):
        """Grow the matrices for a joining node."""
        n = max(self.nodes) + 1 if self.nodes else 0
        assert node.id == n, f"node ids must be dense ({node.id} != {n})"
        size = n + 1
        for name, row, col, fill in (("latency", latency_row, latency_col, 0.05),
                                     ("bandwidth", bandwidth_row, bandwidth_col, 500e6 / 8)):
            old = getattr(self, name)
            new = np.full((size, size), fill)
            new[:n, :n] = old
            if row is not None:
                new[n, :n] = row
            if col is not None:
                new[:n, n] = col
            setattr(self, name, new)
        self.nodes[node.id] = node


# ---------------------------------------------------------------------------
# Topology builders (paper Sec. VI setup)
# ---------------------------------------------------------------------------

def geo_distributed_network(
    *,
    num_stages: int,
    relay_capacities: List[int],
    num_data_nodes: int = 2,
    data_capacity: int = 4,
    rng: Optional[np.random.Generator] = None,
    num_locations: int = 10,
    min_bandwidth: float = 50e6 / 8,     # 50 Mb/s in bytes/s
    max_bandwidth: float = 500e6 / 8,    # 500 Mb/s
    compute_cost: float = 6.0,           # seconds per microbatch fwd+bwd
    compute_jitter: float = 0.3,
    activation_size: float = 4 * 512 * 1024 * 2 * 32,  # mb=4, seq=512, x32 scale
) -> FlowNetwork:
    """Build the paper's geo-distributed evaluation topology.

    Relay nodes are spread over ``num_locations`` simulated locations;
    intra-location links get max bandwidth / low latency, inter-location
    links get degraded bandwidth (down to 50 Mb/s) and higher latency.
    ``activation_size`` bakes in the paper's x32 bandwidth-reduction trick.
    """
    rng = rng or np.random.default_rng(0)
    nodes: Dict[int, Node] = {}
    nid = 0
    for _ in range(num_data_nodes):
        nodes[nid] = Node(nid, -1, data_capacity, 0.0, is_data=True)
        nid += 1
    per_stage = len(relay_capacities) // num_stages
    for s in range(num_stages):
        for k in range(per_stage):
            cap = relay_capacities[s * per_stage + k]
            c = compute_cost * (1.0 + compute_jitter * rng.standard_normal())
            nodes[nid] = Node(nid, s, cap, max(0.5, c))
            nid += 1

    N = nid
    loc = rng.integers(0, num_locations, size=N)
    lat = np.empty((N, N))
    bw = np.empty((N, N))
    for i in range(N):
        for j in range(N):
            if loc[i] == loc[j]:
                lat[i, j] = rng.uniform(0.001, 0.005)
                bw[i, j] = max_bandwidth
            else:
                lat[i, j] = rng.uniform(0.02, 0.15)
                bw[i, j] = rng.uniform(min_bandwidth, max_bandwidth)
    np.fill_diagonal(lat, 0.0)
    np.fill_diagonal(bw, max_bandwidth)
    return FlowNetwork(nodes=nodes, num_stages=num_stages, latency=lat,
                       bandwidth=bw, activation_size=activation_size)


def synthetic_network(
    *,
    num_stages: int,
    relays_per_stage: int,
    capacities,                   # callable(rng) -> int
    link_costs,                   # callable(rng) -> float (total d_ij directly)
    num_sources: int = 1,
    source_capacity: int = 100,
    rng: Optional[np.random.Generator] = None,
) -> Tuple[FlowNetwork, np.ndarray]:
    """Abstract flow-test network (paper Tables IV/V): d_ij drawn directly.

    Returns (network, cost_matrix) where cost_matrix[i, j] *is* d_ij —
    edge_cost is bypassed by storing costs in the latency matrix with
    zero compute and infinite bandwidth.
    """
    rng = rng or np.random.default_rng(0)
    nodes: Dict[int, Node] = {}
    nid = 0
    for _ in range(num_sources):
        nodes[nid] = Node(nid, -1, source_capacity, 0.0, is_data=True)
        nid += 1
    for s in range(num_stages):
        for _ in range(relays_per_stage):
            nodes[nid] = Node(nid, s, int(capacities(rng)), 0.0)
            nid += 1
    N = nid
    cost = np.empty((N, N))
    for i in range(N):
        for j in range(N):
            cost[i, j] = link_costs(rng) if i != j else 0.0
    net = FlowNetwork(nodes=nodes, num_stages=num_stages,
                      latency=cost, bandwidth=np.full((N, N), np.inf),
                      activation_size=0.0)
    return net, cost
