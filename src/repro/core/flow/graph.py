"""Flow-network model of a decentralized training system (paper Sec. III/IV).

Nodes are data nodes or relay nodes, grouped into pipeline stages.  Link
costs follow Eq. 1:

    d_ij = (c_i + c_j)/2 + (lambda_ij + lambda_ji)/2 + 2*size/(beta_ij + beta_ji)

with asymmetric latency/bandwidth averaged because every link is used once
forward and once backward.

Compression-aware pricing
-------------------------
On WAN links, compressing the payload is the dominant bandwidth lever
(FusionLLM), so every link is priced at its *best admissible wire
codec*: ``FlowNetwork.codec_menu`` names entries of :data:`WIRE_CODECS`
(compression ratio, encode+decode compute rate, fidelity penalty), the
scenario-level ``fidelity_budget`` gates which codecs are admissible,
and the per-edge price becomes

    min over admissible codecs c of
        lat_avg + 2*(ratio_c*size)/(beta_ij+beta_ji)
        + coder_rate_c*size + fidelity_weight*penalty_c

so fast links keep ``fp32`` (no distortion for negligible time saved)
while slow inter-region links pick aggressive codecs — routing and
compression are co-optimized because both land in the same cost
matrices the planner consumes.  ``wire_codec_matrix()`` exposes the
argmin (which codec each link chose).  The default menu ``("fp32",)``
takes a short-circuit path whose float arithmetic is *bit-identical* to
the pre-codec implementation; that is the in-engine equality oracle.

Reputation-aware pricing
------------------------
Beyond fail-stop faults, the defense layer prices *distrust* into the
same Eq. 1 matrices: each node carries a reputation in (0, 1] (default
1.0), and every edge INTO node j pays an extra

    reputation_weight * (1/rep_j - 1)

on ``cost_matrix()``/``edge_matrix()``/``edge_cost()`` — the matrices
the planner and reroute policy consume — but NOT on
``comm_matrix()``/``comm_cost()``, which model transfer physics (a
suspected node does not move bytes slower; the planner just avoids
it).  ``report_fault`` multiplicatively drops a node's reputation
(quarantine: the penalty dwarfs typical edge costs so flow routes
around it), ``decay_reputations`` relaxes everyone back toward 1.0
(rehabilitation), and when every reputation returns to ~1.0 storage
snaps back to the trivial ``None`` state whose arithmetic — and cached
matrix *objects* — are bit-identical to the reputation-free
implementation.  Reputation survives ``kill_node``/rejoin: quarantine
is about trust, not liveness.

KV-residency pricing (serving plane)
------------------------------------
When the same stage graph carries decode traffic, a node holding N
resident KV-cache sequences is the serving analogue of a loaded
activation store: every edge INTO node j pays an extra

    kv_weight * residency_j

on ``cost_matrix()``/``edge_matrix()``/``edge_cost()`` (planner-facing
matrices only, like reputation — residency does not move bytes slower,
it just makes loaded nodes less attractive to *new* chains).  The
default ``kv_weight = 0`` / empty residency keeps the trivial ``None``
storage whose arithmetic and cached matrix objects are bit-identical to
the serving-free implementation.  Evicting/migrating a resident
sequence to another node pays ``kv_migration_cost(i, j, kv_bytes)`` —
the KV payload priced through the same admissible-wire-codec
communication model as activations (FusionLLM's compressed geo-links
apply to KV-boundary traffic verbatim).

Scale notes
-----------
``edge_cost``/``comm_cost`` are the innermost calls of both the protocol
and the simulator, so the Eq. 1 terms are precomputed once into dense
(N, N) matrices (``cost_matrix()``) and every query is a single array
read.  The caches are keyed on a version counter that ``add_node`` (and
``invalidate_costs``) bumps; node death does *not* invalidate them
because link costs are independent of liveness.  Per-size matrices
(``comm_matrix``/``edge_matrix``) live in a small per-epoch dict so
alternating sizes — e.g. activation bytes vs aggregation bytes, or the
multiple effective sizes a codec menu produces — do not thrash full
rebuilds (``matrix_rebuild_count`` tracks rebuilds for regression
tests).  ``add_node`` grows the latency/bandwidth matrices
geometrically (amortized O(N) per join instead of a fresh O(N^2)
reallocation per join).
"""
from __future__ import annotations

import dataclasses
import inspect
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

# Defaults for links of a joining node when no measurements are supplied
# (previously inlined in add_node).
DEFAULT_JOIN_LATENCY = 0.05
DEFAULT_JOIN_BANDWIDTH = 500e6 / 8


@dataclass(frozen=True)
class LinkCodec:
    """One wire-codec entry of the per-link compression menu.

    ``ratio`` is encoded bytes per raw byte; ``coder_rate`` is the
    encode+decode compute term in seconds per raw byte (both endpoints
    combined); ``fidelity_penalty`` is a dimensionless distortion proxy
    — a scenario's ``fidelity_budget`` gates admissibility and
    ``FlowNetwork.fidelity_weight`` converts the residual distortion of
    an admissible codec into seconds-equivalent cost, so near-lossless
    links are not compressed for free.
    """
    name: str
    ratio: float
    coder_rate: float
    fidelity_penalty: float


# The planner's codec menu.  Ratios mirror the runtime codecs in
# `runtime/activations.py`: bf16 halves the payload, int8 is 1 byte per
# element plus a fp32 scale (~0.26 measured on bench tensors), top-k at
# k=1/16 keeps value+int32 index pairs (2*k of the raw bytes).  Coder
# rates are seconds/byte on the CI-class host (cast ~10 GB/s, quantise
# ~5 GB/s, top-k selection ~2.5 GB/s, encode+decode combined).
WIRE_CODECS: Dict[str, LinkCodec] = {
    "fp32": LinkCodec("fp32", 1.0, 0.0, 0.0),
    "bf16": LinkCodec("bf16", 0.5, 1.0e-10, 0.004),
    "int8": LinkCodec("int8", 0.26, 2.0e-10, 0.02),
    "top-k": LinkCodec("top-k", 0.125, 4.0e-10, 0.08),
}

# Bounded per-epoch size->matrix cache (a codec menu touches a handful
# of sizes per epoch; 16 is generous).
_WIRE_CACHE_MAX = 16

# Reputation defaults for the detect-quarantine-reroute defense layer.
# A fault report multiplies reputation by REPORT_DROP (floored), each
# decay step closes RECOVERY_RATE of the gap back to 1.0, and a node is
# "quarantined" while its reputation sits below QUARANTINE_THRESHOLD.
# With drop 0.2 the edge penalty is reputation_weight*(1/0.2-1) = 4x
# the weight — at the default weight of 50 that is ~200s-equivalent,
# dominating typical Eq. 1 edge costs (~10-40s) so planning routes
# around the node until decay rehabilitates it.
REPORT_DROP = 0.2
REPUTATION_FLOOR = 1e-3
RECOVERY_RATE = 0.4
QUARANTINE_THRESHOLD = 0.5


@dataclass
class Node:
    id: int
    stage: int                  # 0..S-1 for relays; -1 for data nodes
    capacity: int               # max concurrent microbatches (cap_i)
    compute_cost: float         # c_i: time to process one microbatch
    is_data: bool = False
    alive: bool = True
    location: int = -1          # geographic location id (-1 = unknown)

    def __hash__(self):
        return self.id


@dataclass
class FlowNetwork:
    """Global network description — the *simulator's* ground truth.

    Decentralized protocol code only ever reads local slices of this
    (a node's own row/column and its known peers), preserving the paper's
    partial-knowledge property.
    """
    nodes: Dict[int, Node]
    num_stages: int
    latency: np.ndarray          # (N, N) lambda_ij, seconds
    bandwidth: np.ndarray        # (N, N) beta_ij, bytes/s
    activation_size: float       # bytes per microbatch activation
    codec_menu: Tuple[str, ...] = ("fp32",)   # WIRE_CODECS names offered
    fidelity_budget: float = 0.0  # max admissible fidelity_penalty
    fidelity_weight: float = 1.0  # seconds-equivalent per unit penalty
    reputation_weight: float = 50.0  # seconds-equivalent per unit of
    #   distrust (1/rep - 1) on edges into a suspected node
    kv_weight: float = 0.0       # seconds-equivalent per KV-resident
    #   sequence on edges into a loaded node (serving plane; 0 = off)

    # ------------------------------------------------------------------
    # Cached Eq. 1 cost model
    # ------------------------------------------------------------------
    def __setattr__(self, name, value):
        object.__setattr__(self, name, value)
        # rebinding a cost input (e.g. bench code replacing the whole
        # latency matrix, or widening the codec menu) invalidates the
        # caches; in-place element writes still require an explicit
        # invalidate_costs().
        if name in ("latency", "bandwidth", "activation_size",
                    "codec_menu", "fidelity_budget", "fidelity_weight",
                    "reputation_weight", "kv_weight"):
            object.__setattr__(self, "_cost_version",
                               getattr(self, "_cost_version", 0) + 1)

    def invalidate_costs(self):
        """Bump the cache version; the next cost query rebuilds.

        Call after mutating ``latency``/``bandwidth``/``compute_cost`` in
        place.  ``add_node`` calls this automatically.
        """
        self._cost_version = getattr(self, "_cost_version", 0) + 1

    @property
    def cost_version(self) -> int:
        """Monotonic counter identifying the current cost-cache epoch."""
        return getattr(self, "_cost_version", 0)

    def _cost_cache(self) -> dict:
        ver = self.cost_version
        cc = getattr(self, "_cc", None)
        if cc is not None and cc["version"] == ver:
            return cc
        lat_avg = 0.5 * (self.latency + self.latency.T)
        bw_sum = self.bandwidth + self.bandwidth.T
        n = lat_avg.shape[0]
        comp = np.zeros(n)
        for nid, node in self.nodes.items():
            if nid < n:
                comp[nid] = node.compute_cost
        comp_pair = 0.5 * (comp[:, None] + comp[None, :])
        cost = comp_pair + lat_avg + 2.0 * self.activation_size / bw_sum
        cc = dict(version=ver, lat_avg=lat_avg, bw_sum=bw_sum,
                  comp_pair=comp_pair, cost=cost)
        self._cc = cc
        return cc

    # -- wire-codec menu ------------------------------------------------
    def admissible_codecs(self) -> Tuple[LinkCodec, ...]:
        """Menu entries whose fidelity penalty fits the budget, in menu
        order (ties in edge price resolve to the earlier entry).

        ``fp32`` (penalty 0) is always admissible, so an over-tight
        budget degrades to lossless rather than to an empty menu.
        """
        key = (tuple(self.codec_menu), float(self.fidelity_budget))
        cached = getattr(self, "_adm", None)
        if cached is not None and cached[0] == key:
            return cached[1]
        menu = []
        for name in key[0]:
            codec = WIRE_CODECS.get(name)
            if codec is None:
                raise ValueError(
                    f"unknown wire codec {name!r}; "
                    f"known: {sorted(WIRE_CODECS)}")
            if codec.fidelity_penalty <= key[1] or codec.name == "fp32":
                menu.append(codec)
        adm = tuple(menu)
        self._adm = (key, adm)
        return adm

    def _wire_trivial(self) -> bool:
        """True when pricing reduces to the pre-codec fp32 arithmetic."""
        adm = self.admissible_codecs()
        return (len(adm) == 1 and adm[0].ratio == 1.0
                and adm[0].coder_rate == 0.0
                and adm[0].fidelity_penalty == 0.0)

    # -- reputation (detect-quarantine-reroute defense layer) -----------
    def _reputation_trivial(self) -> bool:
        """True when every node is fully trusted (storage is ``None``)
        and pricing reduces to the exact reputation-free arithmetic."""
        return getattr(self, "_reputation", None) is None

    def reputation_active(self) -> bool:
        """True while any node's reputation is below 1.0."""
        return not self._reputation_trivial()

    def _rep_array(self) -> np.ndarray:
        """Materialize (and grow) the reputation vector for mutation."""
        n = (max(self.nodes) + 1) if self.nodes else 0
        rep = getattr(self, "_reputation", None)
        if rep is None:
            rep = np.ones(n)
        elif rep.shape[0] < n:
            grown = np.ones(n)          # joiners start fully trusted
            grown[:rep.shape[0]] = rep
            rep = grown
        self._reputation = rep
        return rep

    def reputation(self, nid: int) -> float:
        rep = getattr(self, "_reputation", None)
        if rep is None or nid >= rep.shape[0]:
            return 1.0
        return float(rep[nid])

    def quarantined(self, nid: int) -> bool:
        """True while planning actively routes around ``nid``."""
        return self.reputation(nid) < QUARANTINE_THRESHOLD

    def set_reputation(self, nid: int, value: float):
        """Pin a node's reputation directly (tests / manual override)."""
        if not 0.0 < value <= 1.0:
            raise ValueError(f"reputation must be in (0, 1], got {value}")
        rep = self._rep_array()
        rep[nid] = value
        self._maybe_snap_trivial()
        self.invalidate_costs()

    def report_fault(self, nid: int, *, drop: float = REPORT_DROP):
        """Multiplicatively drop ``nid``'s reputation on a detection.

        Order-independent within an iteration (multiplication commutes);
        the engine applies decay first, then the iteration's reports, so
        fresh detections carry the full penalty into the next plan.
        """
        rep = self._rep_array()
        rep[nid] = max(REPUTATION_FLOOR, float(rep[nid]) * drop)
        self.invalidate_costs()

    def decay_reputations(self, *, rate: float = RECOVERY_RATE):
        """Relax all reputations toward 1.0 (rehabilitation).

        No-op (and no cache-version bump) in the trivial state, so runs
        that never report a fault keep their exact cache epochs.  When
        the worst deficit decays below 1e-9 storage snaps back to
        ``None`` and pricing returns to the bit-identical trivial path.
        """
        rep = getattr(self, "_reputation", None)
        if rep is None:
            return
        self._reputation = rep + rate * (1.0 - rep)
        self._maybe_snap_trivial()
        self.invalidate_costs()

    def _maybe_snap_trivial(self):
        rep = getattr(self, "_reputation", None)
        if rep is not None and float(np.max(1.0 - rep)) < 1e-9:
            self._reputation = None

    def _rep_penalty(self, cc: dict) -> Optional[np.ndarray]:
        """Per-destination penalty vector ``w*(1/rep - 1)``, or ``None``
        in the trivial state.  Cached per cost-cache epoch (reputation
        mutators bump the version)."""
        rep = getattr(self, "_reputation", None)
        if rep is None:
            return None
        cached = getattr(self, "_rep_pen", None)
        if cached is not None and cached[0] == cc["version"]:
            return cached[1]
        n = cc["lat_avg"].shape[0]
        r = np.ones(n)
        m = min(n, rep.shape[0])
        r[:m] = rep[:m]
        vec = self.reputation_weight * (1.0 / r - 1.0)
        self._rep_pen = (cc["version"], vec)
        return vec

    # -- KV-cache residency (serving plane) -----------------------------
    def _kv_trivial(self) -> bool:
        """True when no sequence is resident anywhere (storage ``None``)
        or the surcharge is off; pricing reduces to the exact
        serving-free arithmetic."""
        return (getattr(self, "_kv_residency", None) is None
                or self.kv_weight == 0.0)

    def kv_active(self) -> bool:
        """True while any node carries resident sequences (and the
        surcharge weight is non-zero)."""
        return not self._kv_trivial()

    def _kv_array(self) -> np.ndarray:
        """Materialize (and grow) the residency vector for mutation."""
        n = (max(self.nodes) + 1) if self.nodes else 0
        res = getattr(self, "_kv_residency", None)
        if res is None:
            res = np.zeros(n)
        elif res.shape[0] < n:
            grown = np.zeros(n)         # joiners start empty
            grown[:res.shape[0]] = res
            res = grown
        self._kv_residency = res
        return res

    def kv_residency(self, nid: int) -> int:
        """Resident-sequence count the planner prices on node ``nid``."""
        res = getattr(self, "_kv_residency", None)
        if res is None or nid >= res.shape[0]:
            return 0
        return int(res[nid])

    def set_kv_residency(self, nid: int, count: int):
        """Pin one node's resident-sequence count."""
        if count < 0:
            raise ValueError(f"kv residency must be >= 0, got {count}")
        res = self._kv_array()
        res[nid] = count
        self._maybe_snap_kv_trivial()
        self.invalidate_costs()

    def update_kv_residency(self, counts: Dict[int, int]):
        """Replace the whole residency map in one cache epoch (the
        serving engine's per-iteration bulk update).  An empty map snaps
        storage back to the trivial ``None`` state."""
        res = self._kv_array()
        res[:] = 0.0
        for nid, count in counts.items():
            if count < 0:
                raise ValueError(
                    f"kv residency must be >= 0, got {count} for {nid}")
            if count and nid < res.shape[0]:
                res[nid] = count
        self._maybe_snap_kv_trivial()
        self.invalidate_costs()

    def _maybe_snap_kv_trivial(self):
        res = getattr(self, "_kv_residency", None)
        if res is not None and float(np.max(res)) < 1e-9:
            self._kv_residency = None

    def _kv_penalty(self, cc: dict) -> Optional[np.ndarray]:
        """Per-destination surcharge vector ``kv_weight * residency``,
        or ``None`` in the trivial state.  Cached per cost-cache epoch
        (residency mutators bump the version)."""
        if self._kv_trivial():
            return None
        cached = getattr(self, "_kv_pen", None)
        if cached is not None and cached[0] == cc["version"]:
            return cached[1]
        res = self._kv_residency
        n = cc["lat_avg"].shape[0]
        r = np.zeros(n)
        m = min(n, res.shape[0])
        r[:m] = res[:m]
        vec = self.kv_weight * r
        self._kv_pen = (cc["version"], vec)
        return vec

    def kv_migration_cost(self, i: int, j: int, kv_bytes: float) -> float:
        """Price of migrating one resident sequence's KV slice from
        node ``i`` to node ``j``: the KV payload moved through the same
        admissible-wire-codec communication model as activations."""
        return self.comm_cost(i, j, kv_bytes)

    # -- combined per-destination planner penalty -----------------------
    def _dest_penalty(self, cc: dict) -> Optional[np.ndarray]:
        """Reputation + KV-residency penalty per destination column, or
        ``None`` when both layers are trivial (the bit-identical path).
        Epoch-cached; when only one layer is active its vector is
        returned untouched (no ``+ 0.0`` pass over it)."""
        rep = self._rep_penalty(cc)
        kv = self._kv_penalty(cc)
        if kv is None:
            return rep
        if rep is None:
            return kv
        cached = getattr(self, "_dest_pen", None)
        if cached is not None and cached[0] == cc["version"]:
            return cached[1]
        vec = rep + kv
        self._dest_pen = (cc["version"], vec)
        return vec

    def _cost_with_rep(self, cc: dict) -> np.ndarray:
        """``cc["cost"]`` plus the destination penalties, epoch-cached;
        returns the untouched legacy object in the trivial state."""
        pen = self._dest_penalty(cc)
        if pen is None:
            return cc["cost"]
        cached = getattr(self, "_cost_rep", None)
        if cached is not None and cached[0] == cc["version"]:
            return cached[1]
        mat = cc["cost"] + pen[None, :]
        self._cost_rep = (cc["version"], mat)
        return mat

    def wire_codec_names(self) -> Tuple[str, ...]:
        """Names indexing ``wire_codec_matrix`` entries (menu order)."""
        return tuple(c.name for c in self.admissible_codecs())

    def wire_codec_ratios(self) -> np.ndarray:
        """Compression ratio per admissible codec, same order as names."""
        return np.array([c.ratio for c in self.admissible_codecs()])

    def wire_codec_matrix(self, size: Optional[float] = None) -> np.ndarray:
        """(N, N) index into ``wire_codec_names()``: the codec each link
        chose at ``size`` bytes (argmin of the per-codec edge price)."""
        cc = self._cost_cache()
        if size is None:
            size = self.activation_size
        comm, choice = self._wire_tables(cc, float(size))
        if choice is None:
            choice = np.zeros(comm.shape, dtype=np.int8)
        return choice

    # -- matrix caches --------------------------------------------------
    @property
    def matrix_rebuild_count(self) -> int:
        """Total per-size comm/edge matrix builds (regression guard for
        the per-epoch dict cache: alternating sizes must not thrash)."""
        return getattr(self, "_matrix_rebuilds", 0)

    def _wire_tables(self, cc: dict, size: float):
        """Codec-priced ``(comm, choice)`` at ``size``, per-epoch cached.

        ``comm[i, j]`` is the communication price of the best admissible
        codec on link (i, j); ``choice`` is the argmin (``None`` on the
        trivial fp32-only path, whose arithmetic is bit-identical to the
        pre-codec implementation).
        """
        cache = getattr(self, "_wire_m", None)
        if cache is None or cache[0] != cc["version"]:
            cache = (cc["version"], {})
            self._wire_m = cache
        ent = cache[1].get(size)
        if ent is not None:
            return ent
        lat, bw = cc["lat_avg"], cc["bw_sum"]
        if self._wire_trivial():
            ent = (lat + 2.0 * size / bw, None)
        else:
            adm = self.admissible_codecs()
            fw = float(self.fidelity_weight)
            first = adm[0]
            best = (lat + 2.0 * (first.ratio * size) / bw
                    + (first.coder_rate * size
                       + fw * first.fidelity_penalty))
            choice = np.zeros(lat.shape, dtype=np.int8)
            for k, codec in enumerate(adm[1:], start=1):
                cand = (lat + 2.0 * (codec.ratio * size) / bw
                        + (codec.coder_rate * size
                           + fw * codec.fidelity_penalty))
                better = cand < best
                best = np.where(better, cand, best)
                choice[better] = k
            ent = (best, choice)
        if len(cache[1]) >= _WIRE_CACHE_MAX:
            cache[1].clear()
        cache[1][size] = ent
        self._matrix_rebuilds = getattr(self, "_matrix_rebuilds", 0) + 1
        return ent

    def cost_matrix(self) -> np.ndarray:
        """Dense Eq. 1 cost matrix at the default activation size.

        Cached; treat as read-only.  ``d(i, j)`` is ``cost_matrix()[i, j]``.
        With a non-trivial codec menu each entry is priced at that
        link's best admissible codec; with active reputations each
        column j additionally pays ``reputation_weight*(1/rep_j - 1)``.
        """
        cc = self._cost_cache()
        if self._wire_trivial():
            return self._cost_with_rep(cc)
        return self.edge_matrix(self.activation_size)

    def comm_matrix(self, size: Optional[float] = None) -> np.ndarray:
        """Dense communication-only Eq. 1 matrix at ``size`` bytes.

        ``comm_matrix(size)[i, j] == comm_cost(i, j, size)`` exactly (the
        elementwise NumPy expression mirrors the scalar one).  Cached in
        a per-epoch size dict; treat as read-only.  This is the batched
        lookup the simulator's event core resolves its per-leg transfer
        delays against instead of calling ``comm_cost`` per event.
        """
        cc = self._cost_cache()
        if size is None:
            size = self.activation_size
        return self._wire_tables(cc, float(size))[0]

    def edge_matrix(self, size: Optional[float] = None) -> np.ndarray:
        """Dense full Eq. 1 matrix (compute + comm) at ``size`` bytes.

        ``edge_matrix(size)[i, j] == edge_cost(i, j, size)`` exactly
        (same elementwise association as the scalar path).  Cached in a
        per-epoch size dict; treat as read-only.
        """
        cc = self._cost_cache()
        pen = self._dest_penalty(cc)
        if self._wire_trivial():
            if size is None:
                return self._cost_with_rep(cc)
            key = float(size)
            cache = getattr(self, "_edge_m", None)
            if cache is None or cache[0] != cc["version"]:
                cache = (cc["version"], {})
                self._edge_m = cache
            mat = cache[1].get(key)
            if mat is None:
                mat = (cc["comp_pair"] + cc["lat_avg"]
                       + 2.0 * float(size) / cc["bw_sum"])
                if pen is not None:
                    # safe to fold into the cached entry: reputation
                    # mutators bump the version, starting a new epoch
                    mat = mat + pen[None, :]
                if len(cache[1]) >= _WIRE_CACHE_MAX:
                    cache[1].clear()
                cache[1][key] = mat
                self._matrix_rebuilds = (
                    getattr(self, "_matrix_rebuilds", 0) + 1)
            return mat
        if size is None:
            size = self.activation_size
        key = float(size)
        cache = getattr(self, "_edge_m", None)
        if cache is None or cache[0] != cc["version"]:
            cache = (cc["version"], {})
            self._edge_m = cache
        mat = cache[1].get(key)
        if mat is None:
            mat = cc["comp_pair"] + self._wire_tables(cc, key)[0]
            if pen is not None:
                mat = mat + pen[None, :]
            if len(cache[1]) >= _WIRE_CACHE_MAX:
                cache[1].clear()
            cache[1][key] = mat
        return mat

    def edge_cost(self, i: int, j: int, size: Optional[float] = None) -> float:
        """Eq. 1 cost of moving one microbatch between nodes i and j."""
        cc = self._cost_cache()
        if self._wire_trivial():
            pen = self._dest_penalty(cc)
            if size is None:
                if pen is None:
                    return float(cc["cost"][i, j])
                return float(self._cost_with_rep(cc)[i, j])
            val = float(cc["comp_pair"][i, j] + cc["lat_avg"][i, j]
                        + 2.0 * size / cc["bw_sum"][i, j])
            if pen is not None:
                val = float(val + pen[j])
            return val
        return float(self.edge_matrix(size)[i, j])

    def comm_cost(self, i: int, j: int, size: Optional[float] = None) -> float:
        """Communication-only part of Eq. 1 (no compute term)."""
        cc = self._cost_cache()
        if size is None:
            size = self.activation_size
        if self._wire_trivial():
            return float(cc["lat_avg"][i, j] + 2.0 * size / cc["bw_sum"][i, j])
        return float(self.comm_matrix(size)[i, j])

    # ------------------------------------------------------------------
    def stage_nodes(self, stage: int, alive_only: bool = True) -> List[Node]:
        return [n for n in self.nodes.values()
                if n.stage == stage and not n.is_data
                and (n.alive or not alive_only)]

    def data_nodes(self) -> List[Node]:
        return [n for n in self.nodes.values() if n.is_data]

    def alive_nodes(self) -> List[Node]:
        return [n for n in self.nodes.values() if n.alive]

    def stage_capacity(self, stage: int) -> int:
        return sum(n.capacity for n in self.stage_nodes(stage))

    def kill_node(self, nid: int):
        """Mark a node dead.  Cost caches stay valid (liveness does not
        change link costs); only membership views change."""
        self.nodes[nid].alive = False

    # ------------------------------------------------------------------
    # Amortized matrix growth for churn
    # ------------------------------------------------------------------
    @property
    def matrix_capacity(self) -> int:
        """Allocated side length of the latency/bandwidth buffers."""
        return getattr(self, "_matrix_capacity", self.latency.shape[0])

    @property
    def matrix_grow_count(self) -> int:
        """Number of buffer reallocations performed by ``add_node`` —
        O(log joins) thanks to geometric growth (the seed reallocated
        on every join)."""
        return getattr(self, "_grow_count", 0)

    def _ensure_matrix_capacity(self, size: int):
        lat_buf = getattr(self, "_lat_buf", None)
        bw_buf = getattr(self, "_bw_buf", None)
        backed = (lat_buf is not None
                  and (self.latency is lat_buf or self.latency.base is lat_buf)
                  and (self.bandwidth is bw_buf
                       or self.bandwidth.base is bw_buf))
        if not backed:
            # First growth, or the matrices were rebound externally
            # (e.g. bench code replacing net.latency wholesale): adopt
            # the *current* arrays so the rebound values survive the
            # next join instead of being shadowed by a stale buffer.
            self._lat_buf = self.latency
            self._bw_buf = self.bandwidth
            self._matrix_capacity = self.latency.shape[0]
            if not hasattr(self, "_grow_count"):
                self._grow_count = 0
        if self._matrix_capacity >= size:
            return
        cap = self._matrix_capacity
        newcap = max(16, size, 2 * cap)
        n = self.latency.shape[0]
        lat = np.full((newcap, newcap), DEFAULT_JOIN_LATENCY)
        bw = np.full((newcap, newcap), DEFAULT_JOIN_BANDWIDTH)
        lat[:n, :n] = self.latency
        bw[:n, :n] = self.bandwidth
        self._lat_buf, self._bw_buf = lat, bw
        self._matrix_capacity = newcap
        self._grow_count += 1

    def add_node(self, node: Node, latency_row=None, latency_col=None,
                 bandwidth_row=None, bandwidth_col=None):
        """Grow the matrices for a joining node (amortized O(N))."""
        n = max(self.nodes) + 1 if self.nodes else 0
        assert node.id == n, f"node ids must be dense ({node.id} != {n})"
        size = n + 1
        self._ensure_matrix_capacity(size)
        # Rows/cols beyond the live region are pristine fill values: each
        # row/col index is written at most once (ids are dense and nodes
        # are never removed from the matrices).
        if latency_row is not None:
            self._lat_buf[n, :n] = latency_row
        if latency_col is not None:
            self._lat_buf[:n, n] = latency_col
        if bandwidth_row is not None:
            self._bw_buf[n, :n] = bandwidth_row
        if bandwidth_col is not None:
            self._bw_buf[:n, n] = bandwidth_col
        self.latency = self._lat_buf[:size, :size]
        self.bandwidth = self._bw_buf[:size, :size]
        self.nodes[node.id] = node
        self.invalidate_costs()


# ---------------------------------------------------------------------------
# Topology builders (paper Sec. VI setup)
# ---------------------------------------------------------------------------

def geo_distributed_network(
    *,
    num_stages: int,
    relay_capacities: List[int],
    num_data_nodes: int = 2,
    data_capacity: int = 4,
    rng: Optional[np.random.Generator] = None,
    num_locations: int = 10,
    min_bandwidth: float = 50e6 / 8,     # 50 Mb/s in bytes/s
    max_bandwidth: float = 500e6 / 8,    # 500 Mb/s
    compute_cost: float = 6.0,           # seconds per microbatch fwd+bwd
    compute_jitter: float = 0.3,
    activation_size: float = 4 * 512 * 1024 * 2 * 32,  # mb=4, seq=512, x32 scale
) -> FlowNetwork:
    """Build the paper's geo-distributed evaluation topology.

    Relay nodes are spread over ``num_locations`` simulated locations;
    intra-location links get max bandwidth / low latency, inter-location
    links get degraded bandwidth (down to 50 Mb/s) and higher latency.
    ``activation_size`` bakes in the paper's x32 bandwidth-reduction trick.

    Link matrices are drawn with NumPy broadcasting (O(N^2) C work, not
    O(N^2) Python loop iterations), so thousand-node topologies build in
    milliseconds.  NOTE: the batched draws consume the RNG stream in a
    different order than the seed implementation's per-pair loop, so a
    given seed yields a different (equally distributed) topology than
    before the scale rebuild; node capacities/compute costs, drawn
    first, are unchanged.
    """
    rng = rng or np.random.default_rng(0)
    nodes: Dict[int, Node] = {}
    nid = 0
    for _ in range(num_data_nodes):
        nodes[nid] = Node(nid, -1, data_capacity, 0.0, is_data=True)
        nid += 1
    per_stage = len(relay_capacities) // num_stages
    for s in range(num_stages):
        for k in range(per_stage):
            cap = relay_capacities[s * per_stage + k]
            c = compute_cost * (1.0 + compute_jitter * rng.standard_normal())
            nodes[nid] = Node(nid, s, cap, max(0.5, c))
            nid += 1

    N = nid
    loc = rng.integers(0, num_locations, size=N)
    for i in range(N):
        nodes[i].location = int(loc[i])   # drives correlated regional churn
    same = loc[:, None] == loc[None, :]
    lat = np.where(same,
                   rng.uniform(0.001, 0.005, size=(N, N)),
                   rng.uniform(0.02, 0.15, size=(N, N)))
    bw = np.where(same,
                  max_bandwidth,
                  rng.uniform(min_bandwidth, max_bandwidth, size=(N, N)))
    np.fill_diagonal(lat, 0.0)
    np.fill_diagonal(bw, max_bandwidth)
    return FlowNetwork(nodes=nodes, num_stages=num_stages, latency=lat,
                       bandwidth=bw, activation_size=activation_size)


def synthetic_network(
    *,
    num_stages: int,
    relays_per_stage: int,
    capacities,                   # callable(rng) -> int
    link_costs,                   # callable(rng) -> float, or
                                  # callable(rng, shape) -> (N, N) array
    num_sources: int = 1,
    source_capacity: int = 100,
    rng: Optional[np.random.Generator] = None,
) -> Tuple[FlowNetwork, np.ndarray]:
    """Abstract flow-test network (paper Tables IV/V): d_ij drawn directly.

    Returns (network, cost_matrix) where cost_matrix[i, j] *is* d_ij —
    edge_cost is bypassed by storing costs in the latency matrix with
    zero compute and infinite bandwidth.

    ``link_costs`` may optionally accept a second ``shape`` argument and
    return a full (N, N) array — the vectorized fast path used by the
    scaling benchmarks.  Scalar callables keep the seed's element-wise
    draw order (diagonal excluded), so existing seeds reproduce.
    """
    rng = rng or np.random.default_rng(0)
    nodes: Dict[int, Node] = {}
    nid = 0
    for _ in range(num_sources):
        nodes[nid] = Node(nid, -1, source_capacity, 0.0, is_data=True)
        nid += 1
    for s in range(num_stages):
        for _ in range(relays_per_stage):
            nodes[nid] = Node(nid, s, int(capacities(rng)), 0.0)
            nid += 1
    N = nid
    # Detect the batched protocol from the signature instead of probing
    # with a trial call: a probe could consume RNG draws inside a
    # shape-tolerant scalar callable and silently shift the stream.
    batched = False
    try:
        params = list(inspect.signature(link_costs).parameters.values())
        batched = (len([p for p in params if p.kind in
                        (inspect.Parameter.POSITIONAL_ONLY,
                         inspect.Parameter.POSITIONAL_OR_KEYWORD)]) >= 2
                   or any(p.kind == inspect.Parameter.VAR_POSITIONAL
                          for p in params))
    except (TypeError, ValueError):
        batched = False
    if batched:
        cost = np.asarray(link_costs(rng, (N, N)), dtype=float)
        if cost.shape != (N, N):
            raise ValueError(
                f"batched link_costs must return shape {(N, N)}, "
                f"got {cost.shape}")
        cost = cost.copy()
        np.fill_diagonal(cost, 0.0)
    else:
        cost = np.empty((N, N))
        for i in range(N):
            for j in range(N):
                cost[i, j] = link_costs(rng) if i != j else 0.0
    net = FlowNetwork(nodes=nodes, num_stages=num_stages,
                      latency=cost, bandwidth=np.full((N, N), np.inf),
                      activation_size=0.0)
    return net, cost
