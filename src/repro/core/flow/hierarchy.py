"""Hierarchical geo-planning: region super-nodes + local refinement.

The paper's evaluation topology has a fixed geographic structure (10
locations, Sec. VI): intra-location links are fast and cheap,
inter-location links slow and expensive.  At internet scale (10k+
relays) a flat planner pays O(N) scan costs per decision even though
most of the placement signal lives at the *region* level.  This module
exploits that structure in two phases:

1. **Region graph.**  Alive relays are aggregated by
   (``Node.stage``, ``Node.location``) into super-nodes whose capacity
   is the sum of their members' capacities; the super-edge cost between
   adjacent-stage super-nodes is the mean pairwise member cost (rounded
   to the nearest integer when the underlying matrix is integral, so
   the O(V + C) dial core stays applicable).  The exact
   ``solve_training_flow`` MCMF oracle then runs on this
   ~``num_locations x num_stages`` graph — thousands of times smaller
   than the flat problem — and its path decomposition yields one
   *region chain* per unit of flow.

2. **Local refinement.**  Region chains are materialized stage by
   stage: all units entering the same (stage, region) super-node form
   one small transportation problem — unit ``u`` (whose concrete
   predecessor is already fixed) is matched to a member node ``m`` at
   cost ``d(prev_u, m)`` (plus the return edge ``d(m, origin_u)`` at
   the last stage, so the closing hop is not chosen blindly), subject
   to member capacities.  Each transport is solved exactly with a tiny
   `MinCostFlow` (dial core on quantized costs), and the transports of
   one stage are independent across regions — ``parallel=`` hands them
   to a thread pool.  The forward construction is myopic (it cannot see
   a node's *outgoing* edge yet), so ``refine_passes`` coordinate-descent
   sweeps follow: each re-solves one stage's transports with both
   neighbours fixed (cost ``d(prev_u, m) + d(m, next_u)``), which only
   ever lowers the plan cost.

The result is a feasible concrete plan whose cost is measured against
the flat dial MCMF oracle by ``benchmarks/bench_scale.py`` (the
committed optimality-gap bound) — hierarchy trades a bounded gap for
planning time that scales with ``regions^2 x stages`` instead of
``N^2``.
"""
from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.flow.graph import FlowNetwork, Node
from repro.core.flow.mincost import MinCostFlow, solve_training_flow

#: quantization step (cost units) used to make float (geo) costs
#: integral for the dial core; the per-edge rounding error is bounded
#: by half this quantum.
DEFAULT_QUANTUM = 1e-3


@dataclass
class HierarchicalPlan:
    """Result of ``solve_hierarchical``.

    ``cost`` is the concrete (refined) plan's total chain cost under
    the *original* cost matrix; ``region_cost`` is the super-node
    relaxation's optimal objective (quantized units when the input was
    float) — a lower-fidelity signal, kept for diagnostics.
    """
    flow: float
    cost: float
    paths: List[List[int]]
    region_cost: float
    num_regions: int
    regions: Dict[Tuple[int, int], List[int]] = field(default_factory=dict)


def aggregate_regions(net: FlowNetwork) -> Dict[Tuple[int, int], List[int]]:
    """Alive relays grouped by (stage, location).

    Relays with an unset location (-1) form their own pseudo-region per
    stage, so topologies without geography degrade to one super-node
    per stage (the hierarchy then *is* the stage graph).
    """
    regions: Dict[Tuple[int, int], List[int]] = {}
    for n in net.alive_nodes():
        if n.is_data:
            continue
        regions.setdefault((n.stage, n.location), []).append(n.id)
    return regions


def build_region_network(
        net: FlowNetwork,
        cost_matrix: Optional[np.ndarray] = None,
) -> Tuple[FlowNetwork, np.ndarray, Dict[int, Tuple[int, int]], Dict[int, int]]:
    """The super-node relaxation of ``net``.

    Returns ``(region_net, region_cm, super_of, data_map)`` where
    ``region_net`` has one data node per alive data node of ``net``
    (same capacity) and one relay super-node per (stage, region);
    ``super_of`` maps super-node id -> (stage, region) and ``data_map``
    maps original data-node id -> region-net id.  ``region_cm[a, b]``
    is the mean member-pair cost (integral when the input matrix is
    integral, so ``method="auto"`` keeps selecting the dial core) on
    the adjacent-stage blocks the layered flow consumes; unconsumed
    blocks are left zero rather than aggregated.

    When ``net`` carries a wire-codec menu, ``net.cost_matrix()`` is
    already priced at each link's best admissible codec, so the region
    aggregation (and everything downstream) is codec-aware for free;
    the region net itself stores the aggregated costs directly
    (infinite bandwidth), so no second round of codec pricing applies.
    """
    CM = (np.asarray(cost_matrix, float) if cost_matrix is not None
          else net.cost_matrix())
    regions = aggregate_regions(net)
    data = [n for n in net.data_nodes() if n.alive]
    nodes: Dict[int, Node] = {}
    data_map: Dict[int, int] = {}
    rid = 0
    for n in data:
        nodes[rid] = Node(rid, -1, n.capacity, 0.0, is_data=True)
        data_map[n.id] = rid
        rid += 1
    super_of: Dict[int, Tuple[int, int]] = {}
    member_ids: Dict[int, np.ndarray] = {}
    for (s, loc) in sorted(regions):
        ids = regions[(s, loc)]
        cap = sum(net.nodes[i].capacity for i in ids)
        nodes[rid] = Node(rid, s, cap, 0.0, location=loc)
        super_of[rid] = (s, loc)
        member_ids[rid] = np.asarray(ids, np.int64)
        rid += 1
    R = rid
    groups: List[np.ndarray] = []
    for r in range(R):
        if r in member_ids:
            groups.append(member_ids[r])
        else:
            orig = next(k for k, v in data_map.items() if v == r)
            groups.append(np.asarray([orig], np.int64))
    # The layered region flow only consumes data->stage0,
    # stage_s->stage_{s+1} and stage_{S-1}->data edges, so aggregate
    # exactly those directed blocks (reduceat over a per-stage-pair
    # gather) instead of paying a full N^2 pass for R^2 means — the
    # difference between ~5 s and ~0.1 s at 10k nodes.
    rcm = np.zeros((R, R))
    data_rids = [data_map[n.id] for n in data]
    stage_rids: List[List[int]] = [[] for _ in range(net.num_stages)]
    for srid, (s, _) in super_of.items():
        stage_rids[s].append(srid)
    integral = True

    def fill(rows_rids: List[int], cols_rids: List[int]) -> None:
        nonlocal integral
        if not rows_rids or not cols_rids:
            return
        rlens = np.asarray([len(groups[r]) for r in rows_rids], np.int64)
        clens = np.asarray([len(groups[r]) for r in cols_rids], np.int64)
        rows = np.concatenate([groups[r] for r in rows_rids])
        cols = np.concatenate([groups[r] for r in cols_rids])
        block = CM[np.ix_(rows, cols)]
        if integral:
            integral = bool(np.isfinite(block).all()
                            and (block == np.floor(block)).all())
        rstarts = np.zeros(len(rows_rids), np.int64)
        np.cumsum(rlens[:-1], out=rstarts[1:])
        cstarts = np.zeros(len(cols_rids), np.int64)
        np.cumsum(clens[:-1], out=cstarts[1:])
        sums = np.add.reduceat(
            np.add.reduceat(block, rstarts, axis=0), cstarts, axis=1)
        rcm[np.ix_(rows_rids, cols_rids)] = \
            sums / (rlens[:, None] * clens[None, :])

    S = net.num_stages
    fill(data_rids, stage_rids[0])
    for s in range(S - 1):
        fill(stage_rids[s], stage_rids[s + 1])
    fill(stage_rids[S - 1], data_rids)
    if integral:
        rcm = np.rint(rcm)          # keep the dial core applicable
    region_net = FlowNetwork(nodes=nodes, num_stages=net.num_stages,
                             latency=rcm,
                             bandwidth=np.full((R, R), np.inf),
                             activation_size=0.0)
    return region_net, rcm, super_of, data_map


try:
    from scipy.optimize import linear_sum_assignment as _lsa
except ImportError:                               # pragma: no cover
    _lsa = None


def _solve_transport(C: np.ndarray, caps: np.ndarray,
                     quantum: float) -> List[int]:
    """Exact min-cost matching of k units to m capacitated members.

    ``C[u, j]`` is the cost of placing unit ``u`` on member ``j``;
    returns the chosen member column per unit.  Members are expanded
    into capacity-many columns and handed to scipy's C assignment
    solver (exact, ~100x faster than a python-level MCMF on these
    ~100x100 problems); without scipy the `MinCostFlow` dial core on
    quantized costs is the fallback (same optimum, bounded rounding).
    """
    k, m = C.shape
    if m == 1:
        return [0] * k
    if _lsa is not None:
        icaps = caps.astype(np.int64)
        cols = np.repeat(np.arange(m), icaps)
        _, chosen = _lsa(C[:, cols])
        return cols[chosen].tolist()
    solve_method = "dial"
    if not np.isfinite(C).all():
        Cq = C                      # disconnected pairs: dense core
        solve_method = "dense"
    elif (C == np.floor(C)).all():
        Cq = C
    else:
        Cq = np.round(C / quantum)
    V = k + m + 2
    S, T = V - 2, V - 1
    mc = MinCostFlow(V, arc_hint=k * m + k + m)
    uk = np.arange(k, dtype=np.int64)
    mk = k + np.arange(m, dtype=np.int64)
    mc.add_edges(np.full(k, S, np.int64), uk, 1.0, 0.0)
    unit_arcs = mc.add_edges(np.repeat(uk, m), np.tile(mk, k),
                             1.0, Cq.ravel())
    mc.add_edges(mk, np.full(m, T, np.int64), caps.astype(float), 0.0)
    mc.solve(S, T, float(k), method=solve_method)
    cap = mc.cap
    choice: List[int] = []
    for u in range(k):
        arcs = unit_arcs[u * m:(u + 1) * m]
        picked = np.flatnonzero(cap[arcs ^ 1] > 0.5)
        choice.append(int(picked[0]) if picked.size else 0)
    return choice


def solve_hierarchical(net: FlowNetwork,
                       cost_matrix: Optional[np.ndarray] = None,
                       data_node: Optional[int] = None,
                       max_flow: Optional[float] = None,
                       method: str = "auto",
                       parallel: int = 0,
                       refine_passes: int = 2,
                       quantum: float = DEFAULT_QUANTUM) -> HierarchicalPlan:
    """Two-phase hierarchical plan (region MCMF + local refinement).

    ``parallel`` > 0 refines a stage's per-region transports on that
    many worker threads (they are independent problems); 0 = serial.
    ``refine_passes`` coordinate-descent sweeps follow the forward
    construction (each monotonically lowers the plan cost).  Other
    parameters mirror ``solve_training_flow``.
    """
    CM = (np.asarray(cost_matrix, float) if cost_matrix is not None
          else net.cost_matrix())
    region_net, rcm, super_of, data_map = build_region_network(net, CM)
    regions = aggregate_regions(net)
    rplan = solve_training_flow(
        region_net, cost_matrix=rcm,
        data_node=None if data_node is None else data_map[data_node],
        max_flow=max_flow, want_paths=True, method=method)
    inv_data = {v: k for k, v in data_map.items()}
    S = net.num_stages
    # unit u: origin data node + its region chain (location per stage)
    origins: List[int] = []
    chains: List[List[int]] = []
    for rpath in rplan.paths:
        if len(rpath) != S + 2 or rpath[0] not in inv_data:
            continue
        origins.append(inv_data[rpath[0]])
        chains.append([super_of[r][1] for r in rpath[1:-1]])
    U = len(origins)
    concrete: List[List[int]] = [[dn] for dn in origins]
    caps_left = {nid: net.nodes[nid].capacity
                 for ids in regions.values() for nid in ids}

    def refine_group(s: int, loc: int, units: List[int], sweep: bool):
        members = regions[(s, loc)]
        marr = np.asarray(members, np.int64)
        # concrete[u][s] is unit u's stage-(s-1) node (or origin at s=0)
        parr = np.asarray([concrete[u][s] for u in units], np.int64)
        C = CM[np.ix_(parr, marr)]
        if s == S - 1:
            # the closing hop back to each unit's own origin is known
            # even during construction — fold it in so the last stage
            # is not chosen blindly
            nxt = np.asarray([origins[u] for u in units], np.int64)
            C = C + CM[np.ix_(marr, nxt)].T
        elif sweep:
            nxt = np.asarray([concrete[u][s + 2] for u in units], np.int64)
            C = C + CM[np.ix_(marr, nxt)].T
        caps = np.asarray([caps_left[mid] for mid in members], float)
        choice = _solve_transport(C, caps, quantum)
        return units, marr, choice

    def run_stage(s: int, sweep: bool):
        by_loc: Dict[int, List[int]] = {}
        for u in range(U):
            by_loc.setdefault(chains[u][s], []).append(u)
        if sweep:
            # release this stage's current seats before re-matching
            for u in range(U):
                caps_left[concrete[u][s + 1]] += 1
        groups = [(s, loc, units, sweep) for loc, units in by_loc.items()]
        if parallel > 0 and len(groups) > 1:
            with ThreadPoolExecutor(max_workers=parallel) as pool:
                results = list(pool.map(lambda g: refine_group(*g), groups))
        else:
            results = [refine_group(*g) for g in groups]
        for units, marr, choice in results:
            for u, j in zip(units, choice):
                nid = int(marr[j])
                caps_left[nid] -= 1
                if sweep:
                    concrete[u][s + 1] = nid
                else:
                    concrete[u].append(nid)

    for s in range(S):
        run_stage(s, sweep=False)
    for _ in range(max(0, refine_passes)):
        for s in range(S):
            run_stage(s, sweep=True)
    total = 0.0
    paths: List[List[int]] = []
    for u in range(U):
        chain = concrete[u] + [origins[u]]
        paths.append(chain)
        total += float(sum(CM[a, b] for a, b in zip(chain, chain[1:])))
    return HierarchicalPlan(flow=float(U), cost=total, paths=paths,
                            region_cost=rplan.cost,
                            num_regions=len({loc for _, loc in regions}),
                            regions=regions)
