"""Centralized min-cost max-flow oracle (out-of-kilter equivalent).

The paper's optimal baselines (Fig. 5, Fig. 7, Table VI) use Fulkerson's
out-of-kilter algorithm [19].  We implement successive shortest paths
with Johnson potentials, which computes the same optimum (min-cost
max-flow is unique in value).

Arc storage is preallocated NumPy arrays with geometric growth (amortized
O(1) per ``add_edge``; ``add_edges`` appends whole arc batches in one
vectorized write — the layered training graph's dense stage-to-stage
mesh builds in milliseconds instead of hundreds of thousands of Python
calls).  Two interchangeable Dijkstra cores drive the successive
shortest paths:

* **dial** (default when every arc cost is a small integer, as in the
  paper's Table IV/V graphs): Johnson potentials stay integral, so each
  Dijkstra runs over integer distances with a bucket (Dial) queue —
  node extraction is an O(1) bucket pop driven by a tiny heap of
  distinct distances, relaxation stays vectorized per CSR slice, and
  the search stops as soon as the sink settles.  O(F * (E + D log D))
  with D = distinct distance values; ~10x over the dense core on the
  2000-relay scaling benchmark, which makes the optimal baseline cheap
  enough to re-run online next to the decentralized engine.
* **dense**: masked ``argmin`` extraction over the distance vector,
  O(F * (V^2 + E)) — the general-cost fallback (and the equality oracle
  for the dial core's tests).

``solve(..., method=)`` accepts ``"auto"`` (integer costs -> dial),
``"dial"``, or ``"dense"``.

The training graph is layered: super-source -> data nodes -> stage 0 ->
... -> stage S-1 -> super-sink, node capacities enforced by splitting
every node into (in, out) with a capacity arc.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.flow.graph import FlowNetwork


class MinCostFlow:
    """Successive-shortest-paths MCMF on preallocated NumPy arc arrays.

    ``to``/``cap``/``cost`` keep their original (arc-indexed) meaning —
    arc ``i ^ 1`` is the reverse of arc ``i`` — but are exposed as array
    views; ``graph[u]`` (adjacency lists of arc ids, insertion order) is
    materialised lazily for the path-decomposition consumers.
    """

    def __init__(self, n: int, arc_hint: int = 64):
        self.n = n
        self._m = 0
        capacity = max(16, 2 * arc_hint)
        self._to = np.empty(capacity, np.int64)
        self._cap = np.empty(capacity, np.float64)
        self._cost = np.empty(capacity, np.float64)
        self._src = np.empty(capacity, np.int64)
        self._graph: Optional[List[List[int]]] = None

    # -- array views / legacy accessors ---------------------------------
    @property
    def to(self) -> np.ndarray:
        return self._to[:self._m]

    @property
    def cap(self) -> np.ndarray:
        return self._cap[:self._m]

    @property
    def cost(self) -> np.ndarray:
        return self._cost[:self._m]

    @property
    def graph(self) -> List[List[int]]:
        if self._graph is None:
            g: List[List[int]] = [[] for _ in range(self.n)]
            for idx, u in enumerate(self._src[:self._m].tolist()):
                g[u].append(idx)
            self._graph = g
        return self._graph

    def _grow(self, need: int):
        capacity = len(self._to)
        if need <= capacity:
            return
        new = max(need, 2 * capacity)
        for name in ("_to", "_cap", "_cost", "_src"):
            old = getattr(self, name)
            arr = np.empty(new, old.dtype)
            arr[:self._m] = old[:self._m]
            setattr(self, name, arr)

    def add_edge(self, u: int, v: int, cap: float, cost: float) -> int:
        idx = self._m
        self._grow(idx + 2)
        self._to[idx] = v
        self._cap[idx] = cap
        self._cost[idx] = cost
        self._src[idx] = u
        self._to[idx + 1] = u
        self._cap[idx + 1] = 0.0
        self._cost[idx + 1] = -cost
        self._src[idx + 1] = v
        self._m += 2
        self._graph = None
        return idx

    def add_edges(self, us, vs, caps, costs) -> np.ndarray:
        """Vectorized batch append; returns the forward arc indices.

        Equivalent to ``[add_edge(u, v, c, w) for ...]`` (same arc ids,
        same ``i ^ 1`` reverse pairing) in a handful of array writes.
        """
        us = np.asarray(us, np.int64)
        vs = np.asarray(vs, np.int64)
        caps = np.broadcast_to(np.asarray(caps, np.float64), us.shape)
        costs = np.broadcast_to(np.asarray(costs, np.float64), us.shape)
        k = len(us)
        m0 = self._m
        self._grow(m0 + 2 * k)
        fwd = m0 + 2 * np.arange(k, dtype=np.int64)
        self._to[fwd] = vs
        self._to[fwd + 1] = us
        self._cap[fwd] = caps
        self._cap[fwd + 1] = 0.0
        self._cost[fwd] = costs
        self._cost[fwd + 1] = -costs
        self._src[fwd] = us
        self._src[fwd + 1] = vs
        self._m = m0 + 2 * k
        self._graph = None
        return fwd

    def solve(self, s: int, t: int, max_flow: float = float("inf"),
              method: str = "auto") -> Tuple[float, float]:
        """Returns (flow, cost).

        ``method``: ``"dial"`` (integer-cost bucket-queue Dijkstra),
        ``"dense"`` (masked-argmin Dijkstra, any costs), or ``"auto"``
        (dial iff every arc cost is a finite integer).
        """
        m = self._m
        costs = self._cost[:m]
        if method == "auto":
            finite = np.isfinite(costs)
            integral = bool(finite.all()
                            and (costs == np.floor(costs)).all())
            method = "dial" if integral else "dense"
        elif method == "dial":
            if not (np.isfinite(costs).all()
                    and (costs == np.floor(costs)).all()):
                raise ValueError("dial method requires finite integer "
                                 "arc costs")
        if method == "dial":
            return self._solve_dial(s, t, max_flow)
        return self._solve_dense(s, t, max_flow)

    def _csr(self):
        """CSR adjacency: arcs grouped by source, insertion order kept."""
        n, m = self.n, self._m
        src = self._src[:m]
        arc_order = np.argsort(src, kind="stable")
        to_sorted = self._to[arc_order]
        cost_sorted = self._cost[arc_order]
        start = np.zeros(n + 1, np.int64)
        np.cumsum(np.bincount(src, minlength=n), out=start[1:])
        return arc_order, to_sorted, cost_sorted, start

    def _augment(self, s: int, t: int, prev_arc: np.ndarray,
                 headroom: float) -> Tuple[float, float]:
        """Push the bottleneck along prev_arc's s->t path; returns
        (pushed flow, added cost)."""
        push = headroom
        v = t
        while v != s:
            idx = int(prev_arc[v])
            cap = float(self._cap[idx])
            if cap < push:
                push = cap
            v = int(self._to[idx ^ 1])
        cost = 0.0
        v = t
        while v != s:
            idx = int(prev_arc[v])
            self._cap[idx] -= push
            self._cap[idx ^ 1] += push
            cost += push * float(self._cost[idx])
            v = int(self._to[idx ^ 1])
        return push, cost

    def _solve_dense(self, s: int, t: int,
                     max_flow: float) -> Tuple[float, float]:
        n = self.n
        arc_order, to_sorted, cost_sorted, start = self._csr()
        inf = float("inf")
        flow = cost = 0.0
        potential = np.zeros(n)
        while flow < max_flow:
            dist = np.full(n, inf)
            dist[s] = 0.0
            prev_arc = np.full(n, -1, np.int64)
            done = np.zeros(n, bool)
            for _ in range(n):
                u = int(np.argmin(np.where(done, inf, dist)))
                if done[u] or dist[u] == inf:
                    break
                done[u] = True
                a0, a1 = int(start[u]), int(start[u + 1])
                if a0 == a1:
                    continue
                arcs = arc_order[a0:a1]
                open_ = self._cap[arcs] > 1e-9
                if not open_.any():
                    continue
                arcs = arcs[open_]
                vs = to_sorted[a0:a1][open_]
                nd = dist[u] + cost_sorted[a0:a1][open_] \
                    + potential[u] - potential[vs]
                better = nd < dist[vs] - 1e-12
                if better.any():
                    vs_b = vs[better]
                    nd_b = nd[better]
                    arcs_b = arcs[better]
                    np.minimum.at(dist, vs_b, nd_b)
                    # any arc achieving the (possibly shared) new minimum
                    won = nd_b == dist[vs_b]
                    prev_arc[vs_b[won]] = arcs_b[won]
            if dist[t] == inf:
                break
            finite = dist < inf
            potential[finite] += dist[finite]
            push, added = self._augment(s, t, prev_arc, max_flow - flow)
            cost += added
            flow += push
        return float(flow), float(cost)

    def _solve_dial(self, s: int, t: int,
                    max_flow: float) -> Tuple[float, float]:
        """Integer-cost core: bucket-queue Dijkstra phases, each
        followed by a *blocking flow* over the admissible
        (zero-reduced-cost) subgraph.

        Reduced costs under integral Johnson potentials stay integral
        and non-negative, so distances are exact ints (no epsilon
        comparisons) and node extraction is an O(1) bucket pop driven
        by a heap of distinct distances, stopping as soon as the sink
        settles.  Every augmenting path inside the admissible subgraph
        is a current shortest path, so saturating a blocking flow per
        phase pushes what plain successive-shortest-paths would push
        over many identical Dijkstra re-runs — same optimum, a fraction
        of the searches."""
        n = self.n
        arc_order, to_sorted, cost_sorted, start = self._csr()
        cost_i = cost_sorted.astype(np.int64)
        INF = np.iinfo(np.int64).max
        flow = cost = 0.0
        potential = np.zeros(n, np.int64)
        cap = self._cap
        while flow < max_flow:
            dist = np.full(n, INF, np.int64)
            dist[s] = 0
            done = np.zeros(n, bool)
            buckets: Dict[int, List[int]] = {0: [s]}
            heap = [0]
            dist_t = INF
            while heap:
                d = heapq.heappop(heap)
                if d >= dist_t:
                    break                      # sink settled: done
                for u in buckets.pop(d, ()):
                    if done[u] or dist[u] != d:
                        continue               # stale bucket entry
                    done[u] = True
                    if u == t:
                        dist_t = d
                        break
                    a0, a1 = int(start[u]), int(start[u + 1])
                    if a0 == a1:
                        continue
                    arcs = arc_order[a0:a1]
                    open_ = cap[arcs] > 1e-9
                    if not open_.any():
                        continue
                    vs = to_sorted[a0:a1][open_]
                    nd = d + cost_i[a0:a1][open_] \
                        + potential[u] - potential[vs]
                    better = nd < dist[vs]
                    if not better.any():
                        continue
                    vs_b = vs[better]
                    nd_b = nd[better]
                    np.minimum.at(dist, vs_b, nd_b)
                    won = nd_b == dist[vs_b]
                    for v, nv in zip(vs_b[won].tolist(),
                                     nd_b[won].tolist()):
                        bk = buckets.get(nv)
                        if bk is None:
                            buckets[nv] = [v]
                            heapq.heappush(heap, nv)
                        else:
                            bk.append(v)
                if dist_t < INF:
                    break
            if dist_t == INF:
                break
            # early-stopped: unsettled nodes count as dist_t (the
            # standard truncation keeps reduced costs non-negative)
            np.minimum(dist, dist_t, out=dist)
            potential += dist
            pushed, added = self._blocking_flow(
                s, t, max_flow - flow, potential,
                arc_order, to_sorted, start)
            if pushed <= 0.0:
                break                          # numerical safety valve
            flow += pushed
            cost += added
        return float(flow), float(cost)

    def _blocking_flow(self, s: int, t: int, headroom: float,
                       potential: np.ndarray, arc_order: np.ndarray,
                       to_sorted: np.ndarray, start: np.ndarray
                       ) -> Tuple[float, float]:
        """Saturate augmenting paths in the admissible subgraph (arcs
        with zero reduced cost and open capacity) via a current-arc DFS
        — Dinic's blocking-flow step specialised to the cost-admissible
        network.  Returns (pushed flow, added cost)."""
        m = self._m
        src = self._src[:m]
        to = self._to[:m]
        # reduced costs are integral-valued floats: exact zero test
        rc = self._cost[:m] + potential[src] - potential[to]
        adm = (rc == 0.0) & (self._cap[:m] > 1e-9)
        adm_sorted = adm[arc_order]
        pos = np.flatnonzero(adm_sorted)
        if not pos.size:
            return 0.0, 0.0
        arcs_c = arc_order[pos].tolist()
        to_c = to_sorted[pos].tolist()
        start_c = np.searchsorted(pos, start).tolist()
        ptr = start_c[:-1]                     # current-arc pointers
        end_c = start_c[1:]
        cap = self._cap
        cost_arr = self._cost
        pushed = added = 0.0
        path: List[int] = []                   # compacted arc positions
        nodes: List[int] = [s]
        onpath = [False] * self.n              # zero-cost cycles exist in
        onpath[s] = True                       # the admissible graph —
        u = s                                  # never re-enter the path
        while True:
            if u == t:
                arcs = [arcs_c[p] for p in path]
                push = headroom - pushed
                for a in arcs:
                    c = float(cap[a])
                    if c < push:
                        push = c
                for a in arcs:
                    cap[a] -= push
                    cap[a ^ 1] += push
                    added += push * float(cost_arr[a])
                pushed += push
                if pushed >= headroom - 1e-9:
                    break
                # rewind to just before the first saturated arc
                cut = 0
                for k, a in enumerate(arcs):
                    if cap[a] <= 1e-9:
                        cut = k
                        break
                del path[cut:]
                for nid in nodes[cut + 1:]:
                    onpath[nid] = False
                del nodes[cut + 1:]
                u = nodes[-1]
                continue
            advanced = False
            p = ptr[u]
            e = end_c[u]
            while p < e:
                if cap[arcs_c[p]] > 1e-9 and not onpath[to_c[p]]:
                    advanced = True
                    break
                p += 1
            ptr[u] = p
            if advanced:
                path.append(p)
                u = to_c[p]
                nodes.append(u)
                onpath[u] = True
            else:
                if u == s:
                    break
                path.pop()
                nodes.pop()
                onpath[u] = False
                u = nodes[-1]
                ptr[u] += 1             # dead-end child: advance past
        return pushed, added


@dataclass
class OptimalPlan:
    flow: float
    cost: float
    paths: List[List[int]]       # node-id paths, one per unit of flow


def solve_training_flow(net: FlowNetwork,
                        cost_matrix: Optional[np.ndarray] = None,
                        data_node: Optional[int] = None,
                        max_flow: Optional[float] = None,
                        want_paths: bool = False,
                        method: str = "auto") -> OptimalPlan:
    """Optimal min-cost max-flow through the stage-layered training graph.

    cost_matrix overrides Eq.1 edge costs (flow tests draw d_ij directly).
    When no override is given, ``net.cost_matrix()`` is consumed as-is —
    including per-link wire-codec pricing when the network carries a
    codec menu — so the oracle optimizes over the same codec-priced
    graph as the decentralized engine.
    When ``data_node`` is given, only that source's flow is considered
    (the GWTF formulation requires flow to return to its own origin).
    ``method`` selects the Dijkstra core (see ``MinCostFlow.solve``).
    """
    CM = (np.asarray(cost_matrix, np.float64) if cost_matrix is not None
          else net.cost_matrix())

    sources = ([net.nodes[data_node]] if data_node is not None
               else net.data_nodes())
    relays = [n for n in net.alive_nodes() if not n.is_data]
    ids = [n.id for n in sources + relays]
    # node splitting: in = 2*k, out = 2*k+1
    index = {nid: k for k, nid in enumerate(ids)}
    V = 2 * len(ids) + 2
    S, T = V - 2, V - 1
    mc = MinCostFlow(V, arc_hint=len(ids) * 8)
    # split-node capacity arcs (in -> out), then supply arcs — batched,
    # same arc order as the scalar construction
    ks = np.array([index[n.id] for n in sources + relays], np.int64)
    caps = np.array([n.capacity for n in sources + relays], np.float64)
    mc.add_edges(2 * ks, 2 * ks + 1, caps, 0.0)
    src_ks = np.array([index[n.id] for n in sources], np.int64)
    src_caps = np.array([n.capacity for n in sources], np.float64)
    mc.add_edges(np.full(len(sources), S, np.int64), 2 * src_ks,
                 src_caps, 0.0)
    total_supply = float(src_caps.sum())
    first = [n for n in relays if n.stage == 0]
    last = [n for n in relays if n.stage == net.num_stages - 1]
    first_ids = np.array([n.id for n in first], np.int64)
    last_ids = np.array([n.id for n in last], np.int64)
    first_ks = np.array([index[n.id] for n in first], np.int64)
    last_ks = np.array([index[n.id] for n in last], np.int64)
    inf = float("inf")
    for src in sources:
        sk = index[src.id]
        if len(first):
            mc.add_edges(np.full(len(first), 2 * sk + 1, np.int64),
                         2 * first_ks, inf, CM[src.id, first_ids])
        if len(last):
            mc.add_edges(2 * last_ks + 1,
                         np.full(len(last), T, np.int64),
                         inf, CM[last_ids, src.id])
    by_stage: Dict[int, List] = {}
    for n in relays:
        by_stage.setdefault(n.stage, []).append(n)
    for s in range(net.num_stages - 1):
        a_nodes = by_stage.get(s, [])
        b_nodes = by_stage.get(s + 1, [])
        if not a_nodes or not b_nodes:
            continue
        a_ids = np.array([n.id for n in a_nodes], np.int64)
        b_ids = np.array([n.id for n in b_nodes], np.int64)
        a_ks = np.array([index[n.id] for n in a_nodes], np.int64)
        b_ks = np.array([index[n.id] for n in b_nodes], np.int64)
        us = np.repeat(2 * a_ks + 1, len(b_nodes))
        vs = np.tile(2 * b_ks, len(a_nodes))
        costs = CM[a_ids][:, b_ids].ravel()
        mc.add_edges(us, vs, inf, costs)
    cap = total_supply if max_flow is None else max_flow
    flow, cost = mc.solve(S, T, cap, method=method)
    paths: List[List[int]] = []
    if want_paths:
        # flow decomposition over the layered DAG: forward arcs with
        # positive residual-backwards capacity carry flow.
        rev = {2 * index[n.id]: n.id for n in sources + relays}
        rev.update({2 * index[n.id] + 1: n.id for n in sources + relays})
        arc_flow = {}
        for u in range(mc.n):
            for idx in mc.graph[u]:
                if idx % 2 == 0 and mc.cap[idx ^ 1] > 1e-9:
                    arc_flow[idx] = mc.cap[idx ^ 1]
        for _ in range(int(flow)):
            # walk S -> T via arcs with remaining decomposed flow
            path, u, ok = [], S, True
            guard = 0
            while u != T and guard < 10 * mc.n:
                guard += 1
                nxt = None
                for idx in mc.graph[u]:
                    if idx % 2 == 0 and arc_flow.get(idx, 0) > 1e-9:
                        nxt = idx
                        break
                if nxt is None:
                    ok = False
                    break
                arc_flow[nxt] -= 1
                u = mc.to[nxt]
                if u in rev and (not path or path[-1] != rev[u]):
                    path.append(rev[u])
            if ok and path:
                # dedupe node-split duplicates, close the loop at origin
                dedup = []
                for nid in path:
                    if not dedup or dedup[-1] != nid:
                        dedup.append(nid)
                dedup.append(dedup[0])
                paths.append(dedup)
    return OptimalPlan(flow=flow, cost=cost, paths=paths)
