"""Centralized min-cost max-flow oracle (out-of-kilter equivalent).

The paper's optimal baselines (Fig. 5, Fig. 7, Table VI) use Fulkerson's
out-of-kilter algorithm [19].  We implement successive shortest paths with
Johnson potentials, which computes the same optimum (min-cost max-flow is
unique in value) in O(F * E log V) — fine at benchmark sizes.

The training graph is layered: super-source -> data nodes -> stage 0 ->
... -> stage S-1 -> super-sink, node capacities enforced by splitting
every node into (in, out) with a capacity arc.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.flow.graph import FlowNetwork


class MinCostFlow:
    """Generic successive-shortest-paths MCMF on an explicit arc list."""

    def __init__(self, n: int):
        self.n = n
        self.graph: List[List[int]] = [[] for _ in range(n)]
        # arcs stored flat: to, cap, cost, flow
        self.to: List[int] = []
        self.cap: List[float] = []
        self.cost: List[float] = []

    def add_edge(self, u: int, v: int, cap: float, cost: float) -> int:
        idx = len(self.to)
        self.graph[u].append(idx)
        self.to.append(v); self.cap.append(cap); self.cost.append(cost)
        self.graph[v].append(idx + 1)
        self.to.append(u); self.cap.append(0.0); self.cost.append(-cost)
        return idx

    def solve(self, s: int, t: int, max_flow: float = float("inf")
              ) -> Tuple[float, float]:
        """Returns (flow, cost)."""
        n = self.n
        flow = cost = 0.0
        potential = [0.0] * n
        while flow < max_flow:
            dist = [float("inf")] * n
            dist[s] = 0.0
            prev_arc = [-1] * n
            pq = [(0.0, s)]
            while pq:
                d, u = heapq.heappop(pq)
                if d > dist[u] + 1e-12:
                    continue
                for idx in self.graph[u]:
                    if self.cap[idx] <= 1e-9:
                        continue
                    v = self.to[idx]
                    nd = d + self.cost[idx] + potential[u] - potential[v]
                    if nd < dist[v] - 1e-12:
                        dist[v] = nd
                        prev_arc[v] = idx
                        heapq.heappush(pq, (nd, v))
            if dist[t] == float("inf"):
                break
            for i in range(n):
                if dist[i] < float("inf"):
                    potential[i] += dist[i]
            # bottleneck along path
            push = max_flow - flow
            v = t
            while v != s:
                idx = prev_arc[v]
                push = min(push, self.cap[idx])
                v = self.to[idx ^ 1]
            v = t
            while v != s:
                idx = prev_arc[v]
                self.cap[idx] -= push
                self.cap[idx ^ 1] += push
                cost += push * self.cost[idx]
                v = self.to[idx ^ 1]
            flow += push
        return flow, cost


@dataclass
class OptimalPlan:
    flow: float
    cost: float
    paths: List[List[int]]       # node-id paths, one per unit of flow


def solve_training_flow(net: FlowNetwork,
                        cost_matrix: Optional[np.ndarray] = None,
                        data_node: Optional[int] = None,
                        max_flow: Optional[float] = None,
                        want_paths: bool = False) -> OptimalPlan:
    """Optimal min-cost max-flow through the stage-layered training graph.

    cost_matrix overrides Eq.1 edge costs (flow tests draw d_ij directly).
    When ``data_node`` is given, only that source's flow is considered
    (the GWTF formulation requires flow to return to its own origin).
    """
    def d(i, j):
        return cost_matrix[i, j] if cost_matrix is not None else net.edge_cost(i, j)

    sources = ([net.nodes[data_node]] if data_node is not None
               else net.data_nodes())
    relays = [n for n in net.alive_nodes() if not n.is_data]
    ids = [n.id for n in sources + relays]
    # node splitting: in = 2*k, out = 2*k+1
    index = {nid: k for k, nid in enumerate(ids)}
    V = 2 * len(ids) + 2
    S, T = V - 2, V - 1
    mc = MinCostFlow(V)
    for n in sources + relays:
        k = index[n.id]
        mc.add_edge(2 * k, 2 * k + 1, n.capacity, 0.0)
    total_supply = 0.0
    for n in sources:
        mc.add_edge(S, 2 * index[n.id], n.capacity, 0.0)
        total_supply += n.capacity
    first = [n for n in relays if n.stage == 0]
    last = [n for n in relays if n.stage == net.num_stages - 1]
    for src in sources:
        for n in first:
            mc.add_edge(2 * index[src.id] + 1, 2 * index[n.id],
                        float("inf"), d(src.id, n.id))
        for n in last:
            mc.add_edge(2 * index[n.id] + 1, T, float("inf"), d(n.id, src.id))
    for s in range(net.num_stages - 1):
        for a in (n for n in relays if n.stage == s):
            for b in (n for n in relays if n.stage == s + 1):
                mc.add_edge(2 * index[a.id] + 1, 2 * index[b.id],
                            float("inf"), d(a.id, b.id))
    cap = total_supply if max_flow is None else max_flow
    flow, cost = mc.solve(S, T, cap)
    paths: List[List[int]] = []
    if want_paths:
        # flow decomposition over the layered DAG: forward arcs with
        # positive residual-backwards capacity carry flow.
        rev = {2 * index[n.id]: n.id for n in sources + relays}
        rev.update({2 * index[n.id] + 1: n.id for n in sources + relays})
        arc_flow = {}
        for u in range(mc.n):
            for idx in mc.graph[u]:
                if idx % 2 == 0 and mc.cap[idx ^ 1] > 1e-9:
                    arc_flow[idx] = mc.cap[idx ^ 1]
        for _ in range(int(flow)):
            # walk S -> T via arcs with remaining decomposed flow
            path, u, ok = [], S, True
            guard = 0
            while u != T and guard < 10 * mc.n:
                guard += 1
                nxt = None
                for idx in mc.graph[u]:
                    if idx % 2 == 0 and arc_flow.get(idx, 0) > 1e-9:
                        nxt = idx
                        break
                if nxt is None:
                    ok = False
                    break
                arc_flow[nxt] -= 1
                u = mc.to[nxt]
                if u in rev and (not path or path[-1] != rev[u]):
                    path.append(rev[u])
            if ok and path:
                # dedupe node-split duplicates, close the loop at origin
                dedup = []
                for nid in path:
                    if not dedup or dedup[-1] != nid:
                        dedup.append(nid)
                dedup.append(dedup[0])
                paths.append(dedup)
    return OptimalPlan(flow=flow, cost=cost, paths=paths)
