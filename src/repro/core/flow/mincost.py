"""Centralized min-cost max-flow oracle (out-of-kilter equivalent).

The paper's optimal baselines (Fig. 5, Fig. 7, Table VI) use Fulkerson's
out-of-kilter algorithm [19].  We implement successive shortest paths
with Johnson potentials, which computes the same optimum (min-cost
max-flow is unique in value).

Arc storage is preallocated NumPy arrays with geometric growth (amortized
O(1) per ``add_edge``), and the inner Dijkstra is array-based: node
extraction by masked ``argmin`` over the distance vector and vectorized
relaxation of each node's CSR arc slice.  O(F * (V^2 + E)) with C-speed
constants — this keeps the optimal baseline usable as a reference at the
scaling benchmark's thousands-of-relays sizes, where the seed's
pure-Python heap version dominated benchmark wall-clock.

The training graph is layered: super-source -> data nodes -> stage 0 ->
... -> stage S-1 -> super-sink, node capacities enforced by splitting
every node into (in, out) with a capacity arc.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.flow.graph import FlowNetwork


class MinCostFlow:
    """Successive-shortest-paths MCMF on preallocated NumPy arc arrays.

    ``to``/``cap``/``cost`` keep their original (arc-indexed) meaning —
    arc ``i ^ 1`` is the reverse of arc ``i`` — but are exposed as array
    views; ``graph[u]`` (adjacency lists of arc ids, insertion order) is
    materialised lazily for the path-decomposition consumers.
    """

    def __init__(self, n: int, arc_hint: int = 64):
        self.n = n
        self._m = 0
        capacity = max(16, 2 * arc_hint)
        self._to = np.empty(capacity, np.int64)
        self._cap = np.empty(capacity, np.float64)
        self._cost = np.empty(capacity, np.float64)
        self._src = np.empty(capacity, np.int64)
        self._graph: Optional[List[List[int]]] = None

    # -- array views / legacy accessors ---------------------------------
    @property
    def to(self) -> np.ndarray:
        return self._to[:self._m]

    @property
    def cap(self) -> np.ndarray:
        return self._cap[:self._m]

    @property
    def cost(self) -> np.ndarray:
        return self._cost[:self._m]

    @property
    def graph(self) -> List[List[int]]:
        if self._graph is None:
            g: List[List[int]] = [[] for _ in range(self.n)]
            for idx, u in enumerate(self._src[:self._m].tolist()):
                g[u].append(idx)
            self._graph = g
        return self._graph

    def _grow(self, need: int):
        capacity = len(self._to)
        if need <= capacity:
            return
        new = max(need, 2 * capacity)
        for name in ("_to", "_cap", "_cost", "_src"):
            old = getattr(self, name)
            arr = np.empty(new, old.dtype)
            arr[:self._m] = old[:self._m]
            setattr(self, name, arr)

    def add_edge(self, u: int, v: int, cap: float, cost: float) -> int:
        idx = self._m
        self._grow(idx + 2)
        self._to[idx] = v
        self._cap[idx] = cap
        self._cost[idx] = cost
        self._src[idx] = u
        self._to[idx + 1] = u
        self._cap[idx + 1] = 0.0
        self._cost[idx + 1] = -cost
        self._src[idx + 1] = v
        self._m += 2
        self._graph = None
        return idx

    def solve(self, s: int, t: int, max_flow: float = float("inf")
              ) -> Tuple[float, float]:
        """Returns (flow, cost)."""
        n, m = self.n, self._m
        # CSR adjacency: arcs grouped by source, insertion order preserved
        src = self._src[:m]
        arc_order = np.argsort(src, kind="stable")
        to_sorted = self._to[arc_order]
        cost_sorted = self._cost[arc_order]
        start = np.zeros(n + 1, np.int64)
        np.cumsum(np.bincount(src, minlength=n), out=start[1:])
        inf = float("inf")
        flow = cost = 0.0
        potential = np.zeros(n)
        while flow < max_flow:
            dist = np.full(n, inf)
            dist[s] = 0.0
            prev_arc = np.full(n, -1, np.int64)
            done = np.zeros(n, bool)
            for _ in range(n):
                u = int(np.argmin(np.where(done, inf, dist)))
                if done[u] or dist[u] == inf:
                    break
                done[u] = True
                a0, a1 = int(start[u]), int(start[u + 1])
                if a0 == a1:
                    continue
                arcs = arc_order[a0:a1]
                open_ = self._cap[arcs] > 1e-9
                if not open_.any():
                    continue
                arcs = arcs[open_]
                vs = to_sorted[a0:a1][open_]
                nd = dist[u] + cost_sorted[a0:a1][open_] \
                    + potential[u] - potential[vs]
                better = nd < dist[vs] - 1e-12
                if better.any():
                    vs_b = vs[better]
                    nd_b = nd[better]
                    arcs_b = arcs[better]
                    np.minimum.at(dist, vs_b, nd_b)
                    # any arc achieving the (possibly shared) new minimum
                    won = nd_b == dist[vs_b]
                    prev_arc[vs_b[won]] = arcs_b[won]
            if dist[t] == inf:
                break
            finite = dist < inf
            potential[finite] += dist[finite]
            # bottleneck along path
            push = max_flow - flow
            v = t
            while v != s:
                idx = int(prev_arc[v])
                push = min(push, float(self._cap[idx]))
                v = int(self._to[idx ^ 1])
            v = t
            while v != s:
                idx = int(prev_arc[v])
                self._cap[idx] -= push
                self._cap[idx ^ 1] += push
                cost += push * float(self._cost[idx])
                v = int(self._to[idx ^ 1])
            flow += push
        return float(flow), float(cost)


@dataclass
class OptimalPlan:
    flow: float
    cost: float
    paths: List[List[int]]       # node-id paths, one per unit of flow


def solve_training_flow(net: FlowNetwork,
                        cost_matrix: Optional[np.ndarray] = None,
                        data_node: Optional[int] = None,
                        max_flow: Optional[float] = None,
                        want_paths: bool = False) -> OptimalPlan:
    """Optimal min-cost max-flow through the stage-layered training graph.

    cost_matrix overrides Eq.1 edge costs (flow tests draw d_ij directly).
    When ``data_node`` is given, only that source's flow is considered
    (the GWTF formulation requires flow to return to its own origin).
    """
    def d(i, j):
        return cost_matrix[i, j] if cost_matrix is not None else net.edge_cost(i, j)

    sources = ([net.nodes[data_node]] if data_node is not None
               else net.data_nodes())
    relays = [n for n in net.alive_nodes() if not n.is_data]
    ids = [n.id for n in sources + relays]
    # node splitting: in = 2*k, out = 2*k+1
    index = {nid: k for k, nid in enumerate(ids)}
    V = 2 * len(ids) + 2
    S, T = V - 2, V - 1
    mc = MinCostFlow(V)
    for n in sources + relays:
        k = index[n.id]
        mc.add_edge(2 * k, 2 * k + 1, n.capacity, 0.0)
    total_supply = 0.0
    for n in sources:
        mc.add_edge(S, 2 * index[n.id], n.capacity, 0.0)
        total_supply += n.capacity
    first = [n for n in relays if n.stage == 0]
    last = [n for n in relays if n.stage == net.num_stages - 1]
    for src in sources:
        for n in first:
            mc.add_edge(2 * index[src.id] + 1, 2 * index[n.id],
                        float("inf"), d(src.id, n.id))
        for n in last:
            mc.add_edge(2 * index[n.id] + 1, T, float("inf"), d(n.id, src.id))
    for s in range(net.num_stages - 1):
        for a in (n for n in relays if n.stage == s):
            for b in (n for n in relays if n.stage == s + 1):
                mc.add_edge(2 * index[a.id] + 1, 2 * index[b.id],
                            float("inf"), d(a.id, b.id))
    cap = total_supply if max_flow is None else max_flow
    flow, cost = mc.solve(S, T, cap)
    paths: List[List[int]] = []
    if want_paths:
        # flow decomposition over the layered DAG: forward arcs with
        # positive residual-backwards capacity carry flow.
        rev = {2 * index[n.id]: n.id for n in sources + relays}
        rev.update({2 * index[n.id] + 1: n.id for n in sources + relays})
        arc_flow = {}
        for u in range(mc.n):
            for idx in mc.graph[u]:
                if idx % 2 == 0 and mc.cap[idx ^ 1] > 1e-9:
                    arc_flow[idx] = mc.cap[idx ^ 1]
        for _ in range(int(flow)):
            # walk S -> T via arcs with remaining decomposed flow
            path, u, ok = [], S, True
            guard = 0
            while u != T and guard < 10 * mc.n:
                guard += 1
                nxt = None
                for idx in mc.graph[u]:
                    if idx % 2 == 0 and arc_flow.get(idx, 0) > 1e-9:
                        nxt = idx
                        break
                if nxt is None:
                    ok = False
                    break
                arc_flow[nxt] -= 1
                u = mc.to[nxt]
                if u in rev and (not path or path[-1] != rev[u]):
                    path.append(rev[u])
            if ok and path:
                # dedupe node-split duplicates, close the loop at origin
                dedup = []
                for nid in path:
                    if not dedup or dedup[-1] != nid:
                        dedup.append(nid)
                dedup.append(dedup[0])
                paths.append(dedup)
    return OptimalPlan(flow=flow, cost=cost, paths=paths)
